# pytest: Pallas kernel vs pure-numpy oracle — the CORE correctness signal.
#
# hypothesis sweeps shapes and quantization parameter regimes; every case
# asserts the Pallas (interpret=True) kernel matches ref.py bit-for-bit on
# codes and allclose on reconstructions.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import quant as qk
from compile.kernels import ref


def run_quant_pallas(x, scale, zp, lo, hi):
    s = lambda v: jnp.asarray([v], jnp.float32)
    return np.asarray(qk.quantize(jnp.asarray(x), s(scale), s(zp), s(lo), s(hi)))


def run_dequant_pallas(codes, scale, zp):
    s = lambda v: jnp.asarray([v], jnp.float32)
    return np.asarray(qk.dequantize(jnp.asarray(codes, jnp.int32), s(scale), s(zp)))


shapes = st.tuples(st.integers(1, 96), st.sampled_from([1, 3, 8, 32, 128]))
bits = st.sampled_from(ref.SUPPORTED_BITS)


@settings(max_examples=40, deadline=None)
@given(shape=shapes, q=bits, seed=st.integers(0, 2**31 - 1), sigma=st.floats(0.01, 10.0))
def test_quantize_naive_matches_ref(shape, q, seed, sigma):
    rng = np.random.default_rng(seed)
    x = rng.laplace(0.0, sigma, shape).astype(np.float32)
    scale, zp, lo, hi = ref.naive_params(x, q)
    want = ref.quantize(x, scale, zp, lo, hi)
    got = run_quant_pallas(x, scale, zp, lo, hi)
    # round-half tie behaviour can differ by 1 code at exact .5 boundaries
    diff = np.abs(got - want)
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.02  # ties are rare for continuous data


@settings(max_examples=40, deadline=None)
@given(shape=shapes, q=bits, seed=st.integers(0, 2**31 - 1), sigma=st.floats(0.01, 10.0))
def test_quantize_symmetric_matches_ref(shape, q, seed, sigma):
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, sigma, shape).astype(np.float32)
    alpha = ref.aciq_alpha(x, q)
    scale, zp, lo, hi = ref.symmetric_params(alpha, q)
    want = ref.quantize(x, scale, zp, lo, hi)
    got = run_quant_pallas(x, scale, zp, lo, hi)
    diff = np.abs(got - want)
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.02


@settings(max_examples=40, deadline=None)
@given(shape=shapes, q=bits, seed=st.integers(0, 2**31 - 1))
def test_dequantize_matches_ref(shape, q, seed):
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (q - 1)), (1 << (q - 1)) - 1
    codes = rng.integers(lo, hi + 1, shape).astype(np.int32)
    scale, zp = 0.173, 0.0
    want = ref.dequantize(codes, scale, zp)
    got = run_dequant_pallas(codes, scale, zp)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(shape=shapes, q=bits, seed=st.integers(0, 2**31 - 1))
def test_roundtrip_error_bounded(shape, q, seed):
    """Reconstruction error inside the representable range [lo*s, hi*s] is
    bounded by scale/2; values beyond it clamp to the range edge."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 1.0, shape).astype(np.float32)
    alpha = float(np.abs(x).max()) + 1e-3
    scale, zp, lo, hi = ref.symmetric_params(alpha, q)
    codes = run_quant_pallas(x, scale, zp, lo, hi)
    xh = run_dequant_pallas(codes, scale, zp)
    rep_lo, rep_hi = lo * scale, hi * scale
    inside = (x >= rep_lo) & (x <= rep_hi)
    assert np.abs(xh[inside] - x[inside]).max(initial=0.0) <= scale / 2 + 1e-6
    assert np.all(np.abs(xh[~inside] - rep_hi) < scale + 1e-6) or np.all(
        np.abs(xh[~inside] - rep_lo) < scale + 1e-6
    )


def test_quantize_codes_in_range():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 5.0, (64, 128)).astype(np.float32)
    for q in ref.SUPPORTED_BITS:
        scale, zp, lo, hi = ref.symmetric_params(0.5, q)  # deliberately tight clip
        codes = run_quant_pallas(x, scale, zp, lo, hi)
        assert codes.min() >= lo and codes.max() <= hi


def test_pick_block_rows_divides():
    for rows in [1, 7, 64, 96, 1000, 1024]:
        br = qk.pick_block_rows(rows)
        assert rows % br == 0 and 1 <= br <= 128


def test_vmem_budget():
    # One grid step must fit comfortably in a 16 MB VMEM budget.
    assert qk.vmem_bytes(128, 128) < 16 * 2**20 // 8
