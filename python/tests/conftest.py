import sys
from pathlib import Path

# Tests run as `cd python && pytest tests/` — make `compile` importable.
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
