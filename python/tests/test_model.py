# Model/partitioning correctness: staged execution must equal the full
# forward pass exactly (pipelining must not change semantics), and the
# synthetic task must be learnable enough to carry an accuracy axis.
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import data
from compile.model import (
    ViTConfig,
    boundary_activations,
    forward,
    forward_staged,
    init_params,
    param_count,
    stage_cuts,
)

CFG = ViTConfig()


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    imgs, labels = data.make_split(seed=123, n=8)
    return jnp.asarray(imgs), np.asarray(labels)


def test_param_count_about_1m(params):
    n = param_count(params)
    assert 0.5e6 < n < 3e6


@pytest.mark.parametrize("n_stages", [1, 2, 3, 4, 8])
def test_staged_equals_full(params, batch, n_stages):
    imgs, _ = batch
    full = forward(CFG, params, imgs)
    staged = forward_staged(CFG, params, imgs, n_stages)
    np.testing.assert_allclose(np.asarray(full), np.asarray(staged), rtol=2e-5, atol=2e-5)


def test_stage_cuts_cover_all_blocks():
    for depth in (4, 8, 12):
        for n in range(1, depth + 1):
            cuts = stage_cuts(depth, n)
            assert cuts[0][0] == 0 and cuts[-1][1] == depth
            for (a, b), (c, d) in zip(cuts, cuts[1:]):
                assert b == c and b > a
            sizes = [b - a for a, b in cuts]
            assert max(sizes) - min(sizes) <= 1  # even partition


def test_boundary_activation_shapes(params, batch):
    imgs, _ = batch
    acts = boundary_activations(CFG, params, imgs, 4)
    assert len(acts) == 3
    for a in acts:
        assert a.shape == (8, CFG.tokens, CFG.dim)


def test_logit_shape(params, batch):
    imgs, _ = batch
    logits = forward(CFG, params, imgs)
    assert logits.shape == (8, CFG.classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_dataset_deterministic():
    a_imgs, a_labels = data.make_split(seed=42, n=16)
    b_imgs, b_labels = data.make_split(seed=42, n=16)
    np.testing.assert_array_equal(a_imgs, b_imgs)
    np.testing.assert_array_equal(a_labels, b_labels)


def test_dataset_learnable_by_linear_probe():
    """Sanity: class prototypes are separable — nearest-prototype accuracy
    far above chance (1%), so a trained ViT has signal to learn. It must
    not be trivially easy either (fine-grained classes share a base)."""
    protos = data.make_prototypes()
    rng = np.random.default_rng(9)
    imgs, labels = data.sample_batch(rng, protos, 512)
    flat_p = protos.reshape(data.NUM_CLASSES, -1)
    flat_x = imgs.reshape(512, -1)
    # Cosine nearest-prototype classification.
    fp = flat_p / np.linalg.norm(flat_p, axis=1, keepdims=True)
    fx = flat_x / np.linalg.norm(flat_x, axis=1, keepdims=True)
    pred = (fx @ fp.T).argmax(1)
    acc = (pred == labels).mean()
    assert acc > 0.2, f"probe accuracy {acc} too close to chance"


def test_dataset_images_heavy_tailed():
    """The contrast mixture + sparse base make image statistics
    leptokurtic — the premise for heavy-tailed activations (Fig 3/4)."""
    imgs, _ = data.make_split(seed=11, n=256)
    x = imgs.ravel()
    kurt = ((x - x.mean()) ** 4).mean() / (x.std() ** 4) - 3
    assert kurt > 2.0, f"excess kurtosis {kurt}"


def test_activation_distribution_long_tailed(params):
    """The premise of Fig 3: boundary activations have outliers, so the
    naive min/max range is much wider than the bulk of the data."""
    imgs, _ = data.make_split(seed=55, n=16)
    acts = boundary_activations(CFG, params, jnp.asarray(imgs), 4)
    for a in acts:
        a = np.asarray(a).ravel()
        assert np.abs(a).max() > 6 * np.abs(a).std()
