# Oracle self-tests: the §3 math (ACIQ, DS-ACIQ, PDA) behaves as the paper
# claims on controlled distributions. These pin down the semantics the rust
# implementation is validated against (via golden.json).
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def test_aciq_ratios_match_banner_constants():
    # Banner et al. report alpha*/b = 2.83 (2-bit), 5.03 (4-bit) for Laplace.
    assert ref.aciq_ratio(2) == pytest.approx(2.83, abs=0.02)
    assert ref.aciq_ratio(3) == pytest.approx(3.89, abs=0.02)
    assert ref.aciq_ratio(4) == pytest.approx(5.03, abs=0.02)


def test_aciq_ratio_monotone_in_bits():
    rs = [ref.aciq_ratio(q) for q in range(2, 17)]
    assert all(b > a for a, b in zip(rs, rs[1:]))


def test_aciq_ratio_is_minimizer():
    for q in (2, 4, 8):
        r = ref.aciq_ratio(q)
        m0 = ref.aciq_mse_laplace(r, q)
        for eps in (-0.05, 0.05):
            assert ref.aciq_mse_laplace(r + eps, q) >= m0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.floats(0.05, 5.0))
def test_laplace_b_estimates_scale(seed, b):
    rng = np.random.default_rng(seed)
    x = rng.laplace(0.0, b, 20000)
    assert ref.laplace_b(x) == pytest.approx(b, rel=0.06)


def test_aciq_beats_naive_with_outliers():
    """The paper's Fig 3 phenomenon: outliers wreck the naive min/max range
    (its quantization interval is orders of magnitude wider), so the bulk of
    the distribution rounds to zero; ACIQ clipping preserves it. Note MSE is
    the wrong lens at high bitwidths (clipping trades outlier error for bulk
    resolution), so we assert on interval width and bulk error."""
    rng = np.random.default_rng(1)
    x = np.concatenate([rng.normal(0, 0.5, 50000), rng.normal(0, 30.0, 50)]).astype(np.float32)
    bulk = x[np.abs(x) < 2.0]
    for q in (2, 4, 6, 8):
        s_naive, *_ = ref.naive_params(x, q)
        s_aciq, *_ = ref.symmetric_params(ref.aciq_alpha(x, q), q)
        assert s_aciq < s_naive / 5, f"q={q}: aciq interval should be much tighter"
        bulk_err_naive = np.median(np.abs(bulk - ref.quantize_naive(x, q)[np.abs(x) < 2.0]))
        bulk_err_aciq = np.median(np.abs(bulk - ref.quantize_aciq(x, q)[np.abs(x) < 2.0]))
        assert bulk_err_aciq < bulk_err_naive + 1e-9, f"q={q}"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ds_aciq_never_worse_on_density_fit(seed):
    """The search includes b_E, so the Eq. 1 density-fit MSE at b* is
    never worse than ACIQ's implicit estimate."""
    rng = np.random.default_rng(seed)
    x = np.concatenate(
        [rng.normal(0, 0.3, 8000), rng.laplace(0, 1.5, 2000)]
    ).astype(np.float32)
    counts, centers, width = ref.histogram(x)
    b_e = ref.laplace_b(x)
    fit_e = ref.density_fit_mse(counts, centers, width, b_e)
    for q in (2, 4):
        _, fit_star = ref.ds_aciq_b(x, q)
        assert fit_star <= fit_e + 1e-15


def test_ds_aciq_improves_density_fit_at_2bit():
    """Fig 4's claim: a sharply-peaked bulk + wide tail makes the moment
    estimate's Laplace miss the real histogram; the directed search (down,
    towards the real peak) cuts the Eq. 1 fit MSE by ~50% or more."""
    rng = np.random.default_rng(7)
    x = np.concatenate(
        [rng.laplace(0, 0.1, 50000), rng.laplace(0, 2.0, 5000)]
    ).astype(np.float32)
    b_e = ref.laplace_b(x)
    counts, centers, width = ref.histogram(x)
    fit_e = ref.density_fit_mse(counts, centers, width, b_e)
    b_star, fit_star = ref.ds_aciq_b(x, 2)
    assert b_star < b_e  # searched down (real peak above Laplace estimate)
    assert fit_star < fit_e * 0.5  # paper: "decreases the MSE by around 50%"


def test_pda_dispatch():
    """PDA = DS-ACIQ at 2/4-bit, plain ACIQ otherwise (paper §3)."""
    rng = np.random.default_rng(3)
    x = rng.laplace(0, 1.0, 5000).astype(np.float32)
    for q in (6, 8, 16):
        np.testing.assert_array_equal(ref.quantize_pda(x, q), ref.quantize_aciq(x, q))


def test_histogram_total_mass():
    x = np.random.default_rng(0).normal(0, 1, 10000)
    counts, centers, width = ref.histogram(x)
    assert counts.sum() == 10000
    assert len(counts) == len(centers) == 2048
    assert width > 0


def test_symmetric_params_ranges():
    for q in ref.SUPPORTED_BITS:
        s, zp, lo, hi = ref.symmetric_params(1.0, q)
        assert zp == 0.0
        assert hi - lo + 1 == (1 << q)
        assert s == pytest.approx(1.0 / (1 << (q - 1)))


def test_naive_params_cover_range():
    rng = np.random.default_rng(5)
    x = rng.normal(3.0, 2.0, 1000).astype(np.float32)  # asymmetric data
    for q in ref.SUPPORTED_BITS:
        s, zp, lo, hi = ref.naive_params(x, q)
        codes = ref.quantize(x, s, zp, lo, hi)
        assert codes.min() >= lo and codes.max() <= hi
        # min and max of the tensor must map near the code range ends
        assert ref.quantize(np.array([x.min()]), s, zp, lo, hi)[0] <= lo + 1
        assert ref.quantize(np.array([x.max()]), s, zp, lo, hi)[0] >= hi - 1
