"""AOT driver: train (cached) -> lower shards + kernels to HLO text ->
export eval set, calibration activations, golden vectors, manifest.

Interchange format is HLO **text**, NOT .serialize(): the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Run via `make artifacts` (no-op when inputs are unchanged):
    cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import struct
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from .kernels import quant as qk
from .kernels import ref
from .model import (
    ViTConfig,
    boundary_activations,
    forward,
    init_params,
    param_count,
    stage_cuts,
    stage_fn,
)
from .train import load_or_train

EVAL_MAGIC = 0x51504556  # "QPEV"
CALIB_MAGIC = 0x51504341  # "QPCA"


def to_hlo_text(fn, *specs) -> str:
    """Lower a jitted fn (must return a tuple) to HLO text.

    `as_hlo_text(True)` == print_large_constants: the model weights are
    baked into the shard as constants and MUST survive the text round-trip
    (the default elides them as `{...}`, which parses back as zeros)."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def write_eval_bin(path: Path, imgs: np.ndarray, labels: np.ndarray) -> None:
    """Binary eval set consumed by rust/src/data. Layout:
    u32 magic | u32 version | u32 count | u32 h | u32 w | u32 c |
    f32[count*h*w*c] images | u32[count] labels  (all little-endian)."""
    n, h, w, c = imgs.shape
    with open(path, "wb") as f:
        f.write(struct.pack("<IIIIII", EVAL_MAGIC, 1, n, h, w, c))
        f.write(imgs.astype("<f4").tobytes())
        f.write(labels.astype("<u4").tobytes())


def write_calib_bin(path: Path, acts: list[np.ndarray]) -> None:
    """Boundary calibration activations for rust-side analyses (Fig 3/4 and
    DS-ACIQ goldens). Layout: u32 magic | u32 version | u32 n_tensors |
    then per tensor: u32 rank | u32 dims[rank] | f32 data."""
    with open(path, "wb") as f:
        f.write(struct.pack("<III", CALIB_MAGIC, 1, len(acts)))
        for a in acts:
            a = np.asarray(a, "<f4")
            f.write(struct.pack("<I", a.ndim))
            f.write(struct.pack(f"<{a.ndim}I", *a.shape))
            f.write(a.tobytes())


def golden_vectors(acts: list[np.ndarray], rng: np.random.Generator) -> dict:
    """Cross-language golden vectors: the rust quant library must reproduce
    these numbers (tests/golden.rs)."""
    cases = []
    # A real boundary activation slice + controlled synthetic distributions.
    samples = {
        "boundary0_slice": np.asarray(acts[0]).ravel()[:4096].astype(np.float32),
        "laplace": rng.laplace(0.0, 0.7, 4096).astype(np.float32),
        "gauss_outliers": np.concatenate(
            [rng.normal(0, 0.5, 4000), rng.normal(0, 8.0, 96)]
        ).astype(np.float32),
    }
    for name, x in samples.items():
        for q in ref.SUPPORTED_BITS:
            s, zp, lo, hi = ref.naive_params(x, q)
            naive_rt = ref.quant_roundtrip(x, s, zp, lo, hi)
            alpha = ref.aciq_alpha(x, q)
            b_star, ds_mse = ref.ds_aciq_b(x, q)
            cases.append(
                {
                    "name": name,
                    "q": q,
                    "b_e": ref.laplace_b(x),
                    "aciq_ratio": ref.ACIQ_RATIOS[q],
                    "aciq_alpha": alpha,
                    "naive_scale": float(s),
                    "naive_zp": float(zp),
                    "naive_mse": ref.mse(x, naive_rt),
                    "aciq_mse": ref.mse(x, ref.quantize_aciq(x, q)),
                    "ds_b_star": b_star,
                    "ds_hist_mse": ds_mse,
                    "pda_mse": ref.mse(x, ref.quantize_pda(x, q)),
                }
            )
    # Exact-code vectors: tiny input, full expected codes, both modes.
    x_small = np.array(
        [-3.0, -1.5, -0.4, -0.05, 0.0, 0.02, 0.3, 0.9, 1.7, 4.2], np.float32
    )
    exact = []
    for q in ref.SUPPORTED_BITS:
        s, zp, lo, hi = ref.naive_params(x_small, q)
        exact.append(
            {
                "q": q,
                "mode": "naive",
                "scale": float(s),
                "zp": float(zp),
                "lo": lo,
                "hi": hi,
                "codes": ref.quantize(x_small, s, zp, lo, hi).tolist(),
            }
        )
        s2, zp2, lo2, hi2 = ref.symmetric_params(ref.aciq_alpha(x_small, q), q)
        exact.append(
            {
                "q": q,
                "mode": "aciq",
                "scale": float(s2),
                "zp": float(zp2),
                "lo": lo2,
                "hi": hi2,
                "codes": ref.quantize(x_small, s2, zp2, lo2, hi2).tolist(),
            }
        )
    return {"x_small": x_small.tolist(), "cases": cases, "exact": exact}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--microbatch", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=600)
    ap.add_argument("--eval-count", type=int, default=1920)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--untrained", action="store_true", help="skip training (tests only)")
    args = ap.parse_args()

    out = Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    cfg = ViTConfig()
    S = args.microbatch

    # ---- weights -----------------------------------------------------------
    if args.untrained:
        params = init_params(cfg, seed=args.seed)
    else:
        params = load_or_train(cfg, out, steps=args.train_steps, seed=args.seed)
    print(f"[aot] model: {param_count(params)/1e6:.2f}M params, "
          f"{cfg.depth} blocks, {args.stages} stages, microbatch {S}")

    # ---- stage HLOs --------------------------------------------------------
    cuts = stage_cuts(cfg.depth, args.stages)
    act_shape = (S, cfg.tokens, cfg.dim)
    img_spec = jax.ShapeDtypeStruct((S, *cfg.img), jnp.float32)
    act_spec = jax.ShapeDtypeStruct(act_shape, jnp.float32)
    stages_meta = []
    for s, (lo, hi) in enumerate(cuts):
        first, last = s == 0, s == len(cuts) - 1
        fn = stage_fn(cfg, params, lo, hi, first, last)
        in_spec = img_spec if first else act_spec
        text = to_hlo_text(fn, in_spec)
        fname = f"stage_{s}.hlo.txt"
        (out / fname).write_text(text)
        out_shape = [S, cfg.classes] if last else list(act_shape)
        stages_meta.append(
            {
                "file": fname,
                "blocks": [lo, hi],
                "first": first,
                "last": last,
                "in_shape": list(in_spec.shape),
                "out_shape": out_shape,
            }
        )
        print(f"[aot] wrote {fname} (blocks {lo}..{hi}, {len(text)} chars)")

    # Full (unpartitioned) model — single-node baseline + quickstart.
    full_text = to_hlo_text(lambda x: (forward(cfg, params, x),), img_spec)
    (out / "model_full.hlo.txt").write_text(full_text)

    # ---- quant kernel HLOs (one pair; bitwidth is runtime data) ------------
    rows, cols = S * cfg.tokens, cfg.dim
    f1 = jax.ShapeDtypeStruct((1,), jnp.float32)
    x2d = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    c2d = jax.ShapeDtypeStruct((rows, cols), jnp.int32)
    (out / "quantize.hlo.txt").write_text(
        to_hlo_text(qk.quantize_fn_for_export(rows, cols), x2d, f1, f1, f1, f1)
    )
    (out / "dequantize.hlo.txt").write_text(
        to_hlo_text(qk.dequantize_fn_for_export(rows, cols), c2d, f1, f1)
    )
    print(f"[aot] wrote quantize/dequantize HLO ({rows}x{cols})")

    # ---- eval set -----------------------------------------------------------
    n_eval = (args.eval_count // S) * S
    ev_imgs, ev_labels = data.make_split(seed=777, n=n_eval)
    write_eval_bin(out / "eval.bin", ev_imgs, ev_labels)
    fp32_logits = np.asarray(forward(cfg, params, jnp.asarray(ev_imgs)))
    fp32_acc = float((fp32_logits.argmax(-1) == ev_labels).mean())
    print(f"[aot] eval set: {n_eval} images, fp32 top-1 = {fp32_acc*100:.2f}%")

    # ---- calibration boundary activations (one microbatch) ------------------
    calib_imgs, _ = data.make_split(seed=4242, n=S)
    acts = [np.asarray(a) for a in
            boundary_activations(cfg, params, jnp.asarray(calib_imgs), args.stages)]
    write_calib_bin(out / "calib.bin", acts)

    # ---- golden vectors ------------------------------------------------------
    rng = np.random.default_rng(99)
    (out / "golden.json").write_text(json.dumps(golden_vectors(acts, rng), indent=1))

    # ---- manifest ------------------------------------------------------------
    manifest = {
        "version": 1,
        "model": {
            "img": list(cfg.img),
            "patch": cfg.patch,
            "dim": cfg.dim,
            "depth": cfg.depth,
            "heads": cfg.heads,
            "classes": cfg.classes,
            "tokens": cfg.tokens,
            "params": param_count(params),
            "trained": not args.untrained,
            "fp32_top1": fp32_acc,
        },
        "microbatch": S,
        "activation_shape": list(act_shape),
        "stages": stages_meta,
        "full_model": {"file": "model_full.hlo.txt",
                       "in_shape": [S, *cfg.img], "out_shape": [S, cfg.classes]},
        "quant": {
            "quantize": "quantize.hlo.txt",
            "dequantize": "dequantize.hlo.txt",
            "rows": rows,
            "cols": cols,
            "supported_bits": list(ref.SUPPORTED_BITS),
            "aciq_ratios": {str(q): ref.ACIQ_RATIOS[q] for q in ref.SUPPORTED_BITS},
        },
        "eval": {"file": "eval.bin", "count": n_eval},
        "calib": {"file": "calib.bin", "boundaries": len(acts)},
        "golden": "golden.json",
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[aot] manifest.json written to {out}")


if __name__ == "__main__":
    main()
