"""Synthetic fine-grained sparse-texture classification dataset.

Stand-in for ImageNet (not available in the sandbox — see DESIGN.md
§Substitutions). Design goals, each mapped to a property the paper's
evaluation depends on:

* **Shared sparse base texture** (high-amplitude content confined to a few
  patches): token energies form a sparse/heavy-tailed mixture, so trained
  boundary activations are leptokurtic at the early cut — the activation
  regime ACIQ/DS-ACIQ target (Fig 3/4).
* **Per-image contrast mixture** (log-uniform gain): natural images vary
  widely in dynamic range; the pooled activation distribution becomes a
  scale mixture with outliers, which is what breaks naive min/max PTQ.
* **Fine-grained classes** (100 classes = shared base + small dense
  class-specific detail): decision margins are small relative to
  activation magnitude, so low-bitwidth quantization noise actually costs
  accuracy — the hardness axis Table 1 needs (fp32 lands ≈ 92-95%).

All randomness is seeded so `make artifacts` is reproducible bit-for-bit.
"""

from __future__ import annotations

import numpy as np

IMG_H, IMG_W, IMG_C = 32, 32, 3
NUM_CLASSES = 100
BASE_PATCHES = 6       # how many of the 16 patches carry the base texture
BASE_AMP = 2.5         # base texture amplitude
DETAIL_AMP = 0.5       # class-specific detail amplitude (the fine-grained signal)
NOISE = 1.0            # per-pixel Gaussian noise sigma
GAIN_RANGE = (0.25, 4.0)  # per-image contrast, log-uniform


def make_prototypes(seed: int = 0) -> np.ndarray:
    """Fixed class prototypes, shape (NUM_CLASSES, H, W, C): one shared
    sparse base texture + a small dense class-specific detail field."""
    rng = np.random.default_rng(seed)
    base = np.zeros((IMG_H, IMG_W, IMG_C), np.float32)
    pids = rng.choice(16, size=BASE_PATCHES, replace=False)
    for p in pids:
        r, c = (p // 4) * 8, (p % 4) * 8
        base[r : r + 8, c : c + 8, :] = rng.normal(0, BASE_AMP, (8, 8, IMG_C))
    protos = np.zeros((NUM_CLASSES, IMG_H, IMG_W, IMG_C), np.float32)
    for k in range(NUM_CLASSES):
        detail = rng.normal(0, DETAIL_AMP, (IMG_H, IMG_W, IMG_C)).astype(np.float32)
        protos[k] = base + detail
    return protos


def sample_batch(
    rng: np.random.Generator,
    protos: np.ndarray,
    n: int,
    noise: float = NOISE,
) -> tuple[np.ndarray, np.ndarray]:
    """Draw n labelled images. Returns (images f32[n,H,W,C], labels i32[n])."""
    ncls = protos.shape[0]
    labels = rng.integers(0, ncls, size=n)
    base = protos[labels]
    eps = rng.normal(0.0, noise, size=base.shape).astype(np.float32)
    # Per-image contrast (log-uniform): the scale-mixture driver.
    logg = rng.uniform(np.log(GAIN_RANGE[0]), np.log(GAIN_RANGE[1]), size=(n, 1, 1, 1))
    gain = np.exp(logg).astype(np.float32)
    imgs = gain * (base + eps)
    return imgs.astype(np.float32), labels.astype(np.int32)


def make_split(seed: int, n: int, noise: float = NOISE):
    """Deterministic dataset split (train/eval use disjoint seeds)."""
    protos = make_prototypes()
    rng = np.random.default_rng(seed)
    return sample_batch(rng, protos, n, noise=noise)
