"""L1: Pallas kernels for the quantization hot-spot.

The paper's compute hot-spot is the PDA module's quantize/dequantize of the
boundary activation (everything else — histogram stats and the DS search —
is control-path work that runs only on recalibration).

Both kernels are a single fused elementwise pass:

  quantize  : codes = clamp(round(x / scale + zp), lo, hi)      f32 -> i32
  dequantize: x_hat = (codes - zp) * scale                      i32 -> f32

The affine form with runtime (scale, zp, lo, hi) covers every method in the
paper with ONE compiled executable each:
  * naive PTQ      : zp = -xmin/scale rounded, [lo,hi] = [0, 2^q-1]
  * ACIQ / DS-ACIQ : zp = 0, [lo,hi] = [-2^{q-1}, 2^{q-1}-1], scale = a/2^{q-1}
Bitwidth changes at runtime are therefore *data*, not recompiles — the key
property the adaptive controller needs.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the kernel is
bandwidth-bound elementwise work, so the TPU mapping is a (block_rows, 128)
VMEM tile pipeline over the (tokens*batch, dim) activation; no MXU. Lowered
with interpret=True for CPU-PJRT execution (Mosaic custom-calls cannot run
on the CPU plugin).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128  # TPU lane width; dim=128 activations map 1:1 onto lanes.


def pick_block_rows(rows: int, target: int = 128) -> int:
    """Largest divisor of `rows` that is <= target (so the grid tiles the
    input exactly; hypothesis feeds odd shapes)."""
    best = 1
    for d in range(1, min(rows, target) + 1):
        if rows % d == 0:
            best = d
    return best


def _scalar_spec():
    # Every grid step sees the same (1,) parameter block.
    return pl.BlockSpec((1,), lambda i: (0,))


def _quant_kernel(x_ref, scale_ref, zp_ref, lo_ref, hi_ref, o_ref):
    x = x_ref[...]
    inv = 1.0 / scale_ref[0]
    codes = jnp.round(x * inv + zp_ref[0])
    codes = jnp.clip(codes, lo_ref[0], hi_ref[0])
    o_ref[...] = codes.astype(jnp.int32)


def _dequant_kernel(c_ref, scale_ref, zp_ref, o_ref):
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (c - zp_ref[0]) * scale_ref[0]


def quantize(x, scale, zp, lo, hi, *, block_rows: int | None = None):
    """Pallas quantize over a 2-D activation (rows, cols). scale/zp/lo/hi
    are shape-(1,) f32 arrays (runtime data)."""
    rows, cols = x.shape
    br = block_rows or pick_block_rows(rows)
    grid = (rows // br,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            _scalar_spec(),
            _scalar_spec(),
            _scalar_spec(),
            _scalar_spec(),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        interpret=True,
    )(x, scale, zp, lo, hi)


def dequantize(codes, scale, zp, *, block_rows: int | None = None):
    """Pallas dequantize: i32 codes -> f32 reconstruction."""
    rows, cols = codes.shape
    br = block_rows or pick_block_rows(rows)
    grid = (rows // br,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            _scalar_spec(),
            _scalar_spec(),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(codes, scale, zp)


def quantize_fn_for_export(rows: int, cols: int):
    """Tuple-returning wrapper for AOT lowering (see aot.py)."""

    def fn(x, scale, zp, lo, hi):
        return (quantize(x, scale, zp, lo, hi),)

    return fn


def dequantize_fn_for_export(rows: int, cols: int):
    def fn(codes, scale, zp):
        return (dequantize(codes, scale, zp),)

    return fn


def vmem_bytes(block_rows: int, cols: int) -> int:
    """VMEM footprint estimate for one grid step (in + out tiles + params),
    used by the DESIGN.md §Perf roofline discussion."""
    return block_rows * cols * 4 * 2 + 4 * 4
