"""Pure-jnp/numpy correctness oracle for the quantization stack.

This file is the single source of truth for the paper's §3 math:

  * uniform affine quantize / dequantize (naive PTQ: min/max range)
  * symmetric clipped quantization (used by ACIQ / DS-ACIQ)
  * ACIQ optimal clip  alpha = F(q) * b  for a Laplace(0, b) assumption
    (Banner et al., NeurIPS'19 [16])
  * DS-ACIQ directed search over the scale factor b (the paper's Eq. 1)

Both the Pallas kernels (kernels/quant.py) and the rust-native
implementation (rust/src/quant/) are validated against these functions —
the Pallas kernel via pytest allclose, the rust code via golden vectors
exported by aot.py into artifacts/golden.json.
"""

from __future__ import annotations

import numpy as np

SUPPORTED_BITS = (2, 4, 6, 8, 16)


# ---------------------------------------------------------------------------
# Core uniform quantization
# ---------------------------------------------------------------------------

def quantize(x, scale, zero_point, lo, hi):
    """codes = clamp(round(x/scale + zp), lo, hi). Generic affine form that
    covers both naive-asymmetric (zp != 0) and symmetric-clipped (zp = 0)."""
    x = np.asarray(x, np.float32)
    scale = np.float32(scale)
    codes = np.round(x / scale + np.float32(zero_point))
    return np.clip(codes, lo, hi).astype(np.int32)


def dequantize(codes, scale, zero_point):
    return ((codes.astype(np.float32) - np.float32(zero_point)) * np.float32(scale)).astype(np.float32)


def naive_params(x, q):
    """Naive PTQ: asymmetric affine range from the tensor min/max (§3:
    "determines the quantization range based on the minimum and maximum
    tensor values")."""
    x = np.asarray(x, np.float32)
    xmin, xmax = float(x.min()), float(x.max())
    # Standard min/max PTQ extends the range to include zero so the
    # zero-point is exactly representable (TFLite convention; the rust
    # implementation matches).
    xmin, xmax = min(xmin, 0.0), max(xmax, 0.0)
    if xmax <= xmin:
        xmax = xmin + 1e-8
    n = (1 << q) - 1
    scale = (xmax - xmin) / n
    zero_point = round(-xmin / scale)
    return np.float32(scale), float(np.clip(zero_point, 0, n)), 0.0, float(n)


def symmetric_params(alpha, q):
    """Symmetric clipped quantization over [-alpha, alpha] with signed codes
    in [-(2^{q-1}), 2^{q-1}-1]."""
    lo = -(1 << (q - 1))
    hi = (1 << (q - 1)) - 1
    scale = alpha / (1 << (q - 1))
    return np.float32(max(scale, 1e-12)), 0.0, float(lo), float(hi)


def quant_roundtrip(x, scale, zp, lo, hi):
    return dequantize(quantize(x, scale, zp, lo, hi), scale, zp)


def mse(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.mean((a - b) ** 2))


# ---------------------------------------------------------------------------
# ACIQ (Laplace)  —  alpha* = F(q) * b
# ---------------------------------------------------------------------------

def aciq_mse_laplace(alpha_over_b, q):
    """Banner et al.'s analytic quantization MSE for X ~ Laplace(0, b),
    normalized by b^2:  2 e^{-a/b} + (a/b)^2 / (3 * 4^q)  (clip term +
    rounding term). The minimizer over alpha/b depends only on q."""
    r = alpha_over_b
    return 2.0 * np.exp(-r) + (r * r) / (3.0 * (4.0**q))


def aciq_ratio(q, iters=200):
    """F(q): solve d/dr [2 e^{-r} + r^2/(3*4^q)] = 0 by Newton on
    g(r) = -2 e^{-r} + 2 r / (3*4^q).  Known values: F(2)≈2.83, F(3)≈3.89,
    F(4)≈5.03 (the constants quoted in [16])."""
    c = 2.0 / (3.0 * (4.0**q))
    r = 2.0 + q  # good initial guess; solution grows ~ linearly in q
    for _ in range(iters):
        g = -2.0 * np.exp(-r) + c * r
        dg = 2.0 * np.exp(-r) + c
        step = g / dg
        r -= step
        if abs(step) < 1e-12:
            break
    return float(r)


ACIQ_RATIOS = {q: aciq_ratio(q) for q in SUPPORTED_BITS}


def laplace_b(x):
    """ACIQ's scale estimate  b_E = sum_i |x_i| / N  (paper §3)."""
    return float(np.mean(np.abs(np.asarray(x, np.float64))))


def aciq_alpha(x, q):
    return ACIQ_RATIOS[q] * laplace_b(x)


def quantize_naive(x, q):
    s, zp, lo, hi = naive_params(x, q)
    return quant_roundtrip(x, s, zp, lo, hi)


def quantize_aciq(x, q):
    s, zp, lo, hi = symmetric_params(aciq_alpha(x, q), q)
    return quant_roundtrip(x, s, zp, lo, hi)


# ---------------------------------------------------------------------------
# DS-ACIQ directed search (paper Eq. 1)
# ---------------------------------------------------------------------------

def histogram(x, bins=2048):
    """|x| histogram used by the directed search: (counts, bin_centers,
    bin_width). Max-|x| range."""
    ax = np.abs(np.asarray(x, np.float64)).ravel()
    top = float(ax.max())
    if top <= 0:
        top = 1e-12
    counts, edges = np.histogram(ax, bins=bins, range=(0.0, top))
    centers = 0.5 * (edges[:-1] + edges[1:])
    return counts.astype(np.float64), centers, edges[1] - edges[0]


def density(counts, width):
    """Real per-unit-x density D_R from the |x| histogram (÷2 unfolds the
    |x| fold back onto the signed axis, assuming symmetry)."""
    n = counts.sum()
    return counts / max(n * width, 1e-300) / 2.0


def laplace_density(centers, b):
    """Estimated density D_E = Laplace(0, b) evaluated at the (positive)
    bin centers."""
    return np.exp(-centers / b) / (2.0 * b)


def density_fit_mse(counts, centers, width, b):
    """The paper's Eq. 1 objective: MSE(D_R, D_E) between the real density
    histogram and the Laplace(0, b) estimate, over the histogram support."""
    d_r = density(counts, width)
    d_e = laplace_density(centers, b)
    return float(np.mean((d_r - d_e) ** 2))


def hist_quant_mse(counts, centers, alpha, q):
    """Quantization reconstruction MSE at clip `alpha`, evaluated on the
    |x| histogram (the quantizer is odd, so folding is exact). Used by the
    acceptance guard."""
    s, zp, lo, hi = symmetric_params(alpha, q)
    xq = quant_roundtrip(centers.astype(np.float32), s, zp, lo, hi)
    err = (centers - xq.astype(np.float64)) ** 2
    n = counts.sum()
    return float((counts * err).sum() / max(n, 1))


def ds_aciq_b(x, q, t=100, bins=2048):
    """Directed search for b* (Eq. 1): argmin_{b in [b_E, b_R]} MSE(D_R, D_E).

    Direction: compare the real density peak max(D_R) with the estimated
    Laplace peak max(D_E) = 1/(2 b_E). If max(D_R) < max(D_E) the real
    distribution is broader than the estimate -> search increasing b;
    vice versa (the heavy-tailed ViT case: the real bulk is MORE peaked
    than the moment estimate suggests, so b* < b_E and the resulting clip
    alpha = F(q) b* is tighter, rescuing small-bitwidth accuracy).
    Boundary: b_R = [2 max(D_R)]^{-1}, the Laplace scale whose peak equals
    the real peak.

    Falls back to b_E when no candidate improves the fit ("it either finds
    the parameter b* that gives a lower MSE or otherwise use the b_E").
    """
    x = np.asarray(x, np.float32)
    b_e = laplace_b(x)
    counts, centers, width = histogram(x, bins=bins)
    peak_r = float(density(counts, width).max())
    b_r = 1.0 / (2.0 * max(peak_r, 1e-300))

    best_b = b_e
    best_mse = density_fit_mse(counts, centers, width, b_e)
    for i in range(1, t + 1):
        b = b_e + (b_r - b_e) * (i / t)
        m = density_fit_mse(counts, centers, width, b)
        if m < best_mse:
            best_b, best_mse = b, m
    return float(best_b), float(best_mse)


def quantize_ds_aciq(x, q, t=100):
    b_star, _ = ds_aciq_b(x, q, t=t)
    s, zp, lo, hi = symmetric_params(ACIQ_RATIOS[q] * b_star, q)
    return quant_roundtrip(x, s, zp, lo, hi)


def quantize_pda(x, q, t=100):
    """PDA = PTQ with DS-ACIQ, activated only at 2/4-bit (paper §3: "the
    DS-ACIQ approach is only activated under 4- and 2-bit quantization")."""
    if q in (2, 4):
        return quantize_ds_aciq(x, q, t=t)
    return quantize_aciq(x, q)
