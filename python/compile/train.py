"""Build-time trainer for the ViT-Tiny-synthetic model.

Hand-rolled Adam (optax is not available in the image). Runs once inside
`make artifacts`; weights are cached in artifacts/weights.npz keyed by a
config hash, so repeat builds are no-ops. Training takes ~30-60 s on CPU.

The trained loss curve is logged to artifacts/train_log.csv and summarised
in EXPERIMENTS.md — this is the "real small workload" of the end-to-end
validation requirement (serving papers load a small *real* model; ours is
real in the sense that it is trained to >90% top-1 on its task, not
random-initialised).
"""

from __future__ import annotations

import hashlib
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import ViTConfig, accuracy, init_params, loss_fn, param_count


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in grads}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in grads}
    tf = t.astype(jnp.float32)
    new_p = {}
    for k in params:
        mhat = m[k] / (1 - b1**tf)
        vhat = v[k] / (1 - b2**tf)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, {"m": m, "v": v, "t": t}


def config_hash(cfg: ViTConfig, steps: int, seed: int) -> str:
    blob = json.dumps({"cfg": dataclass_dict(cfg), "steps": steps, "seed": seed}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def dataclass_dict(cfg: ViTConfig) -> dict:
    return {f: getattr(cfg, f) for f in cfg.__dataclass_fields__}


def train(
    cfg: ViTConfig,
    steps: int = 600,
    batch: int = 128,
    lr: float = 1e-3,
    seed: int = 0,
    log_path: Path | None = None,
    verbose: bool = True,
) -> dict:
    """Train from scratch; returns the trained params dict."""
    params = init_params(cfg, seed=seed)
    if verbose:
        print(f"[train] ViT {param_count(params) / 1e6:.2f}M params, {steps} steps")
    opt = adam_init(params)
    protos = data.make_prototypes()
    rng = np.random.default_rng(seed + 1)

    @jax.jit
    def step(p, o, imgs, labels):
        loss, grads = jax.value_and_grad(lambda q: loss_fn(cfg, q, imgs, labels))(p)
        p, o = adam_update(p, grads, o, lr)
        return p, o, loss

    log_rows = []
    t0 = time.time()
    for i in range(steps):
        imgs, labels = data.sample_batch(rng, protos, batch)
        params, opt, loss = step(params, opt, jnp.asarray(imgs), jnp.asarray(labels))
        if i % 25 == 0 or i == steps - 1:
            l = float(loss)
            log_rows.append((i, l, time.time() - t0))
            if verbose:
                print(f"[train] step {i:4d} loss {l:.4f} ({time.time() - t0:.1f}s)")

    # Held-out evaluation.
    ev_imgs, ev_labels = data.make_split(seed=777, n=1024)
    acc = float(accuracy(cfg, params, jnp.asarray(ev_imgs), jnp.asarray(ev_labels)))
    if verbose:
        print(f"[train] held-out top-1 = {acc * 100:.2f}%")
    if log_path is not None:
        with open(log_path, "w") as f:
            f.write("step,loss,seconds\n")
            for r in log_rows:
                f.write(f"{r[0]},{r[1]:.6f},{r[2]:.2f}\n")
            f.write(f"# held-out top-1 = {acc * 100:.2f}%\n")
    return params


def load_or_train(cfg: ViTConfig, artifacts: Path, steps: int = 600, seed: int = 0) -> dict:
    """Cache-aware entrypoint used by aot.py."""
    artifacts.mkdir(parents=True, exist_ok=True)
    h = config_hash(cfg, steps, seed)
    cache = artifacts / "weights.npz"
    meta = artifacts / "weights.meta.json"
    if cache.exists() and meta.exists():
        try:
            if json.loads(meta.read_text())["hash"] == h:
                print(f"[train] cache hit ({cache})")
                loaded = np.load(cache)
                return {k: jnp.asarray(loaded[k]) for k in loaded.files}
        except Exception:
            pass
    params = train(cfg, steps=steps, seed=seed, log_path=artifacts / "train_log.csv")
    np.savez(cache, **{k: np.asarray(v) for k, v in params.items()})
    meta.write_text(json.dumps({"hash": h}))
    return params
