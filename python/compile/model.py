"""L2: ViT-Tiny-synthetic in pure JAX, partitioned into pipeline shards.

The model mirrors the layer-wise concatenated structure the paper exploits
(§2: ViT "has a layer-wise concatenated structure without inter-layer
connections, making it suitable to be partitioned by the layer boundaries").

Shards:
  stage 0   : patch embed (+pos embed) + blocks[0 .. c0)
  stage i   : blocks[c_{i-1} .. c_i)
  stage n-1 : blocks[.. L) + final LayerNorm + mean-pool + linear head

Every inter-stage boundary activation has the same shape (B, T, D), which is
what QuantPipe quantizes on the wire. Weights are baked into each shard's
HLO as constants at AOT time — the rust runtime feeds activations only.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    img: tuple[int, int, int] = (32, 32, 3)
    patch: int = 8
    dim: int = 128
    depth: int = 8
    heads: int = 4
    mlp_ratio: int = 2
    classes: int = 100

    @property
    def tokens(self) -> int:
        return (self.img[0] // self.patch) * (self.img[1] // self.patch)

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.img[2]

    @property
    def mlp_dim(self) -> int:
        return self.dim * self.mlp_ratio


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ViTConfig, seed: int = 0) -> dict:
    """Initialise all weights as a flat dict of jnp arrays."""
    rng = np.random.default_rng(seed)

    def dense(fan_in, fan_out):
        w = rng.normal(0.0, (2.0 / (fan_in + fan_out)) ** 0.5, (fan_in, fan_out))
        return w.astype(np.float32), np.zeros(fan_out, np.float32)

    p: dict[str, np.ndarray] = {}
    p["embed.w"], p["embed.b"] = dense(cfg.patch_dim, cfg.dim)
    p["pos"] = (rng.normal(0, 0.02, (cfg.tokens, cfg.dim))).astype(np.float32)
    for i in range(cfg.depth):
        pre = f"block{i}."
        p[pre + "ln1.g"] = np.ones(cfg.dim, np.float32)
        p[pre + "ln1.b"] = np.zeros(cfg.dim, np.float32)
        p[pre + "qkv.w"], p[pre + "qkv.b"] = dense(cfg.dim, 3 * cfg.dim)
        p[pre + "proj.w"], p[pre + "proj.b"] = dense(cfg.dim, cfg.dim)
        p[pre + "ln2.g"] = np.ones(cfg.dim, np.float32)
        p[pre + "ln2.b"] = np.zeros(cfg.dim, np.float32)
        p[pre + "fc1.w"], p[pre + "fc1.b"] = dense(cfg.dim, cfg.mlp_dim)
        p[pre + "fc2.w"], p[pre + "fc2.b"] = dense(cfg.mlp_dim, cfg.dim)
    p["ln_f.g"] = np.ones(cfg.dim, np.float32)
    p["ln_f.b"] = np.zeros(cfg.dim, np.float32)
    p["head.w"], p["head.b"] = dense(cfg.dim, cfg.classes)
    return {k: jnp.asarray(v) for k, v in p.items()}


def param_count(params: dict) -> int:
    return sum(int(np.prod(v.shape)) for v in params.values())


# ---------------------------------------------------------------------------
# Forward pieces
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def patchify(cfg: ViTConfig, imgs):
    """(B,H,W,C) -> (B,T,patch_dim)."""
    B = imgs.shape[0]
    ph = pw = cfg.patch
    gh, gw = cfg.img[0] // ph, cfg.img[1] // pw
    x = imgs.reshape(B, gh, ph, gw, pw, cfg.img[2])
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, gh * gw, ph * pw * cfg.img[2])


def embed(cfg: ViTConfig, p: dict, imgs):
    x = patchify(cfg, imgs)
    x = x @ p["embed.w"] + p["embed.b"]
    return x + p["pos"]


def attention(cfg: ViTConfig, p: dict, pre: str, x):
    B, T, D = x.shape
    h, hd = cfg.heads, cfg.dim // cfg.heads
    qkv = x @ p[pre + "qkv.w"] + p[pre + "qkv.b"]
    qkv = qkv.reshape(B, T, 3, h, hd).transpose(2, 0, 3, 1, 4)
    q, k, v = qkv[0], qkv[1], qkv[2]
    att = (q @ k.transpose(0, 1, 3, 2)) / (hd**0.5)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, D)
    return out @ p[pre + "proj.w"] + p[pre + "proj.b"]


def block(cfg: ViTConfig, p: dict, i: int, x):
    pre = f"block{i}."
    x = x + attention(cfg, p, pre, layer_norm(x, p[pre + "ln1.g"], p[pre + "ln1.b"]))
    h = layer_norm(x, p[pre + "ln2.g"], p[pre + "ln2.b"])
    h = jax.nn.gelu(h @ p[pre + "fc1.w"] + p[pre + "fc1.b"])
    return x + h @ p[pre + "fc2.w"] + p[pre + "fc2.b"]


def head(cfg: ViTConfig, p: dict, x):
    x = layer_norm(x, p["ln_f.g"], p["ln_f.b"])
    return x.mean(axis=1) @ p["head.w"] + p["head.b"]


def forward(cfg: ViTConfig, p: dict, imgs):
    """Full model: images -> logits (B, classes)."""
    x = embed(cfg, p, imgs)
    for i in range(cfg.depth):
        x = block(cfg, p, i, x)
    return head(cfg, p, x)


# ---------------------------------------------------------------------------
# Pipeline partitioning
# ---------------------------------------------------------------------------

def stage_cuts(depth: int, n_stages: int) -> list[tuple[int, int]]:
    """Evenly partition `depth` blocks into `n_stages` contiguous ranges
    (the paper partitions evenly via [15]'s algorithm; the rust side also
    implements the cost-aware DP in partition/)."""
    assert 1 <= n_stages <= depth
    base, rem = divmod(depth, n_stages)
    cuts, lo = [], 0
    for s in range(n_stages):
        hi = lo + base + (1 if s < rem else 0)
        cuts.append((lo, hi))
        lo = hi
    return cuts


def stage_fn(cfg: ViTConfig, p: dict, lo: int, hi: int, first: bool, last: bool):
    """Build the callable for one shard; weights are captured (baked as HLO
    constants at lowering time)."""

    def fn(x):
        if first:
            x = embed(cfg, p, x)
        for i in range(lo, hi):
            x = block(cfg, p, i, x)
        if last:
            x = head(cfg, p, x)
        return (x,)

    return fn


def forward_staged(cfg: ViTConfig, p: dict, imgs, n_stages: int):
    """Reference: run the partitioned model stage by stage (used in tests to
    prove partitioning is exact)."""
    cuts = stage_cuts(cfg.depth, n_stages)
    x = imgs
    for s, (lo, hi) in enumerate(cuts):
        fn = stage_fn(cfg, p, lo, hi, first=(s == 0), last=(s == len(cuts) - 1))
        (x,) = fn(x)
    return x


def boundary_activations(cfg: ViTConfig, p: dict, imgs, n_stages: int):
    """Activations at each inter-stage boundary (n_stages-1 tensors of shape
    (B, T, D)). Used by aot.py to export calibration tensors and by the
    Fig 3/4 analyses."""
    cuts = stage_cuts(cfg.depth, n_stages)
    x, outs = imgs, []
    for s, (lo, hi) in enumerate(cuts):
        fn = stage_fn(cfg, p, lo, hi, first=(s == 0), last=(s == len(cuts) - 1))
        (x,) = fn(x)
        if s != len(cuts) - 1:
            outs.append(x)
    return outs


# ---------------------------------------------------------------------------
# Loss / accuracy (used by train.py)
# ---------------------------------------------------------------------------

def loss_fn(cfg: ViTConfig, p: dict, imgs, labels):
    logits = forward(cfg, p, imgs)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def accuracy(cfg: ViTConfig, p: dict, imgs, labels):
    logits = forward(cfg, p, imgs)
    return (jnp.argmax(logits, -1) == labels).mean()
