//! A lightweight source model for the self-hosted lints.
//!
//! [`SourceFile::parse`] runs a character state machine over a `.rs` file
//! and splits every line into *code* (with string/char-literal contents
//! masked out) and *comment* text. The lints in [`crate::analysis::lints`]
//! then do plain substring matching on the code part without tripping over
//! tokens that only appear inside strings, and read the comment part for
//! `// lint: allow(...)` and `// SAFETY:` annotations.
//!
//! This is deliberately **not** a Rust parser: it understands exactly the
//! constructs that would otherwise produce false positives — string
//! literals (incl. raw and byte strings), char literals vs. lifetimes,
//! line comments, and nested block comments — and nothing more.

use std::path::{Path, PathBuf};

/// One source line after masking.
#[derive(Debug, Clone)]
pub struct Line {
    /// Code text with string/char contents replaced by spaces and all
    /// comment text removed. Safe for substring matching.
    pub code: String,
    /// Concatenated comment text appearing on this line (line comments,
    /// doc comments and block-comment fragments alike).
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]` region (or the
    /// whole file is a test/bench/example target).
    pub in_test: bool,
}

impl Line {
    /// True when the line carries no code at all (blank or comment-only).
    pub fn is_comment_only(&self) -> bool {
        self.code.trim().is_empty() && !self.comment.trim().is_empty()
    }
}

/// A parsed source file: path plus masked lines.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Path as given to [`SourceFile::parse`] (display-friendly, usually
    /// relative to the crate root).
    pub path: PathBuf,
    /// Masked lines, 0-indexed (line numbers in findings are 1-based).
    pub lines: Vec<Line>,
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    LineComment,
    /// Nested block comments: Rust block comments nest, so track depth.
    BlockComment(u32),
    Str,
    /// Raw string with this many `#` marks, e.g. `r#"…"#` has 1.
    RawStr(u32),
    CharLit,
}

impl SourceFile {
    /// Parse `text` into masked lines. `whole_file_is_test` marks every
    /// line as test code (used for `tests/`, `benches/` and `examples/`
    /// targets, where unwraps are idiomatic).
    pub fn parse(path: impl Into<PathBuf>, text: &str, whole_file_is_test: bool) -> SourceFile {
        let mut lines = Vec::new();
        let mut code = String::new();
        let mut comment = String::new();
        let mut mode = Mode::Code;
        let chars: Vec<char> = text.chars().collect();
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            if c == '\n' {
                // A line comment ends at the newline; everything else
                // (block comments, string literals) continues across it.
                if mode == Mode::LineComment {
                    mode = Mode::Code;
                }
                lines.push(Line {
                    code: std::mem::take(&mut code),
                    comment: std::mem::take(&mut comment),
                    in_test: whole_file_is_test,
                });
                i += 1;
                continue;
            }
            match mode {
                Mode::Code => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        mode = Mode::LineComment;
                        i += 2;
                        continue;
                    }
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::BlockComment(1);
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                        continue;
                    }
                    if c == '"' {
                        // Raw string? Look back over `#`s to an `r` (with
                        // optional `b` byte prefix) directly adjacent.
                        let mut j = i;
                        let mut hashes = 0u32;
                        while j > 0 && chars[j - 1] == '#' {
                            hashes += 1;
                            j -= 1;
                        }
                        let has_r = j > 0 && chars[j - 1] == 'r';
                        let standalone =
                            j < 2 || !is_ident_char(chars[j - 2]) || chars[j - 2] == 'b';
                        mode = if has_r && standalone {
                            Mode::RawStr(hashes)
                        } else {
                            Mode::Str
                        };
                        code.push('"');
                        i += 1;
                        continue;
                    }
                    if c == '\'' {
                        // Char literal vs lifetime: a char literal closes
                        // within two characters (one char, or an escape);
                        // a lifetime is `'` + identifier with no closing
                        // quote. `'a'` is a literal, `'a` is a lifetime.
                        let is_char_lit = match chars.get(i + 1) {
                            Some(&'\\') => true,
                            Some(&n) => chars.get(i + 2) == Some(&'\'') && n != '\'',
                            None => false,
                        };
                        if is_char_lit {
                            mode = Mode::CharLit;
                        }
                        code.push('\'');
                        i += 1;
                        continue;
                    }
                    code.push(c);
                    i += 1;
                }
                Mode::LineComment => {
                    comment.push(c);
                    i += 1;
                }
                Mode::BlockComment(depth) => {
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::BlockComment(depth + 1);
                        i += 2;
                        continue;
                    }
                    if c == '*' && chars.get(i + 1) == Some(&'/') {
                        mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                        i += 2;
                        continue;
                    }
                    comment.push(c);
                    i += 1;
                }
                Mode::Str => {
                    if c == '\\' {
                        code.push(' ');
                        if chars.get(i + 1) == Some(&'\n') {
                            // `\` line continuation: keep the newline so
                            // the top of the loop still breaks the line.
                            i += 1;
                            continue;
                        }
                        if chars.get(i + 1).is_some() {
                            code.push(' ');
                        }
                        i += 2;
                        continue;
                    }
                    if c == '"' {
                        mode = Mode::Code;
                        code.push('"');
                    } else {
                        code.push(' ');
                    }
                    i += 1;
                }
                Mode::RawStr(hashes) => {
                    if c == '"' {
                        let mut ok = true;
                        for k in 0..hashes as usize {
                            if chars.get(i + 1 + k) != Some(&'#') {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            mode = Mode::Code;
                            code.push('"');
                            for _ in 0..hashes {
                                code.push('#');
                            }
                            i += 1 + hashes as usize;
                            continue;
                        }
                    }
                    code.push(' ');
                    i += 1;
                }
                Mode::CharLit => {
                    if c == '\\' {
                        code.push(' ');
                        if chars.get(i + 1).is_some() {
                            code.push(' ');
                        }
                        i += 2;
                        continue;
                    }
                    if c == '\'' {
                        mode = Mode::Code;
                        code.push('\'');
                    } else {
                        code.push(' ');
                    }
                    i += 1;
                }
            }
        }
        if !code.is_empty() || !comment.is_empty() {
            lines.push(Line { code, comment, in_test: whole_file_is_test });
        }
        let mut file = SourceFile { path: path.into(), lines };
        if !whole_file_is_test {
            file.mark_test_regions();
        }
        file
    }

    /// Mark lines inside `#[cfg(test)]`-attributed items as test code by
    /// brace-counting from the item's opening `{` to its matching close.
    fn mark_test_regions(&mut self) {
        let mut i = 0usize;
        while i < self.lines.len() {
            if !self.lines[i].code.contains("#[cfg(test)]") {
                i += 1;
                continue;
            }
            // From the attribute line, scan forward to the item's first
            // `{`, then run the brace counter until it closes.
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut j = i;
            while j < self.lines.len() {
                self.lines[j].in_test = true;
                for c in self.lines[j].code.chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        // An un-braced item (`#[cfg(test)] mod t;`) ends
                        // at the first `;` before any brace opens.
                        ';' if !opened => {
                            depth = 0;
                            opened = true;
                        }
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        }
    }

    /// Path rendered with `/` separators for rule matching and reporting.
    pub fn rel(&self) -> String {
        self.path
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/")
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Walk the crate's own sources: `src/` (library code), plus `tests/`,
/// `benches/` and the repo-level `examples/` (all treated as test code).
/// The vendored shim crates under `vendor/` are skipped — they are
/// stand-ins for external deps, not part of the codebase under lint.
pub fn crate_sources(manifest_dir: &Path) -> std::io::Result<Vec<SourceFile>> {
    let mut out = Vec::new();
    let roots: [(&str, bool); 4] =
        [("src", false), ("tests", true), ("benches", true), ("../examples", true)];
    for (root, is_test) in roots {
        let dir = manifest_dir.join(root);
        if dir.is_dir() {
            walk(&dir, &dir, root.trim_start_matches("../"), is_test, &mut out)?;
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(out)
}

fn walk(
    top: &Path,
    dir: &Path,
    label: &str,
    is_test: bool,
    out: &mut Vec<SourceFile>,
) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "vendor" || name == "target" {
                continue;
            }
            walk(top, &path, label, is_test, out)?;
        } else if name.ends_with(".rs") {
            let text = std::fs::read_to_string(&path)?;
            let rel = path.strip_prefix(top).unwrap_or(&path);
            let display = Path::new(label).join(rel);
            out.push(SourceFile::parse(display, &text, is_test));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> SourceFile {
        SourceFile::parse("test.rs", text, false)
    }

    #[test]
    fn masks_string_contents() {
        let f = parse("let x = \"call .unwrap() here\";\n");
        assert!(!f.lines[0].code.contains(".unwrap()"));
        assert!(f.lines[0].code.contains("let x = \""));
    }

    #[test]
    fn strips_line_comments_into_comment_field() {
        let f = parse("foo(); // lint: allow(unwrap): reason\n");
        assert!(f.lines[0].code.contains("foo();"));
        assert!(!f.lines[0].code.contains("lint:"));
        assert!(f.lines[0].comment.contains("lint: allow(unwrap)"));
    }

    #[test]
    fn nested_block_comments() {
        let f = parse("a /* one /* two */ still */ b\n");
        assert!(f.lines[0].code.contains('a'));
        assert!(f.lines[0].code.contains('b'));
        assert!(!f.lines[0].code.contains("one"));
        assert!(f.lines[0].comment.contains("two"));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let f = parse("let s = r#\"has \".unwrap()\" inside\"#;\nnext();\n");
        assert!(!f.lines[0].code.contains(".unwrap()"));
        assert!(f.lines[1].code.contains("next();"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let f = parse("fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'x';\nlet n = '\\n';\n");
        assert!(f.lines[0].code.contains("str { x }"), "lifetime must not open a literal");
        assert!(!f.lines[1].code.contains('x'), "char literal contents masked");
        assert!(f.lines[2].code.contains('\''), "escaped char literal closes");
    }

    #[test]
    fn multi_line_strings_stay_masked() {
        let f = parse("let s = \"first\nsecond .unwrap()\nthird\";\ncode();\n");
        assert!(!f.lines[1].code.contains(".unwrap()"));
        assert!(f.lines[3].code.contains("code();"));
    }

    #[test]
    fn backslash_newline_continuation_keeps_line_count() {
        let f = parse("let s = \"one \\\n two\";\nafter();\n");
        assert_eq!(f.lines.len(), 3, "continuation must not swallow the newline");
        assert!(f.lines[2].code.contains("after();"));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let text = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = parse(text);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test, "region ends at the matching brace");
    }

    #[test]
    fn whole_file_test_flag() {
        let f = SourceFile::parse("tests/x.rs", "fn a() {}\n", true);
        assert!(f.lines[0].in_test);
    }

    #[test]
    fn crate_sources_walks_this_crate() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = crate_sources(dir).unwrap();
        assert!(
            files.iter().any(|f| f.rel() == "src/analysis/source.rs"),
            "walker must find this very file"
        );
        assert!(
            files.iter().all(|f| !f.rel().contains("vendor/")),
            "vendored shims are not linted"
        );
    }
}
