//! Self-hosted lint rules over the crate's own sources.
//!
//! The rules encode invariants this codebase has committed to:
//!
//! * **unwrap** — no bare `.unwrap()` / `.expect(` in non-test `net/` and
//!   `pipeline/` code. Worker threads there must surface failures through
//!   the error channels, not abort the process mid-run.
//! * **lock** — all mutex acquisition goes through [`crate::util::sync`]
//!   (`TrackedMutex::guard` or the poison-tolerant `lock` helper), so the
//!   lock-order detector sees every acquisition. Bare `.lock(` calls are
//!   banned everywhere except `util/sync.rs` itself.
//! * **socket-free-session** — `net/session.rs` is the pure protocol
//!   state machine; it must stay free of `std::net` so it remains usable
//!   from the deterministic interleaving checker and from Miri.
//! * **safety-comment** — every `unsafe` carries a `// SAFETY:` comment
//!   explaining why it is sound.
//! * **thread-spawn** — no `thread::spawn` in non-test `net/` code
//!   outside `net/reactor.rs`. Since the reactor refactor the transport
//!   layer owns no threads: all socket reads happen on the one reactor
//!   thread, and a stray per-conduit thread would silently reintroduce
//!   the blocking-sweep architecture.
//!
//! A violation is silenced by an adjacent comment of the form
//! `// lint: allow(<rule>): <reason>` — on the same line, or in the
//! contiguous comment block directly above. The reason is mandatory: the
//! annotation is the reviewer-facing proof obligation.
//!
//! The whole pass runs as an ordinary `cargo test`
//! (`tests/static_analysis.rs`), so CI enforces it with no extra tooling.

use crate::analysis::source::SourceFile;
use std::fmt;

/// A single lint violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// File path relative to the crate root (slash-separated).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule identifier (`unwrap`, `lock`, `socket-free-session`,
    /// `safety-comment`, `thread-spawn`, `wire-spec`).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// True when line `idx` of `file` is covered by a
/// `lint: allow(<rule>)` annotation: on the line itself, or in the
/// contiguous run of comment-only lines directly above it.
fn allowed(file: &SourceFile, idx: usize, rule: &str) -> bool {
    let marker = format!("lint: allow({rule})");
    if file.lines[idx].comment.contains(&marker) {
        return true;
    }
    let mut j = idx;
    while j > 0 && file.lines[j - 1].is_comment_only() {
        j -= 1;
        if file.lines[j].comment.contains(&marker) {
            return true;
        }
    }
    false
}

/// R1: bare `.unwrap()` / `.expect(` in non-test `net/`/`pipeline/` code.
pub fn check_unwrap(file: &SourceFile, out: &mut Vec<Finding>) {
    let rel = file.rel();
    if !(rel.starts_with("src/net/") || rel.starts_with("src/pipeline/")) {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for (pat, rule) in [(".unwrap()", "unwrap"), (".expect(", "expect")] {
            if line.code.contains(pat) && !allowed(file, idx, rule) {
                out.push(Finding {
                    file: rel.clone(),
                    line: idx + 1,
                    rule: "unwrap",
                    message: format!(
                        "bare `{pat}..` in pipeline/net code; return an error or add \
                         `// lint: allow({rule}): <why it cannot fail>`"
                    ),
                });
            }
        }
    }
}

/// R2: bare `.lock(` outside `util/sync.rs`.
pub fn check_lock(file: &SourceFile, out: &mut Vec<Finding>) {
    let rel = file.rel();
    if rel.ends_with("util/sync.rs") {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.code.contains(".lock(") && !allowed(file, idx, "lock") {
            out.push(Finding {
                file: rel.clone(),
                line: idx + 1,
                rule: "lock",
                message: "bare `.lock()`; use `util::sync::TrackedMutex::guard` so the \
                          lock-order detector sees the acquisition"
                    .into(),
            });
        }
    }
}

/// R3: `net/session.rs` must stay socket-free.
pub fn check_session_socket_free(file: &SourceFile, out: &mut Vec<Finding>) {
    let rel = file.rel();
    if !rel.ends_with("net/session.rs") {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        for pat in ["std::net", "TcpStream", "TcpListener", "UdpSocket"] {
            if line.code.contains(pat) && !allowed(file, idx, "socket-free-session") {
                out.push(Finding {
                    file: rel.clone(),
                    line: idx + 1,
                    rule: "socket-free-session",
                    message: format!(
                        "`{pat}` in the session state machine; session.rs must stay \
                         I/O-free (sockets live in conduit.rs/stripe.rs)"
                    ),
                });
            }
        }
    }
}

/// R4: every `unsafe` needs an adjacent `// SAFETY:` comment.
pub fn check_safety_comments(file: &SourceFile, out: &mut Vec<Finding>) {
    let rel = file.rel();
    for (idx, line) in file.lines.iter().enumerate() {
        if !has_word(&line.code, "unsafe") {
            continue;
        }
        let mut covered = line.comment.contains("SAFETY:");
        let mut j = idx;
        while !covered && j > 0 && file.lines[j - 1].is_comment_only() {
            j -= 1;
            covered = file.lines[j].comment.contains("SAFETY:");
        }
        if !covered && !allowed(file, idx, "safety-comment") {
            out.push(Finding {
                file: rel.clone(),
                line: idx + 1,
                rule: "safety-comment",
                message: "`unsafe` without an adjacent `// SAFETY:` comment".into(),
            });
        }
    }
}

/// R6: no `thread::spawn` in non-test `net/` code outside the reactor.
/// The reactor owns every read loop; a per-conduit thread anywhere else
/// in the transport layer reintroduces exactly the architecture the
/// reactor replaced.
pub fn check_thread_spawn(file: &SourceFile, out: &mut Vec<Finding>) {
    let rel = file.rel();
    if !rel.starts_with("src/net/") || rel.ends_with("net/reactor.rs") {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        if line.code.contains("thread::spawn") && !allowed(file, idx, "thread-spawn") {
            out.push(Finding {
                file: rel.clone(),
                line: idx + 1,
                rule: "thread-spawn",
                message: "`thread::spawn` in transport code; socket reads belong to the \
                          reactor (net/reactor.rs) — add `// lint: allow(thread-spawn): \
                          <why this thread is not a reader loop>` if it truly is not one"
                    .into(),
            });
        }
    }
}

/// True when `word` occurs in `code` delimited by non-identifier chars.
fn has_word(code: &str, word: &str) -> bool {
    let ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = !code[..at].chars().next_back().is_some_and(ident);
        let after_ok = !code[at + word.len()..].chars().next().is_some_and(ident);
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

/// Run every rule over `files`, returning all findings sorted by
/// (file, line).
pub fn run_all(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in files {
        check_unwrap(file, &mut out);
        check_lock(file, &mut out);
        check_session_socket_free(file, &mut out);
        check_safety_comments(file, &mut out);
        check_thread_spawn(file, &mut out);
    }
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::source::SourceFile;

    fn net_file(text: &str) -> SourceFile {
        SourceFile::parse("src/net/x.rs", text, false)
    }

    #[test]
    fn unwrap_in_net_code_is_flagged() {
        let f = net_file("fn f() { a.unwrap(); }\n");
        let mut out = Vec::new();
        check_unwrap(&f, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "unwrap");
        assert_eq!(out[0].line, 1);
    }

    #[test]
    fn expect_is_flagged_but_expect_err_is_not() {
        let f = net_file("fn f() { a.expect(\"x\"); b.expect_err(\"y\"); c.unwrap_or(0); }\n");
        let mut out = Vec::new();
        check_unwrap(&f, &mut out);
        assert_eq!(out.len(), 1, "only bare .expect( counts: {out:?}");
    }

    #[test]
    fn allow_annotation_on_same_line_silences() {
        let f = net_file("a.unwrap(); // lint: allow(unwrap): infallible here\n");
        let mut out = Vec::new();
        check_unwrap(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn allow_annotation_in_comment_block_above_silences() {
        let f = net_file(
            "// lint: allow(unwrap): the slice is a fixed-size array, so\n\
             // the conversion is infallible.\n\
             a.unwrap();\n",
        );
        let mut out = Vec::new();
        check_unwrap(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn allow_annotation_does_not_leak_past_code() {
        let f = net_file(
            "// lint: allow(unwrap): covers only the next line\na.unwrap();\nb.unwrap();\n",
        );
        let mut out = Vec::new();
        check_unwrap(&f, &mut out);
        assert_eq!(out.len(), 1, "second unwrap is not covered: {out:?}");
        assert_eq!(out[0].line, 3);
    }

    #[test]
    fn unwrap_in_tests_and_strings_is_fine() {
        let f = net_file("#[cfg(test)]\nmod tests {\n    fn t() { a.unwrap(); }\n}\n");
        let mut out = Vec::new();
        check_unwrap(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        let f = net_file("let s = \"please don't .unwrap()\";\n");
        let mut out = Vec::new();
        check_unwrap(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unwrap_outside_net_pipeline_is_fine() {
        let f = SourceFile::parse("src/quant/x.rs", "fn f() { a.unwrap(); }\n", false);
        let mut out = Vec::new();
        check_unwrap(&f, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn bare_lock_is_flagged_everywhere_but_sync() {
        let f = SourceFile::parse("src/metrics/mod.rs", "m.lock().unwrap();\n", false);
        let mut out = Vec::new();
        check_lock(&f, &mut out);
        assert_eq!(out.len(), 1);
        let f = SourceFile::parse("src/util/sync.rs", "m.lock().unwrap();\n", false);
        let mut out = Vec::new();
        check_lock(&f, &mut out);
        assert!(out.is_empty(), "sync.rs is the one place allowed to touch Mutex::lock");
    }

    #[test]
    fn session_socket_rule() {
        let f = SourceFile::parse("src/net/session.rs", "use std::net::TcpStream;\n", false);
        let mut out = Vec::new();
        check_session_socket_free(&f, &mut out);
        assert!(!out.is_empty());
        let f = SourceFile::parse("src/net/conduit.rs", "use std::net::TcpStream;\n", false);
        let mut out = Vec::new();
        check_session_socket_free(&f, &mut out);
        assert!(out.is_empty(), "other net files may use sockets");
    }

    #[test]
    fn thread_spawn_in_net_is_flagged_outside_reactor() {
        let f = net_file("std::thread::spawn(move || loop_forever());\n");
        let mut out = Vec::new();
        check_thread_spawn(&f, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "thread-spawn");
        // The reactor module owns the one legitimate thread.
        let f = SourceFile::parse(
            "src/net/reactor.rs",
            "std::thread::spawn(move || run_loop(inner, rx));\n",
            false,
        );
        let mut out = Vec::new();
        check_thread_spawn(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // Outside net/ the rule does not apply at all.
        let f = SourceFile::parse("src/pipeline/driver.rs", "std::thread::spawn(f);\n", false);
        let mut out = Vec::new();
        check_thread_spawn(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn thread_spawn_allow_annotation_and_tests_silence() {
        let f = net_file(
            "// lint: allow(thread-spawn): joined before return, not a reader.\n\
             std::thread::spawn(f);\n",
        );
        let mut out = Vec::new();
        check_thread_spawn(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
        let f = net_file("#[cfg(test)]\nmod tests {\n    fn t() { std::thread::spawn(f); }\n}\n");
        let mut out = Vec::new();
        check_thread_spawn(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let f = SourceFile::parse("src/x.rs", "unsafe impl Send for T {}\n", false);
        let mut out = Vec::new();
        check_safety_comments(&f, &mut out);
        assert_eq!(out.len(), 1);
        let f = SourceFile::parse(
            "src/x.rs",
            "// SAFETY: T owns no thread-affine state.\nunsafe impl Send for T {}\n",
            false,
        );
        let mut out = Vec::new();
        check_safety_comments(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unsafe_in_identifiers_or_strings_is_ignored() {
        let f = SourceFile::parse(
            "src/x.rs",
            "let not_unsafe_here = 1;\nlet s = \"unsafe\";\n// unsafe in a comment\n",
            false,
        );
        let mut out = Vec::new();
        check_safety_comments(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }
}
