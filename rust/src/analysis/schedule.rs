//! Deterministic interleaving checker for the session protocol.
//!
//! [`BoundaryModel`] models one stage boundary end to end: a
//! [`SessionTx`], a [`SessionRx`], and N conduits carrying FIFO queues in
//! both directions. Every source of nondeterminism in the real system —
//! which stripe a frame rides, when the kernel delivers it, when an ACK
//! is emitted and when it lands, a conduit dying with everything
//! in flight, the HELLO resync on reconnect — is an explicit [`Action`],
//! and [`crate::util::explore`] drives the pair through **every**
//! interleaving up to a bound. Three further sources model the telemetry
//! side channel and the link's failure modes: a data-plane-neutral
//! telemetry record may ride any conduit at any time
//! ([`Action::SendTelemetry`]), a write may be cut off mid-record
//! ([`Action::TruncateUp`]) — everything fully written still lands, the
//! partial record is lost, and the conduit dies — and a record may be
//! corrupted in flight ([`Action::CorruptUp`], mirroring the chaos
//! shaper's byte-flip semantics): the receiver's CRC check rejects it,
//! which reads as a desynced stream, so the record is lost and the
//! conduit dies with everything behind it.
//!
//! Checked after every transition and at every quiescent state:
//!
//! * frames are delivered to the application exactly once, in order, as
//!   a consecutive prefix of the sequence space;
//! * the sender never holds more than `replay_capacity` unacked frames;
//! * at quiescence every frame was delivered and the FIN/FIN_ACK
//!   handshake completed — nothing is lost even across conduit kills.
//!
//! The serving plane adds one more axis: with `streams > 1` every fresh
//! send also picks WHICH client stream claims the next global sequence
//! number ([`Action::SendOn`]), over-approximating the DRR dispatcher's
//! pop order, and the frame carries that stream tag on the wire. The
//! demux invariant — a delivered frame's tag equals the tag it was
//! submitted with, even when the frame rode the kill → HELLO-resync →
//! replay path — is checked at every delivery.
//!
//! The model over-approximates the real schedulers (the sender may pick
//! *any* live conduit per frame, not just the round-robin choice), so a
//! clean search covers strictly more behaviours than the deployed code
//! exhibits. Seeded-fault variants ([`Bug`]) prove the checker actually
//! rejects broken protocols instead of vacuously passing.

use crate::net::frame::Frame;
use crate::net::session::{RxStep, SessionRx, SessionTx, K_ACK, K_FIN_ACK};
use crate::quant::codec::Encoded;
use crate::util::explore::{Fnv, Model};
use std::collections::VecDeque;

/// Sender → receiver traffic on one conduit.
#[derive(Debug, Clone, PartialEq)]
enum Up {
    /// A data frame: `(seq, stream tag)`.
    Frame(u64, u32),
    /// FIN carrying the end-of-stream boundary.
    Fin(u64),
    /// A telemetry record: data-plane-neutral, never acked, never
    /// replayed — the receiver must ignore it completely.
    Tele,
}

/// Receiver → sender traffic: a control record `(kind, seq)`.
type Down = (u8, u64);

/// One conduit: alive flag plus in-flight queues in both directions.
/// Killing the conduit drops both queues — exactly what a dead TCP
/// connection does to its in-flight bytes.
#[derive(Debug, Clone)]
struct Conduit {
    alive: bool,
    up: VecDeque<Up>,
    down: VecDeque<Down>,
}

/// Full system state: both session endpoints plus the wire.
#[derive(Clone)]
pub struct BoundaryState {
    tx: SessionTx,
    rx: SessionRx,
    conduits: Vec<Conduit>,
    /// Next fresh sequence number the application will send.
    next_send: u64,
    /// Stream tag each sent seq was submitted with (`stream_of[seq]`) —
    /// the model's copy of the serving coordinator's `pending` map.
    stream_of: Vec<u32>,
    /// Sequence numbers popped by the receiving application, in order.
    delivered: Vec<u64>,
    /// Stream tag each delivered frame carried, parallel to `delivered`.
    delivered_tags: Vec<u32>,
    /// Remaining kill budget.
    kills_left: u8,
    /// Remaining telemetry-record budget.
    tele_left: u8,
    /// Remaining partial-write (truncation) budget.
    truncs_left: u8,
    /// Remaining in-flight-corruption budget.
    corrupts_left: u8,
}

impl BoundaryState {
    /// Sequence numbers delivered to the application so far, in order.
    pub fn delivered(&self) -> &[u64] {
        &self.delivered
    }

    /// Stream tag each delivered frame carried, parallel to
    /// [`Self::delivered`] — the corpus pins demux survival on this.
    pub fn delivered_tags(&self) -> &[u32] {
        &self.delivered_tags
    }

    /// Sender-side session endpoint (for assertions in tests).
    pub fn tx(&self) -> &SessionTx {
        &self.tx
    }

    /// Receiver-side session endpoint (for assertions in tests).
    pub fn rx(&self) -> &SessionRx {
        &self.rx
    }
}

/// One schedulable transition of the boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Application records the next frame (tagged stream 0) and writes
    /// it to conduit `.0` — the single-stream plane.
    Send(usize),
    /// Serving plane (`streams > 1`): stream `.1` claims the next global
    /// sequence number and the frame rides conduit `.0` carrying that
    /// stream tag. Enumerated for every stream, over-approximating every
    /// pop order the DRR dispatcher could produce.
    SendOn(usize, u32),
    /// Sender writes FIN (end = `next_seq`) to conduit `.0`.
    SendFin(usize),
    /// Kernel delivers the head of conduit `.0`'s upstream queue.
    DeliverUp(usize),
    /// Kernel delivers the head of conduit `.0`'s downstream queue.
    DeliverDown(usize),
    /// Receiver emits a due cumulative ACK on conduit `.0`.
    EmitAck(usize),
    /// Receiver emits the gated FIN_ACK on conduit `.0`.
    EmitFinAck(usize),
    /// Conduit `.0` dies, losing everything in flight.
    Kill(usize),
    /// Conduit `.0` reconnects: HELLO resync + replay, atomically (the
    /// dialer completes the handshake before the conduit re-enters the
    /// pool).
    Reconnect(usize),
    /// Sender writes one telemetry record to conduit `.0`. Telemetry is
    /// data-plane-neutral: no sequence number, no ack, no replay — the
    /// checker proves its presence never perturbs delivery.
    SendTelemetry(usize),
    /// A write on conduit `.0` is cut off mid-record (process death,
    /// kernel reset between `write` calls): every fully-written record
    /// still in flight is delivered, the partial one is lost, and the
    /// conduit dies — the receiver treats truncation as link failure.
    TruncateUp(usize),
    /// The head in-flight record on conduit `.0` is corrupted on the
    /// wire (the chaos shaper's byte flip): the receiver's CRC check
    /// rejects it, which reads as a desynced stream, so the record is
    /// lost and the conduit dies with everything queued behind it —
    /// replay on reconnect must recover every data frame.
    CorruptUp(usize),
}

/// Seeded faults for the checker's own tests: each breaks the protocol
/// in a way the exhaustive search must catch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Bug {
    /// ACKs overshoot by one, trimming an undelivered frame from the
    /// replay buffer — a kill then loses it irrecoverably.
    AckOvershoot,
    /// Reconnect skips the replay of unacked frames.
    SkipReplay,
    /// Replay after the HELLO resync rebuilds frames tagged stream 0
    /// instead of their submitted stream — the cross-stream leakage the
    /// serving demux invariant must catch (observable only with
    /// `streams >= 2`).
    ReplayRetag,
}

/// Model parameters: frame count, conduit count, session capacity and
/// the kill budget.
#[derive(Debug, Clone, Copy)]
pub struct BoundaryModel {
    /// Frames the application sends (seqs `0..total`).
    pub total: u64,
    /// Number of conduits striping the boundary.
    pub conduits: usize,
    /// Sender replay capacity; the receiver reorder window follows the
    /// striping rule (0 when single-conduit, `capacity` when striped).
    pub capacity: usize,
    /// How many conduit kills the scheduler may inject.
    pub kills: u8,
    /// How many telemetry records the sender may interleave.
    pub tele: u8,
    /// How many partial-write truncations the scheduler may inject.
    pub truncs: u8,
    /// How many in-flight corruptions (CRC-failed records) the
    /// scheduler may inject.
    pub corrupts: u8,
    /// Client streams interleaving sends on this one session (1 = the
    /// classic single-stream plane; `> 1` enables [`Action::SendOn`]).
    pub streams: u32,
    /// Fault injection for self-tests; `None` for the real protocol.
    pub bug: Option<Bug>,
}

impl BoundaryModel {
    /// A clean (no seeded bug) configuration.
    pub fn clean(total: u64, conduits: usize, capacity: usize, kills: u8) -> Self {
        BoundaryModel {
            total,
            conduits,
            capacity,
            kills,
            tele: 0,
            truncs: 0,
            corrupts: 0,
            streams: 1,
            bug: None,
        }
    }

    /// A clean serving-plane configuration: `streams` client streams
    /// interleave their sends on the one session.
    pub fn serving(total: u64, conduits: usize, capacity: usize, kills: u8, streams: u32) -> Self {
        BoundaryModel { streams, ..BoundaryModel::clean(total, conduits, capacity, kills) }
    }

    fn reorder_window(&self) -> usize {
        if self.conduits > 1 {
            self.capacity
        } else {
            0
        }
    }

    /// Pop everything ready at the receiver into `delivered`, checking
    /// the exactly-once in-order invariant frame by frame.
    fn drain_ready(&self, s: &mut BoundaryState) -> Result<(), String> {
        while let Some(f) = s.rx.pop_ready() {
            let want = s.delivered.len() as u64;
            if f.seq != want {
                return Err(format!(
                    "delivery out of order: app got seq {} but expected {} (delivered so far: \
                     {:?})",
                    f.seq, want, s.delivered
                ));
            }
            // The serving demux invariant: the frame must still carry
            // the stream tag it was submitted with — even when it
            // reached the receiver via the kill → HELLO → replay path.
            let submitted = s.stream_of[f.seq as usize];
            if f.stream != submitted {
                return Err(format!(
                    "cross-stream leakage: seq {} was submitted on stream {} but delivered \
                     tagged stream {}",
                    f.seq, submitted, f.stream
                ));
            }
            s.delivered.push(f.seq);
            s.delivered_tags.push(f.stream);
        }
        Ok(())
    }

    /// Deliver one upstream record into the receiver (shared by
    /// [`Action::DeliverUp`] and the flush inside [`Action::TruncateUp`]).
    fn deliver_one(&self, s: &mut BoundaryState, i: usize, msg: Up) -> Result<(), String> {
        match msg {
            Up::Frame(seq, stream) => {
                let step = s.rx.on_frame(frame(seq, stream)).map_err(|e| e.to_string())?;
                self.drain_ready(s)?;
                if step == RxStep::Duplicate {
                    // The real receiver force-acks duplicates so a
                    // replaying sender converges.
                    if let Some(pos) = s.rx.ack_due(true) {
                        s.conduits[i].down.push_back((K_ACK, pos));
                        s.rx.mark_acked(pos);
                    }
                }
            }
            Up::Fin(end) => {
                s.rx.on_fin(end).map_err(|e| e.to_string())?;
            }
            // Telemetry is invisible to the session: no state change at
            // all — the invariants after this transition prove it.
            Up::Tele => {}
        }
        Ok(())
    }

    /// Post-transition safety checks that hold in every state.
    fn invariants(&self, s: &BoundaryState) -> Result<(), String> {
        if s.tx.unacked() > self.capacity {
            return Err(format!(
                "sender holds {} unacked frames, capacity is {}",
                s.tx.unacked(),
                self.capacity
            ));
        }
        if s.rx.last_acked() > s.rx.next_expected() {
            return Err(format!(
                "receiver acked past its own delivery point: acked {} > next_expected {}",
                s.rx.last_acked(),
                s.rx.next_expected()
            ));
        }
        Ok(())
    }
}

/// A minimal data frame for the model (payload content is irrelevant to
/// the session layer, which tracks only sequence numbers and bytes —
/// the stream tag is payload routing it must carry through untouched).
fn frame(seq: u64, stream: u32) -> Frame {
    Frame::for_stream(
        stream,
        seq,
        vec![1],
        Encoded { params: None, elems: 1, payload: vec![0], tiled: false },
    )
}

impl Model for BoundaryModel {
    type State = BoundaryState;
    type Action = Action;

    fn initial(&self) -> BoundaryState {
        BoundaryState {
            tx: SessionTx::new(self.capacity),
            rx: SessionRx::new(self.capacity, self.reorder_window()),
            conduits: (0..self.conduits)
                .map(|_| Conduit { alive: true, up: VecDeque::new(), down: VecDeque::new() })
                .collect(),
            next_send: 0,
            stream_of: Vec::new(),
            delivered: Vec::new(),
            delivered_tags: Vec::new(),
            kills_left: self.kills,
            tele_left: self.tele,
            truncs_left: self.truncs,
            corrupts_left: self.corrupts,
        }
    }

    fn actions(&self, s: &BoundaryState, out: &mut Vec<Action>) {
        let done = s.tx.fin_acked() && s.rx.finished();
        for (i, c) in s.conduits.iter().enumerate() {
            if c.alive {
                if s.next_send < self.total && s.tx.has_room() {
                    if self.streams <= 1 {
                        out.push(Action::Send(i));
                    } else {
                        // Serving plane: any stream may claim the next
                        // global seq — the over-approximation of every
                        // DRR pop order the dispatcher could produce.
                        for st in 0..self.streams {
                            out.push(Action::SendOn(i, st));
                        }
                    }
                }
                if s.next_send == self.total
                    && !s.tx.fin_acked()
                    && !c.up.iter().any(|m| matches!(m, Up::Fin(_)))
                {
                    out.push(Action::SendFin(i));
                }
                if !c.up.is_empty() {
                    out.push(Action::DeliverUp(i));
                }
                if !c.down.is_empty() {
                    out.push(Action::DeliverDown(i));
                }
                if s.rx.ack_due(false).is_some() {
                    out.push(Action::EmitAck(i));
                }
                if s.rx.fin_due().is_some() {
                    out.push(Action::EmitFinAck(i));
                }
                if s.kills_left > 0 && !done {
                    out.push(Action::Kill(i));
                }
                if s.tele_left > 0 && !done {
                    out.push(Action::SendTelemetry(i));
                }
                if s.truncs_left > 0 && !c.up.is_empty() && !done {
                    out.push(Action::TruncateUp(i));
                }
                if s.corrupts_left > 0 && !c.up.is_empty() && !done {
                    out.push(Action::CorruptUp(i));
                }
            } else if !done {
                out.push(Action::Reconnect(i));
            }
        }
    }

    fn apply(&self, prev: &BoundaryState, action: &Action) -> Result<BoundaryState, String> {
        let mut s = prev.clone();
        match *action {
            Action::Send(i) => {
                let seq = s.next_send;
                s.tx.record_send(seq, seq.to_le_bytes().to_vec()).map_err(|e| e.to_string())?;
                s.next_send += 1;
                s.stream_of.push(0);
                s.conduits[i].up.push_back(Up::Frame(seq, 0));
            }
            Action::SendOn(i, st) => {
                let seq = s.next_send;
                s.tx.record_send(seq, seq.to_le_bytes().to_vec()).map_err(|e| e.to_string())?;
                s.next_send += 1;
                s.stream_of.push(st);
                s.conduits[i].up.push_back(Up::Frame(seq, st));
            }
            Action::SendFin(i) => {
                let end = s.tx.next_seq();
                s.conduits[i].up.push_back(Up::Fin(end));
            }
            Action::DeliverUp(i) => match s.conduits[i].up.pop_front() {
                Some(msg) => self.deliver_one(&mut s, i, msg)?,
                None => return Err("DeliverUp scheduled on an empty queue".into()),
            },
            Action::DeliverDown(i) => match s.conduits[i].down.pop_front() {
                Some((kind, seq)) => s.tx.apply_ctrl(kind, seq),
                None => return Err("DeliverDown scheduled on an empty queue".into()),
            },
            Action::EmitAck(i) => {
                let pos = match s.rx.ack_due(false) {
                    Some(pos) => pos,
                    None => return Err("EmitAck scheduled with no ack due".into()),
                };
                let pos = if self.bug == Some(Bug::AckOvershoot) { pos + 1 } else { pos };
                s.conduits[i].down.push_back((K_ACK, pos));
                s.rx.mark_acked(pos.min(s.rx.next_expected()));
            }
            Action::EmitFinAck(i) => {
                let end = match s.rx.fin_due() {
                    Some(end) => end,
                    None => return Err("EmitFinAck scheduled with no FIN due".into()),
                };
                s.conduits[i].down.push_back((K_FIN_ACK, end));
                s.rx.mark_fin_acked();
            }
            Action::Kill(i) => {
                s.kills_left -= 1;
                s.conduits[i].alive = false;
                s.conduits[i].up.clear();
                s.conduits[i].down.clear();
            }
            Action::Reconnect(i) => {
                s.conduits[i].alive = true;
                // The dialer handshake, atomically: receiver speaks
                // HELLO(next_expected) (doubling as a cumulative ack),
                // sender trims and replays its unacked tail on this
                // conduit before it rejoins the pool.
                let pos = s.rx.next_expected();
                s.rx.mark_acked(pos);
                s.tx.on_hello(pos).map_err(|e| e.to_string())?;
                if self.bug != Some(Bug::SkipReplay) {
                    for seq in s.tx.replay_seqs().collect::<Vec<_>>() {
                        // The replay buffer holds the pristine wire
                        // bytes, stream tag included; the retag bug
                        // models a replay path that rebuilds frames
                        // and forgets the tag.
                        let st = if self.bug == Some(Bug::ReplayRetag) {
                            0
                        } else {
                            s.stream_of[seq as usize]
                        };
                        s.conduits[i].up.push_back(Up::Frame(seq, st));
                    }
                }
            }
            Action::SendTelemetry(i) => {
                s.tele_left -= 1;
                s.conduits[i].up.push_back(Up::Tele);
            }
            Action::TruncateUp(i) => {
                s.truncs_left -= 1;
                let mut q = std::mem::take(&mut s.conduits[i].up);
                // The partially-written record at the tail is lost…
                q.pop_back();
                // …but every record fully written before it was already in
                // the kernel's hands and still lands, in order.
                for msg in q {
                    self.deliver_one(&mut s, i, msg)?;
                }
                // Then the connection is gone: the receiver saw a
                // truncated stream, which is a link failure, and whatever
                // it had queued back to the sender dies with the socket.
                s.conduits[i].alive = false;
                s.conduits[i].up.clear();
                s.conduits[i].down.clear();
            }
            Action::CorruptUp(i) => {
                s.corrupts_left -= 1;
                // The head record's bytes fail the CRC check at the
                // receiver: it never reaches the session layer, and the
                // receiver drops the conduit as desynced — the corrupt
                // record and everything queued behind it are lost
                // together. Same transition as a kill, but spent from
                // its own budget so corruption is exercised even when
                // `kills` is zero.
                s.conduits[i].alive = false;
                s.conduits[i].up.clear();
                s.conduits[i].down.clear();
            }
        }
        self.invariants(&s)?;
        Ok(s)
    }

    fn check_terminal(&self, s: &BoundaryState) -> Result<(), String> {
        let want: Vec<u64> = (0..self.total).collect();
        if s.delivered != want {
            return Err(format!(
                "quiescent with frames missing: delivered {:?}, wanted 0..{}",
                s.delivered, self.total
            ));
        }
        if !s.tx.fin_acked() || !s.rx.finished() {
            return Err(format!(
                "quiescent without a completed FIN handshake (fin_acked={}, finished={})",
                s.tx.fin_acked(),
                s.rx.finished()
            ));
        }
        Ok(())
    }

    fn fingerprint(&self, s: &BoundaryState) -> u64 {
        let mut h = Fnv::default();
        h.u64(s.next_send).u64(s.delivered.len() as u64).u64(s.kills_left as u64);
        for st in &s.stream_of {
            h.u64(*st as u64);
        }
        h.u64(s.tele_left as u64).u64(s.truncs_left as u64).u64(s.corrupts_left as u64);
        h.u64(s.tx.next_seq()).u64(s.tx.acked()).u64(s.tx.fin_acked() as u64);
        for seq in s.tx.replay_seqs() {
            h.u64(seq);
        }
        h.u64(s.rx.next_expected()).u64(s.rx.last_acked());
        h.u64(s.rx.fin_boundary().unwrap_or(u64::MAX)).u64(s.rx.finished() as u64);
        for seq in s.rx.parked_seqs() {
            h.u64(seq);
        }
        for c in &s.conduits {
            h.u64(0xC0).u64(c.alive as u64);
            for m in &c.up {
                match m {
                    Up::Frame(seq, st) => h.u64(1).u64(*seq).u64(*st as u64),
                    Up::Fin(end) => h.u64(2).u64(*end),
                    Up::Tele => h.u64(3),
                };
            }
            h.u64(0xD0);
            for (kind, seq) in &c.down {
                h.u64(*kind as u64).u64(*seq);
            }
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::explore::{explore, replay, Bounds};

    #[test]
    fn single_conduit_clean_run_is_exhaustively_correct() {
        let m = BoundaryModel::clean(3, 1, 2, 0);
        let cov = explore(&m, Bounds::default()).unwrap_or_else(|v| panic!("{v}"));
        assert!(cov.terminals >= 1, "at least one quiescent state: {cov:?}");
        assert!(cov.states > 20, "the schedule space is nontrivial: {cov:?}");
    }

    #[test]
    fn single_conduit_with_kill_replays_losslessly() {
        let m = BoundaryModel::clean(2, 1, 2, 1);
        let cov = explore(&m, Bounds::default()).unwrap_or_else(|v| panic!("{v}"));
        assert!(cov.terminals >= 1, "{cov:?}");
    }

    #[test]
    fn striped_boundary_with_kill_is_exhaustively_correct() {
        let m = BoundaryModel::clean(3, 2, 4, 1);
        let bounds = Bounds { max_depth: 64, max_states: 1 << 21 };
        let cov = explore(&m, bounds).unwrap_or_else(|v| panic!("{v}"));
        assert!(cov.terminals >= 1, "{cov:?}");
        assert!(cov.states > 1000, "striping + kill explores a real space: {cov:?}");
    }

    #[test]
    fn ack_overshoot_bug_is_caught() {
        let m = BoundaryModel {
            total: 2,
            conduits: 1,
            capacity: 2,
            kills: 1,
            tele: 0,
            truncs: 0,
            corrupts: 0,
            streams: 1,
            bug: Some(Bug::AckOvershoot),
        };
        let v = explore(&m, Bounds::default()).expect_err("overshooting acks must be caught");
        assert!(!v.trace.is_empty(), "violation carries a reproducing schedule");
    }

    #[test]
    fn skipped_replay_bug_is_caught() {
        let m = BoundaryModel {
            total: 2,
            conduits: 1,
            capacity: 2,
            kills: 1,
            tele: 0,
            truncs: 0,
            corrupts: 0,
            streams: 1,
            bug: Some(Bug::SkipReplay),
        };
        let v = explore(&m, Bounds::default()).expect_err("skipping replay must lose frames");
        assert!(!v.trace.is_empty());
    }

    #[test]
    fn telemetry_records_never_perturb_the_data_plane() {
        // Telemetry may land between any two data records, on any
        // conduit, at any point of the run — delivery must stay exactly
        // once, in order, in EVERY interleaving.
        let m = BoundaryModel {
            total: 2,
            conduits: 2,
            capacity: 4,
            kills: 0,
            tele: 2,
            truncs: 0,
            corrupts: 0,
            streams: 1,
            bug: None,
        };
        let bounds = Bounds { max_depth: 64, max_states: 1 << 21 };
        let cov = explore(&m, bounds).unwrap_or_else(|v| panic!("{v}"));
        assert!(cov.terminals >= 1, "{cov:?}");
    }

    #[test]
    fn partial_write_truncation_recovers_losslessly() {
        // A write cut off mid-record delivers the fully-written prefix,
        // loses the partial record and kills the conduit; the HELLO
        // resync on reconnect must replay exactly what went missing.
        let m = BoundaryModel {
            total: 2,
            conduits: 1,
            capacity: 2,
            kills: 0,
            tele: 1,
            truncs: 1,
            corrupts: 0,
            streams: 1,
            bug: None,
        };
        let bounds = Bounds { max_depth: 64, max_states: 1 << 21 };
        let cov = explore(&m, bounds).unwrap_or_else(|v| panic!("{v}"));
        assert!(cov.terminals >= 1, "{cov:?}");
        assert!(cov.states > 20, "truncation explores a real space: {cov:?}");
    }

    #[test]
    fn in_flight_corruption_recovers_losslessly() {
        // A CRC-failed record costs the receiver the whole conduit (the
        // stream is desynced past it), so recovery rides the same
        // machinery as a kill: HELLO resync + replay of the unacked
        // tail. Exhaustively, in every interleaving, nothing is lost.
        let m = BoundaryModel {
            total: 2,
            conduits: 1,
            capacity: 2,
            kills: 0,
            tele: 0,
            truncs: 0,
            corrupts: 1,
            streams: 1,
            bug: None,
        };
        let bounds = Bounds { max_depth: 64, max_states: 1 << 21 };
        let cov = explore(&m, bounds).unwrap_or_else(|v| panic!("{v}"));
        assert!(cov.terminals >= 1, "{cov:?}");
        assert!(cov.states > 20, "corruption explores a real space: {cov:?}");
    }

    #[test]
    fn two_streams_interleave_without_cross_stream_leakage() {
        // Serving plane: 2 streams race for 3 global seqs on a boundary
        // that loses one conduit mid-run. Every assignment of streams to
        // seqs, interleaved with every kill/resync point, must deliver
        // exactly once, in order, with every stream tag intact.
        let m = BoundaryModel::serving(3, 1, 2, 1, 2);
        let bounds = Bounds { max_depth: 64, max_states: 1 << 21 };
        let cov = explore(&m, bounds).unwrap_or_else(|v| panic!("{v}"));
        assert!(cov.terminals >= 1, "{cov:?}");
        assert!(cov.states > 100, "the stream axis explores a real space: {cov:?}");
    }

    #[test]
    fn replay_retag_bug_is_caught() {
        // A replay path that rebuilds frames tagged stream 0 leaks a
        // stream-1 frame across the demux boundary as soon as a kill
        // forces a replay — the checker must find that schedule.
        let m = BoundaryModel {
            bug: Some(Bug::ReplayRetag),
            ..BoundaryModel::serving(2, 1, 2, 1, 2)
        };
        let v = explore(&m, Bounds::default()).expect_err("retagged replay must leak");
        assert!(
            format!("{v}").contains("cross-stream leakage"),
            "wrong violation: {v}"
        );
    }

    #[test]
    fn a_known_schedule_replays_deterministically() {
        let m = BoundaryModel::clean(1, 1, 1, 0);
        let schedule = [
            Action::Send(0),
            Action::DeliverUp(0),
            Action::EmitAck(0),
            Action::DeliverDown(0),
            Action::SendFin(0),
            Action::DeliverUp(0),
            Action::EmitFinAck(0),
            Action::DeliverDown(0),
        ];
        let end = replay(&m, &schedule).unwrap_or_else(|v| panic!("{v}"));
        assert_eq!(end.delivered, vec![0]);
        assert!(end.tx.fin_acked());
    }
}
