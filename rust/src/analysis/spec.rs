//! R5: cross-check wire-protocol constants against the normative doc.
//!
//! `docs/WIRE_PROTOCOL.md` is the contract other implementations are
//! written against; [`crate::net::session`] and [`crate::net::frame`]
//! are the implementation. This module parses the doc's normative tables
//! (control-kind table, frame header, bounds) and diffs every value
//! against the constants the code actually uses, so the two can never
//! drift silently — the check runs in `tests/static_analysis.rs`.

use crate::analysis::lints::Finding;
use crate::net::frame;
use crate::net::session;
use crate::quant::tile;

/// Wire facts extracted from the normative doc.
#[derive(Debug, Clone, PartialEq)]
pub struct WireSpec {
    /// Control-record marker (`prefix == CTRL_MARKER`), with doc line.
    pub ctrl_marker: (u32, usize),
    /// Fixed control-record length in bytes, with doc line.
    pub ctrl_len: (usize, usize),
    /// Frame length bound, with doc line.
    pub max_frame_bytes: (usize, usize),
    /// Telemetry payload bound, with doc line.
    pub max_telemetry_bytes: (usize, usize),
    /// Frame-header magic, with doc line.
    pub magic: (u32, usize),
    /// Frame-header version, with doc line.
    pub version: (u8, usize),
    /// Control kinds: (kind byte, name, doc line).
    pub kinds: Vec<(u8, String, usize)>,
    /// Tiled-payload header length, with doc line (§2.1).
    pub tile_hdr: (usize, usize),
    /// Tiled-payload per-tile param row length, with doc line.
    pub tile_param: (usize, usize),
    /// Tiled-payload outlier record length, with doc line.
    pub tile_outlier: (usize, usize),
    /// Tile-count bound, with doc line.
    pub max_tiles: (usize, usize),
}

/// First hex literal (`0x…`) on the line, underscores allowed.
fn extract_hex(line: &str) -> Option<u64> {
    let at = line.find("0x")?;
    let digits: String = line[at + 2..]
        .chars()
        .take_while(|c| c.is_ascii_hexdigit() || *c == '_')
        .filter(|c| *c != '_')
        .collect();
    u64::from_str_radix(&digits, 16).ok()
}

/// Trailing byte-count annotation: `… (N bytes…)`.
fn extract_paren_bytes(line: &str) -> Option<usize> {
    let inside = &line[line.rfind('(')? + 1..];
    let digits: String = inside.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok().filter(|_| inside.contains("bytes"))
}

/// Value of a power-of-two bound written as `` `NAME = 2^exp` ``.
fn extract_pow2(line: &str, name: &str) -> Option<usize> {
    let pat = format!("{name} = 2^");
    let at = line.find(&pat)?;
    let exp: String =
        line[at + pat.len()..].chars().take_while(|c| c.is_ascii_digit()).collect();
    exp.parse::<u32>().ok().map(|e| 1usize << e)
}

/// Parse the normative doc. Returns an error naming the first missing
/// fact, so doc restructuring fails the suite loudly rather than by
/// silently checking nothing.
pub fn parse(doc: &str) -> Result<WireSpec, String> {
    let mut ctrl_marker = None;
    let mut ctrl_len = None;
    let mut max_frame = None;
    let mut max_telemetry = None;
    let mut magic = None;
    let mut version = None;
    let mut kinds = Vec::new();
    let mut tile_hdr = None;
    let mut tile_param = None;
    let mut tile_outlier = None;
    let mut max_tiles = None;
    for (idx, line) in doc.lines().enumerate() {
        let no = idx + 1;
        if line.contains("CTRL_MARKER") && ctrl_marker.is_none() {
            if let Some(v) = extract_hex(line) {
                ctrl_marker = Some((v as u32, no));
            }
        }
        if max_frame.is_none() {
            if let Some(v) = extract_pow2(line, "MAX_FRAME_BYTES") {
                max_frame = Some((v, no));
            }
        }
        if max_telemetry.is_none() {
            if let Some(v) = extract_pow2(line, "MAX_TELEMETRY_BYTES") {
                max_telemetry = Some((v, no));
            }
        }
        if line.contains("marker") && line.contains("bytes)") && ctrl_len.is_none() {
            // "… | seq u64        (13 bytes)"
            let inside = line.rfind('(').map(|p| &line[p + 1..]);
            let digits: String = inside
                .unwrap_or("")
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            if let Ok(v) = digits.parse::<usize>() {
                ctrl_len = Some((v, no));
            }
        }
        if max_tiles.is_none() {
            if let Some(v) = extract_pow2(line, "MAX_TILES") {
                max_tiles = Some((v, no));
            }
        }
        let tokens: Vec<&str> = line.split_whitespace().collect();
        // §2.1 tiled-payload rows, keyed on their leading field name.
        if tokens.first() == Some(&"header") && line.contains("ntiles") && tile_hdr.is_none() {
            if let Some(v) = extract_paren_bytes(line) {
                tile_hdr = Some((v, no));
            }
        }
        if tokens.first() == Some(&"param") && line.contains("scale") && tile_param.is_none() {
            if let Some(v) = extract_paren_bytes(line) {
                tile_param = Some((v, no));
            }
        }
        if tokens.first() == Some(&"outlier") && line.contains("index") && tile_outlier.is_none()
        {
            if let Some(v) = extract_paren_bytes(line) {
                tile_outlier = Some((v, no));
            }
        }
        if tokens.first() == Some(&"magic") && magic.is_none() {
            if let Some(v) = extract_hex(line) {
                magic = Some((v as u32, no));
            }
        }
        if tokens.first() == Some(&"ver") && version.is_none() {
            if let Some(v) = tokens.get(2).and_then(|t| t.parse::<u8>().ok()) {
                version = Some((v, no));
            }
        }
        // Control-kind table rows: `kind <n>  NAME{...}`. The frame
        // header's own `kind   u8 …` row fails the integer parse.
        if tokens.first() == Some(&"kind") {
            if let Some(k) = tokens.get(1).and_then(|t| t.parse::<u8>().ok()) {
                if let Some(name) = tokens.get(2).copied() {
                    let name = name.split('{').next().unwrap_or(name);
                    kinds.push((k, name.to_string(), no));
                }
            }
        }
    }
    Ok(WireSpec {
        ctrl_marker: ctrl_marker.ok_or("doc: CTRL_MARKER value not found")?,
        ctrl_len: ctrl_len.ok_or("doc: control-record byte length not found")?,
        max_frame_bytes: max_frame.ok_or("doc: MAX_FRAME_BYTES bound not found")?,
        max_telemetry_bytes: max_telemetry.ok_or("doc: MAX_TELEMETRY_BYTES bound not found")?,
        magic: magic.ok_or("doc: frame magic not found")?,
        version: version.ok_or("doc: frame version not found")?,
        kinds,
        tile_hdr: tile_hdr.ok_or("doc: tiled-payload header length not found")?,
        tile_param: tile_param.ok_or("doc: tiled-payload param row length not found")?,
        tile_outlier: tile_outlier.ok_or("doc: tiled-payload outlier record length not found")?,
        max_tiles: max_tiles.ok_or("doc: MAX_TILES bound not found")?,
    })
}

fn mismatch(line: usize, message: String) -> Finding {
    Finding { file: "docs/WIRE_PROTOCOL.md".into(), line, rule: "wire-spec", message }
}

/// Diff the parsed spec against the constants in `net::session` and
/// `net::frame`. Empty result = doc and code agree.
pub fn cross_check(spec: &WireSpec) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut check_u64 = |name: &str, doc: u64, line: usize, code: u64| {
        if doc != code {
            out.push(mismatch(
                line,
                format!("{name}: doc says {doc:#x}, code says {code:#x}"),
            ));
        }
    };
    check_u64(
        "CTRL_MARKER",
        spec.ctrl_marker.0 as u64,
        spec.ctrl_marker.1,
        session::CTRL_MARKER as u64,
    );
    check_u64("CTRL_LEN", spec.ctrl_len.0 as u64, spec.ctrl_len.1, session::CTRL_LEN as u64);
    check_u64(
        "MAX_FRAME_BYTES",
        spec.max_frame_bytes.0 as u64,
        spec.max_frame_bytes.1,
        session::MAX_FRAME_BYTES as u64,
    );
    check_u64(
        "MAX_TELEMETRY_BYTES",
        spec.max_telemetry_bytes.0 as u64,
        spec.max_telemetry_bytes.1,
        session::MAX_TELEMETRY_BYTES as u64,
    );
    check_u64("frame MAGIC", spec.magic.0 as u64, spec.magic.1, frame::MAGIC as u64);
    check_u64("frame VERSION", spec.version.0 as u64, spec.version.1, frame::VERSION as u64);
    check_u64(
        "TILE_HDR_BYTES",
        spec.tile_hdr.0 as u64,
        spec.tile_hdr.1,
        tile::TILE_HDR_BYTES as u64,
    );
    check_u64(
        "TILE_PARAM_BYTES",
        spec.tile_param.0 as u64,
        spec.tile_param.1,
        tile::TILE_PARAM_BYTES as u64,
    );
    check_u64(
        "OUTLIER_BYTES",
        spec.tile_outlier.0 as u64,
        spec.tile_outlier.1,
        tile::OUTLIER_BYTES as u64,
    );
    check_u64("MAX_TILES", spec.max_tiles.0 as u64, spec.max_tiles.1, tile::MAX_TILES as u64);
    let code_kinds: [(&str, u8); 6] = [
        ("HELLO", session::K_HELLO),
        ("ACK", session::K_ACK),
        ("FIN", session::K_FIN),
        ("FIN_ACK", session::K_FIN_ACK),
        ("TELEMETRY", session::K_TELEMETRY),
        ("HAVE", session::K_HAVE),
    ];
    for (name, code_val) in code_kinds {
        match spec.kinds.iter().find(|(_, n, _)| n == name) {
            Some(&(doc_val, _, line)) if doc_val != code_val => out.push(mismatch(
                line,
                format!("control kind {name}: doc says {doc_val}, code says {code_val}"),
            )),
            Some(_) => {}
            None => out.push(mismatch(
                1,
                format!("control kind {name} (= {code_val} in code) missing from the doc table"),
            )),
        }
    }
    for (doc_val, name, line) in &spec.kinds {
        if !code_kinds.iter().any(|(n, _)| n == name) {
            out.push(mismatch(
                *line,
                format!("doc lists control kind {doc_val} {name} that the code does not define"),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
length `L` (bounded by `MAX_FRAME_BYTES = 2^30`; larger is corrupt)
* prefix `== 0xFFFF_FFFF` (`CTRL_MARKER`) — a control record.
magic  u32   \"QPFR\" (0x5150_4652)
ver    u8    2
kind   u8    0 = raw f32, 1 = quantized, 2 = tiled
stream u32   client stream / request ID (0 = single-stream)
header  ntiles u32 | tile_elems u32 | noutliers u32         (12 bytes)
param   scale f32 | zp f32 | lo f32 | hi f32 | bits u8      (17 bytes, × ntiles)
outlier index u32 | value f32                               (8 bytes, × noutliers)
`MAX_TILES = 2^16`
marker u32 = 0xFFFF_FFFF | kind u8 | seq u64        (13 bytes)
kind 1  HELLO{next_expected}   receiver → sender
kind 2  ACK{next_expected}     receiver → sender
kind 3  FIN{end_seq}           sender → receiver
kind 4  FIN_ACK{end_seq}       receiver → sender
kind 5  TELEMETRY{len}         sender → receiver
kind 6  HAVE{seq}              receiver → sender
(bounded by `MAX_TELEMETRY_BYTES = 2^20`; larger is desync)
";

    #[test]
    fn parses_all_facts() {
        let spec = parse(GOOD).unwrap();
        assert_eq!(spec.ctrl_marker.0, 0xFFFF_FFFF);
        assert_eq!(spec.ctrl_len.0, 13);
        assert_eq!(spec.max_frame_bytes.0, 1 << 30);
        assert_eq!(spec.max_telemetry_bytes.0, 1 << 20);
        assert_eq!(spec.magic.0, 0x5150_4652);
        assert_eq!(spec.version.0, 2);
        assert_eq!(spec.kinds.len(), 6, "frame-header kind row must not leak in");
        assert_eq!(spec.tile_hdr.0, 12);
        assert_eq!(spec.tile_param.0, 17);
        assert_eq!(spec.tile_outlier.0, 8);
        assert_eq!(spec.max_tiles.0, 1 << 16);
    }

    #[test]
    fn good_doc_cross_checks_clean() {
        let spec = parse(GOOD).unwrap();
        let diffs = cross_check(&spec);
        assert!(diffs.is_empty(), "{diffs:?}");
    }

    #[test]
    fn drifted_constant_is_caught() {
        let drifted = GOOD.replace("2^30", "2^29");
        let diffs = cross_check(&parse(&drifted).unwrap());
        assert_eq!(diffs.len(), 1);
        assert!(diffs[0].message.contains("MAX_FRAME_BYTES"), "{}", diffs[0]);
    }

    #[test]
    fn renumbered_kind_is_caught() {
        let drifted = GOOD.replace("kind 4  FIN_ACK", "kind 6  FIN_ACK");
        let diffs = cross_check(&parse(&drifted).unwrap());
        assert!(
            diffs.iter().any(|d| d.message.contains("FIN_ACK")),
            "renumbered FIN_ACK must be flagged: {diffs:?}"
        );
    }

    #[test]
    fn missing_fact_is_a_parse_error() {
        let gutted = GOOD.replace("CTRL_MARKER", "SOMETHING_ELSE");
        assert!(parse(&gutted).unwrap_err().contains("CTRL_MARKER"));
        let gutted = GOOD.replace("MAX_TILES", "SOMETHING_ELSE");
        assert!(parse(&gutted).unwrap_err().contains("MAX_TILES"));
    }

    #[test]
    fn drifted_tile_constant_is_caught() {
        let drifted = GOOD.replace("(17 bytes", "(19 bytes");
        let diffs = cross_check(&parse(&drifted).unwrap());
        assert_eq!(diffs.len(), 1);
        assert!(diffs[0].message.contains("TILE_PARAM_BYTES"), "{}", diffs[0]);
        let drifted = GOOD.replace("MAX_TILES = 2^16", "MAX_TILES = 2^12");
        let diffs = cross_check(&parse(&drifted).unwrap());
        assert!(diffs.iter().any(|d| d.message.contains("MAX_TILES")), "{diffs:?}");
    }
}
