//! Self-hosted correctness tooling: lint pass, wire-spec cross-check,
//! and the deterministic interleaving checker.
//!
//! Everything here runs as ordinary `cargo test` targets — no external
//! tools, no nightly features — so CI enforces the codebase's structural
//! invariants with the same command that runs its unit tests:
//!
//! * [`source`] — a masked source model of the crate's own `.rs` files
//!   (strings/comments/cfg(test) regions resolved), the substrate the
//!   lints match against.
//! * [`lints`] — the rules: no bare `unwrap` in net/pipeline code, all
//!   locking through `util::sync`, a socket-free session layer, and
//!   `// SAFETY:` comments on every `unsafe`. Violations are silenced
//!   only by an adjacent `// lint: allow(<rule>): <reason>`.
//! * [`spec`] — parses the normative tables in `docs/WIRE_PROTOCOL.md`
//!   and diffs them against the constants in [`crate::net::session`] and
//!   [`crate::net::frame`], so doc and implementation cannot drift.
//! * [`schedule`] — a model of one stage boundary (session + striped
//!   conduits) for [`crate::util::explore`]: every interleaving of
//!   send/deliver/ack/kill/HELLO-resync/FIN up to a bound, with
//!   exactly-once in-order delivery checked at every step.
//!
//! The driving tests live in `rust/tests/static_analysis.rs` and
//! `rust/tests/interleavings.rs`.

pub mod lints;
pub mod schedule;
pub mod source;
pub mod spec;

pub use lints::{run_all, Finding};
pub use source::{crate_sources, SourceFile};
