//! Dataset loaders (artifacts/eval.bin, calib.bin) and accuracy metrics.
//!
//! Binary formats are defined by python/compile/aot.py (little-endian):
//! * eval.bin : magic "QPEV" | ver | count | h | w | c | f32 images | u32 labels
//! * calib.bin: magic "QPCA" | ver | n | per-tensor (rank, dims, f32 data)

use crate::tensor::Tensor;
use crate::Result;
use std::io::Read;
use std::path::Path;

/// eval.bin header magic ("QPEV").
pub const EVAL_MAGIC: u32 = 0x5150_4556;
/// calib.bin header magic ("QPCA").
pub const CALIB_MAGIC: u32 = 0x5150_4341;

/// The held-out evaluation set: images + labels.
#[derive(Debug, Clone)]
pub struct EvalSet {
    /// Row-major image data, count × h × w × c.
    pub images: Vec<f32>,
    /// One label per image.
    pub labels: Vec<u32>,
    /// Number of images.
    pub count: usize,
    /// Per-image (h, w, c).
    pub dims: (usize, usize, usize),
}

impl EvalSet {
    /// Load an eval.bin produced by `make artifacts`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())
            .map_err(|e| anyhow::anyhow!("open {:?}: {e} (run `make artifacts`)", path.as_ref()))?;
        let mut hdr = [0u8; 24];
        f.read_exact(&mut hdr)?;
        let u = |i: usize| u32::from_le_bytes(hdr[i * 4..i * 4 + 4].try_into().unwrap());
        anyhow::ensure!(u(0) == EVAL_MAGIC, "bad eval.bin magic");
        anyhow::ensure!(u(1) == 1, "unsupported eval.bin version");
        let (count, h, w, c) = (u(2) as usize, u(3) as usize, u(4) as usize, u(5) as usize);
        let mut img_bytes = vec![0u8; count * h * w * c * 4];
        f.read_exact(&mut img_bytes)?;
        let images: Vec<f32> = img_bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        let mut lab_bytes = vec![0u8; count * 4];
        f.read_exact(&mut lab_bytes)?;
        let labels: Vec<u32> = lab_bytes
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(EvalSet { images, labels, count, dims: (h, w, c) })
    }

    /// Microbatch `i` of size `s` as an image tensor (s, h, w, c).
    pub fn microbatch(&self, i: usize, s: usize) -> Tensor {
        let (h, w, c) = self.dims;
        let stride = h * w * c;
        let start = i * s * stride;
        let end = start + s * stride;
        assert!(end <= self.images.len(), "microbatch {i} out of range");
        Tensor::new(self.images[start..end].to_vec(), vec![s, h, w, c])
    }

    /// Labels for microbatch `i`.
    pub fn labels_for(&self, i: usize, s: usize) -> &[u32] {
        &self.labels[i * s..(i + 1) * s]
    }

    /// Whole microbatches of size `s` in the set.
    pub fn microbatches(&self, s: usize) -> usize {
        self.count / s
    }

    /// Synthetic one-hot eval set: image `i` is the one-hot vector of its
    /// label over `classes` dims, so a passthrough pipeline classifies it
    /// perfectly. Used by transport tests and artifact-free demos
    /// (`quantpipe coordinate --synthetic`).
    pub fn synthetic_onehot(count: usize, classes: usize) -> EvalSet {
        let mut images = Vec::with_capacity(count * classes);
        let mut labels = Vec::with_capacity(count);
        for i in 0..count {
            let lab = i % classes;
            for c in 0..classes {
                images.push(if c == lab { 1.0 } else { 0.0 });
            }
            labels.push(lab as u32);
        }
        EvalSet { images, labels, count, dims: (1, 1, classes) }
    }
}

/// Calibration boundary activations exported by aot.py.
pub fn load_calib(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let mut hdr = [0u8; 12];
    f.read_exact(&mut hdr)?;
    let u32at = |b: &[u8], i: usize| u32::from_le_bytes(b[i * 4..i * 4 + 4].try_into().unwrap());
    anyhow::ensure!(u32at(&hdr, 0) == CALIB_MAGIC, "bad calib.bin magic");
    anyhow::ensure!(u32at(&hdr, 1) == 1, "unsupported calib.bin version");
    let n = u32at(&hdr, 2) as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut rank_b = [0u8; 4];
        f.read_exact(&mut rank_b)?;
        let rank = u32::from_le_bytes(rank_b) as usize;
        let mut dims_b = vec![0u8; rank * 4];
        f.read_exact(&mut dims_b)?;
        let shape: Vec<usize> = dims_b
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap()) as usize)
            .collect();
        let elems: usize = shape.iter().product();
        let mut data_b = vec![0u8; elems * 4];
        f.read_exact(&mut data_b)?;
        let data: Vec<f32> = data_b
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        out.push(Tensor::new(data, shape));
    }
    Ok(out)
}

/// Top-1 accuracy of logits against labels.
pub fn top1_accuracy(logits: &Tensor, labels: &[u32]) -> f64 {
    let preds = logits.argmax_rows();
    assert_eq!(preds.len(), labels.len());
    let correct = preds
        .iter()
        .zip(labels)
        .filter(|(p, l)| **p == **l as usize)
        .count();
    correct as f64 / labels.len().max(1) as f64
}

/// Running accuracy accumulator (per-window accuracy for the Fig 5 track).
#[derive(Debug, Default, Clone, Copy)]
pub struct AccuracyMeter {
    /// Correct top-1 predictions.
    pub correct: u64,
    /// Predictions scored.
    pub total: u64,
}

impl AccuracyMeter {
    /// Score one microbatch of logits against its labels.
    pub fn add(&mut self, logits: &Tensor, labels: &[u32]) {
        let preds = logits.argmax_rows();
        for (p, l) in preds.iter().zip(labels) {
            if *p == *l as usize {
                self.correct += 1;
            }
        }
        self.total += labels.len() as u64;
    }

    /// Accuracy so far (0 when empty).
    pub fn value(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }

    /// Read the accuracy and reset (per-window accounting).
    pub fn take(&mut self) -> f64 {
        let v = self.value();
        *self = AccuracyMeter::default();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_eval(path: &Path, count: usize) {
        let (h, w, c) = (2usize, 2, 1);
        let mut f = std::fs::File::create(path).unwrap();
        for v in [EVAL_MAGIC, 1, count as u32, h as u32, w as u32, c as u32] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        for i in 0..count * h * w * c {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        for i in 0..count {
            f.write_all(&((i % 10) as u32).to_le_bytes()).unwrap();
        }
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("qp-test-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn eval_roundtrip() {
        let dir = tmpdir("eval");
        let p = dir.join("eval.bin");
        write_eval(&p, 8);
        let ev = EvalSet::load(&p).unwrap();
        assert_eq!(ev.count, 8);
        assert_eq!(ev.dims, (2, 2, 1));
        assert_eq!(ev.microbatches(4), 2);
        let mb = ev.microbatch(1, 4);
        assert_eq!(mb.shape, vec![4, 2, 2, 1]);
        assert_eq!(mb.data[0], 16.0); // second microbatch starts at elem 16
        assert_eq!(ev.labels_for(1, 4), &[4, 5, 6, 7]);
    }

    #[test]
    fn accuracy_math() {
        let logits = Tensor::new(vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4], vec![3, 2]);
        assert!((top1_accuracy(&logits, &[0, 1, 1]) - 2.0 / 3.0).abs() < 1e-12);
        let mut m = AccuracyMeter::default();
        m.add(&logits, &[0, 1, 1]);
        m.add(&logits, &[0, 1, 0]);
        assert_eq!(m.correct, 5);
        assert_eq!(m.total, 6);
        assert!((m.take() - 5.0 / 6.0).abs() < 1e-12);
        assert_eq!(m.total, 0);
    }

    #[test]
    fn calib_roundtrip() {
        let dir = tmpdir("calib");
        let p = dir.join("calib.bin");
        let mut f = std::fs::File::create(&p).unwrap();
        for v in [CALIB_MAGIC, 1, 2] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        for t in 0..2u32 {
            f.write_all(&2u32.to_le_bytes()).unwrap(); // rank
            f.write_all(&2u32.to_le_bytes()).unwrap();
            f.write_all(&3u32.to_le_bytes()).unwrap();
            for i in 0..6 {
                f.write_all(&((t * 10 + i) as f32).to_le_bytes()).unwrap();
            }
        }
        drop(f);
        let ts = load_calib(&p).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].shape, vec![2, 3]);
        assert_eq!(ts[1].data[0], 10.0);
    }
}
