//! JSON configuration for the launcher and experiment presets.
//!
//! Everything an experiment varies lives here: stage count, microbatch
//! size, quantization method and policy, window length, target rate,
//! bandwidth traces per link, codec backend and fault injection.
//! `configs/*.json` ship the paper's experiment presets; CLI flags
//! override individual fields (see main.rs). Parsed with the in-tree
//! [`crate::util::json`] (TOML/serde are unavailable offline).

use crate::adapt::{AdaptConfig, Policy};
use crate::net::link::LinkFaults;
use crate::quant::Method;
use crate::util::json::Value;
use crate::Result;
use std::path::Path;
use std::time::Duration;

#[derive(Debug, Clone)]
/// Root configuration: one section per subsystem.
pub struct Config {
    /// Pipeline topology and runtime knobs.
    pub pipeline: PipelineSection,
    /// Quantization method and calibration cadence.
    pub quant: QuantSection,
    /// Adaptive bitwidth controller.
    pub adapt: AdaptSection,
    /// Simulated-link shaping and fault injection.
    pub net: NetSection,
    /// Workload size and output paths.
    pub run: RunSection,
    /// Multi-process deployment topology.
    pub transport: TransportSection,
}

#[derive(Debug, Clone)]
/// `pipeline` config section.
pub struct PipelineSection {
    /// Number of pipeline stages (model shards). Must match the artifacts.
    pub stages: usize,
    /// Images per microbatch (S). Must match the artifacts.
    pub microbatch: usize,
    /// Max in-flight frames per link (backpressure bound).
    pub inflight: usize,
    /// Quantize/dequantize arithmetic: "native" or "hlo" (AOT Pallas kernel).
    pub codec_backend: String,
    /// Worker threads for the fused encode of large boundary activations
    /// (1 = serial, the default; only the native backend parallelizes).
    /// Output is byte-identical for every value.
    pub codec_threads: usize,
    /// Use the SIMD fused-codec kernels when the CPU supports them
    /// (default true; output is byte-identical to the scalar path, so
    /// this knob exists for A/B benchmarking and bug triage only).
    pub codec_simd: bool,
    /// Tile size (elements) for the tiled hybrid codec on sub-byte
    /// links. 0 (the default) keeps the flat single-tensor wire format;
    /// a positive multiple of 8 enables per-tile calibration, the
    /// outlier side-channel and — with the "budget" adapt policy —
    /// non-uniform per-tile bitwidths.
    pub tile_elems: usize,
    /// Fraction of elements shipped raw through the tiled codec's
    /// outlier side-channel (0 ≤ f ≤ 0.5; ignored when `tile_elems` is
    /// 0).
    pub outlier_frac: f64,
    /// Maximum concurrent client streams the serving coordinator admits
    /// (1 = the classic single-stream coordinator; see
    /// `pipeline::serve`). Streams are payload routing, not a new
    /// reliability domain — the session layer never sees them.
    pub max_streams: usize,
    /// Bounded ingress-queue depth per client stream. A full queue
    /// backpressures only that client (`Admission::Backpressured`);
    /// everyone else keeps flowing.
    pub stream_queue_depth: usize,
}

#[derive(Debug, Clone)]
/// `quant` config section.
pub struct QuantSection {
    /// Calibration method: naive | aciq | ds_aciq | pda.
    pub method: Method,
    /// Re-calibrate every N microbatches (1 = per microbatch).
    pub calib_every: u32,
    /// DS-ACIQ search steps (paper: 100).
    pub ds_steps: usize,
}

#[derive(Debug, Clone)]
/// `adapt` config section.
pub struct AdaptSection {
    /// Enable the adaptive controller (false = fixed bitwidth below).
    pub enabled: bool,
    /// Fixed bitwidth when disabled (32 = no quantization).
    pub fixed_bits: u8,
    /// Target output rate R (images/sec).
    pub target_rate: f64,
    /// Window length in microbatches (paper: 50).
    pub window: u64,
    /// Policy: "ladder" (default), "eq2", "budget", or "fixed:<bits>".
    pub policy: String,
    /// Hysteresis margin for raising bitwidth.
    pub raise_margin: f64,
}

#[derive(Debug, Clone)]
/// `net` config section (SimLink shaping).
pub struct NetSection {
    /// Per-link bandwidth traces, "t:bw" comma lists (see net::trace). One
    /// entry per inter-stage link; a single entry applies to all links.
    pub traces: Vec<String>,
    /// One-way propagation latency, microseconds.
    pub latency_us: u64,
    /// Fault injection.
    pub loss_p: f64,
    /// Jitter injected per send, ms.
    pub jitter_ms: f64,
    /// Seed for the fault injector's RNG.
    pub fault_seed: u64,
}

#[derive(Debug, Clone)]
/// `run` config section.
pub struct RunSection {
    /// Microbatches to push through (0 = one pass over the eval set).
    pub microbatches: u64,
    /// Artifacts directory.
    pub artifacts: String,
    /// Write the Fig-5 style timeline CSV here ("" = don't).
    pub timeline_csv: String,
    /// Write the machine-readable run report JSON here ("" = don't).
    pub report_json: String,
}

/// Multi-process deployment topology (`quantpipe worker` / `coordinate`).
#[derive(Debug, Clone)]
pub struct TransportSection {
    /// "inproc" (single process, SimLink shaping — the default) or "tcp"
    /// (stages in separate processes over real sockets).
    pub mode: String,
    /// Worker k's listen address, in pipeline order (stage k's upstream
    /// connects here).
    pub stage_addrs: Vec<String>,
    /// Coordinator's return-path listen address (the last stage connects
    /// here with the logits stream).
    pub sink_addr: String,
    /// Delay between connect attempts, ms (startup is order-independent).
    pub connect_retry_ms: u64,
    /// Total connect budget, ms.
    pub connect_timeout_ms: u64,
    /// Fault-tolerant links (`net::resilient`): survive transient
    /// connection drops via reconnect + sequenced replay, with an
    /// explicit FIN/FIN_ACK drain at shutdown. Both ends of every link
    /// must agree on this flag.
    pub resilient: bool,
    /// TCP connections per stage boundary (`net::stripe`). 1 = the plain
    /// single-connection link; N > 1 stripes every boundary over N
    /// connections sharing one sequence space (requires `resilient`,
    /// whose session protocol carries the striping). All stripes dial the
    /// same stage address — the receiver multiplexes its one listener, so
    /// no per-stripe ports are needed. Every process in the chain must
    /// agree on this value.
    pub stripes: usize,
    /// Stream per-stage telemetry (window snapshots, counters) forward to
    /// the coordinator, which merges every stage into one
    /// `PipelineReport` (default true). Telemetry is best effort and
    /// data-plane-neutral: it never consumes sequence numbers, never
    /// enters replay buffers, and never delays an ACK.
    pub telemetry: bool,
    /// Sent-but-unacked frames kept for replay per link.
    pub replay_capacity: usize,
    /// Budget to get a failed link back before reporting a hard error, ms.
    pub reconnect_timeout_ms: u64,
    /// First reconnect backoff delay, ms (doubles per attempt, jittered).
    pub backoff_base_ms: u64,
    /// Reconnect backoff cap, ms.
    pub backoff_max_ms: u64,
    /// CPU core to pin the process-wide read reactor thread to
    /// (`net::reactor`), or -1 (the default) to leave placement to the
    /// scheduler. Best effort: applied via `taskset` when the reactor
    /// thread starts, ignored if unavailable. Useful on edge boxes where
    /// the compute stages saturate the other cores.
    pub reactor_pin_core: i64,
    /// Named chaos scenario (`net::scenario`) to impose on every striped
    /// boundary this process sends on: "none" (the default — byte-for-byte
    /// the unshaped path), "cellular_fade", "satellite_pass",
    /// "flash_crowd", "drone_handoff", "partitioned_stripe", "kill_storm"
    /// or "composite_chaos". Requires `stripes >= 1` over resilient links;
    /// shaping is sender-side only, so only the processes that *send* on
    /// a boundary need the scenario configured.
    pub scenario: String,
    /// Seed for the scenario's deterministic impairment schedule: the
    /// same (scenario, seed, stripes) triple always produces the same
    /// fault timeline (see `quantpipe scenario` to print it).
    pub scenario_seed: u64,
}

impl TransportSection {
    /// Delay between connect attempts.
    pub fn connect_retry(&self) -> Duration {
        Duration::from_millis(self.connect_retry_ms.max(1))
    }

    /// The parsed chaos scenario (validated at config-parse time, so
    /// this only fails on a hand-mutated section).
    pub fn scenario_kind(&self) -> Result<crate::net::scenario::ScenarioKind> {
        crate::net::scenario::ScenarioKind::parse(&self.scenario)
    }

    /// Total budget for the first connect of a link.
    pub fn connect_timeout(&self) -> Duration {
        Duration::from_millis(self.connect_timeout_ms)
    }

    /// Resilient-layer tuning derived from this section. The first
    /// connection of a session uses the startup connect budget
    /// (`connect_timeout_ms` — peers launch in any order); only later
    /// re-establishments use the tighter `reconnect_timeout_ms`.
    pub fn resilience_config(&self) -> crate::net::resilient::ResilienceConfig {
        let d = crate::net::resilient::ResilienceConfig::default();
        crate::net::resilient::ResilienceConfig {
            replay_capacity: self.replay_capacity.max(1),
            reconnect_timeout: Duration::from_millis(self.reconnect_timeout_ms.max(1)),
            initial_timeout: self.connect_timeout(),
            backoff_base: Duration::from_millis(self.backoff_base_ms.max(1)),
            backoff_max: Duration::from_millis(self.backoff_max_ms.max(1)),
            ..d
        }
    }
}

impl Default for Config {
    fn default() -> Self {
        Config {
            pipeline: PipelineSection {
                stages: 4,
                microbatch: 64,
                inflight: 2,
                codec_backend: "native".into(),
                codec_threads: 1,
                codec_simd: true,
                tile_elems: 0,
                outlier_frac: 0.01,
                max_streams: 1,
                stream_queue_depth: 4,
            },
            quant: QuantSection { method: Method::Pda, calib_every: 1, ds_steps: 100 },
            adapt: AdaptSection {
                enabled: true,
                fixed_bits: 32,
                target_rate: 100.0,
                window: 50,
                policy: "ladder".into(),
                raise_margin: 1.1,
            },
            net: NetSection {
                traces: vec!["0:inf".into()],
                latency_us: 200,
                loss_p: 0.0,
                jitter_ms: 0.0,
                fault_seed: 0,
            },
            run: RunSection {
                microbatches: 0,
                artifacts: "artifacts".into(),
                timeline_csv: String::new(),
                report_json: String::new(),
            },
            transport: TransportSection {
                mode: "inproc".into(),
                stage_addrs: vec![
                    "127.0.0.1:7711".into(),
                    "127.0.0.1:7712".into(),
                    "127.0.0.1:7713".into(),
                    "127.0.0.1:7714".into(),
                ],
                sink_addr: "127.0.0.1:7710".into(),
                connect_retry_ms: 100,
                connect_timeout_ms: 10_000,
                resilient: false,
                stripes: 1,
                telemetry: true,
                replay_capacity: 128,
                reconnect_timeout_ms: 10_000,
                backoff_base_ms: 10,
                backoff_max_ms: 1_000,
                reactor_pin_core: -1,
                scenario: "none".into(),
                scenario_seed: 0,
            },
        }
    }
}

fn method_from_str(s: &str) -> Result<Method> {
    Ok(match s {
        "naive" => Method::Naive,
        "aciq" => Method::Aciq,
        "ds_aciq" => Method::DsAciq,
        "pda" => Method::Pda,
        other => anyhow::bail!("unknown quant method {other:?}"),
    })
}

impl Config {
    /// Load + parse a JSON config file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::parse(&text)
    }

    /// Parse a JSON config; missing keys fall back to defaults.
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let v = Value::parse(text)?;
        if let Some(p) = v.get("pipeline") {
            if let Some(x) = p.get("stages") { cfg.pipeline.stages = x.as_usize()?; }
            if let Some(x) = p.get("microbatch") { cfg.pipeline.microbatch = x.as_usize()?; }
            if let Some(x) = p.get("inflight") { cfg.pipeline.inflight = x.as_usize()?; }
            if let Some(x) = p.get("codec_backend") { cfg.pipeline.codec_backend = x.as_str()?.into(); }
            if let Some(x) = p.get("codec_threads") {
                cfg.pipeline.codec_threads = x.as_usize()?;
                anyhow::ensure!(
                    cfg.pipeline.codec_threads >= 1,
                    "pipeline.codec_threads must be >= 1 (1 = serial encode)"
                );
            }
            if let Some(x) = p.get("codec_simd") { cfg.pipeline.codec_simd = x.as_bool()?; }
            if let Some(x) = p.get("tile_elems") {
                cfg.pipeline.tile_elems = x.as_usize()?;
                anyhow::ensure!(
                    cfg.pipeline.tile_elems % 8 == 0,
                    "pipeline.tile_elems must be a multiple of 8 (0 = flat codec), got {}",
                    cfg.pipeline.tile_elems
                );
            }
            if let Some(x) = p.get("outlier_frac") {
                cfg.pipeline.outlier_frac = x.as_f64()?;
                anyhow::ensure!(
                    (0.0..=0.5).contains(&cfg.pipeline.outlier_frac),
                    "pipeline.outlier_frac must be in [0, 0.5], got {}",
                    cfg.pipeline.outlier_frac
                );
            }
            if let Some(x) = p.get("max_streams") {
                cfg.pipeline.max_streams = x.as_usize()?;
                anyhow::ensure!(
                    cfg.pipeline.max_streams >= 1,
                    "pipeline.max_streams must be >= 1 (1 = single-stream coordinator)"
                );
            }
            if let Some(x) = p.get("stream_queue_depth") {
                cfg.pipeline.stream_queue_depth = x.as_usize()?;
                anyhow::ensure!(
                    cfg.pipeline.stream_queue_depth >= 1,
                    "pipeline.stream_queue_depth must be >= 1"
                );
            }
        }
        if let Some(q) = v.get("quant") {
            if let Some(x) = q.get("method") { cfg.quant.method = method_from_str(x.as_str()?)?; }
            if let Some(x) = q.get("calib_every") { cfg.quant.calib_every = x.as_u64()? as u32; }
            if let Some(x) = q.get("ds_steps") { cfg.quant.ds_steps = x.as_usize()?; }
        }
        if let Some(a) = v.get("adapt") {
            if let Some(x) = a.get("enabled") { cfg.adapt.enabled = x.as_bool()?; }
            if let Some(x) = a.get("fixed_bits") { cfg.adapt.fixed_bits = x.as_u64()? as u8; }
            if let Some(x) = a.get("target_rate") { cfg.adapt.target_rate = x.as_f64()?; }
            if let Some(x) = a.get("window") { cfg.adapt.window = x.as_u64()?; }
            if let Some(x) = a.get("policy") { cfg.adapt.policy = x.as_str()?.into(); }
            if let Some(x) = a.get("raise_margin") { cfg.adapt.raise_margin = x.as_f64()?; }
        }
        if let Some(n) = v.get("net") {
            if let Some(x) = n.get("traces") {
                cfg.net.traces = x
                    .as_arr()?
                    .iter()
                    .map(|t| Ok(t.as_str()?.to_string()))
                    .collect::<Result<_>>()?;
            }
            if let Some(x) = n.get("latency_us") { cfg.net.latency_us = x.as_u64()?; }
            if let Some(x) = n.get("loss_p") { cfg.net.loss_p = x.as_f64()?; }
            if let Some(x) = n.get("jitter_ms") { cfg.net.jitter_ms = x.as_f64()?; }
            if let Some(x) = n.get("fault_seed") { cfg.net.fault_seed = x.as_u64()?; }
        }
        if let Some(r) = v.get("run") {
            if let Some(x) = r.get("microbatches") { cfg.run.microbatches = x.as_u64()?; }
            if let Some(x) = r.get("artifacts") { cfg.run.artifacts = x.as_str()?.into(); }
            if let Some(x) = r.get("timeline_csv") { cfg.run.timeline_csv = x.as_str()?.into(); }
            if let Some(x) = r.get("report_json") { cfg.run.report_json = x.as_str()?.into(); }
        }
        if let Some(t) = v.get("transport") {
            if let Some(x) = t.get("mode") {
                let mode = x.as_str()?;
                anyhow::ensure!(
                    mode == "inproc" || mode == "tcp",
                    "transport.mode must be \"inproc\" or \"tcp\", got {mode:?}"
                );
                cfg.transport.mode = mode.into();
            }
            if let Some(x) = t.get("stage_addrs") {
                cfg.transport.stage_addrs = x
                    .as_arr()?
                    .iter()
                    .map(|a| Ok(a.as_str()?.to_string()))
                    .collect::<Result<_>>()?;
            }
            if let Some(x) = t.get("sink_addr") { cfg.transport.sink_addr = x.as_str()?.into(); }
            if let Some(x) = t.get("connect_retry_ms") { cfg.transport.connect_retry_ms = x.as_u64()?; }
            if let Some(x) = t.get("connect_timeout_ms") { cfg.transport.connect_timeout_ms = x.as_u64()?; }
            if let Some(x) = t.get("resilient") { cfg.transport.resilient = x.as_bool()?; }
            if let Some(x) = t.get("stripes") {
                cfg.transport.stripes = x.as_usize()?;
                anyhow::ensure!(cfg.transport.stripes >= 1, "transport.stripes must be >= 1");
            }
            if let Some(x) = t.get("telemetry") { cfg.transport.telemetry = x.as_bool()?; }
            if let Some(x) = t.get("replay_capacity") { cfg.transport.replay_capacity = x.as_usize()?; }
            if let Some(x) = t.get("reconnect_timeout_ms") { cfg.transport.reconnect_timeout_ms = x.as_u64()?; }
            if let Some(x) = t.get("backoff_base_ms") { cfg.transport.backoff_base_ms = x.as_u64()?; }
            if let Some(x) = t.get("backoff_max_ms") { cfg.transport.backoff_max_ms = x.as_u64()?; }
            if let Some(x) = t.get("reactor_pin_core") {
                cfg.transport.reactor_pin_core = x.as_f64()? as i64;
                anyhow::ensure!(
                    cfg.transport.reactor_pin_core >= -1,
                    "transport.reactor_pin_core must be a core index or -1 (unpinned)"
                );
            }
            if let Some(x) = t.get("scenario") {
                let name = x.as_str()?;
                // Fail at parse time, not mid-run: unknown names list the
                // valid set.
                crate::net::scenario::ScenarioKind::parse(name)?;
                cfg.transport.scenario = name.into();
            }
            if let Some(x) = t.get("scenario_seed") { cfg.transport.scenario_seed = x.as_u64()?; }
        }
        anyhow::ensure!(
            cfg.transport.stripes == 1 || cfg.transport.resilient,
            "transport.stripes > 1 requires transport.resilient: the striped boundary rides \
             the resilient session protocol (shared sequence space, replay, HELLO resync)"
        );
        anyhow::ensure!(
            cfg.transport.scenario == "none" || cfg.transport.resilient,
            "transport.scenario {:?} requires transport.resilient: chaos shaping expresses \
             loss and corruption as conduit death, which only the resilient session protocol \
             (replay + HELLO resync) survives",
            cfg.transport.scenario
        );
        anyhow::ensure!(
            cfg.transport.scenario == "none" || cfg.transport.mode == "tcp",
            "transport.scenario {:?} requires transport.mode \"tcp\": shapers attach to real \
             socket conduits, so an in-process run would silently ignore the scenario — shape \
             the in-process link with --trace instead",
            cfg.transport.scenario
        );
        Ok(cfg)
    }

    /// Controller config derived from the adapt/pipeline sections.
    pub fn adapt_config(&self) -> Result<AdaptConfig> {
        let policy = match self.adapt.policy.as_str() {
            "ladder" => Policy::Ladder,
            "eq2" => Policy::Eq2,
            "budget" => Policy::Budget,
            other => {
                let bits: u8 = other
                    .strip_prefix("fixed:")
                    .ok_or_else(|| anyhow::anyhow!("unknown policy {other:?}"))?
                    .parse()?;
                Policy::Fixed(bits)
            }
        };
        Ok(AdaptConfig {
            target_rate: self.adapt.target_rate,
            microbatch: self.pipeline.microbatch,
            policy,
            raise_margin: self.adapt.raise_margin,
        })
    }

    /// Trace for link `i` (stage i → i+1).
    pub fn trace_for_link(&self, i: usize) -> Result<crate::net::trace::BandwidthTrace> {
        let s = if self.net.traces.len() == 1 {
            &self.net.traces[0]
        } else {
            self.net
                .traces
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("no trace for link {i}"))?
        };
        crate::net::trace::BandwidthTrace::parse(s)
    }

    /// Fault-injection settings for the simulated links.
    pub fn link_faults(&self) -> LinkFaults {
        LinkFaults {
            loss_p: self.net.loss_p,
            jitter_s: self.net.jitter_ms / 1e3,
            seed: self.net.fault_seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse() {
        let c = Config::parse("{}").unwrap();
        assert_eq!(c.pipeline.stages, 4);
        assert_eq!(c.adapt.window, 50);
        assert_eq!(c.quant.method, Method::Pda);
        assert!(matches!(c.adapt_config().unwrap().policy, Policy::Ladder));
    }

    #[test]
    fn full_json_roundtrip() {
        let text = r#"{
            "pipeline": {"stages": 2, "microbatch": 64, "inflight": 4, "codec_backend": "hlo"},
            "quant": {"method": "aciq", "calib_every": 10},
            "adapt": {"enabled": true, "target_rate": 250.0, "window": 25, "policy": "eq2"},
            "net": {"traces": ["0:inf,10:400M,20:50M"], "loss_p": 0.01},
            "run": {"microbatches": 500}
        }"#;
        let c = Config::parse(text).unwrap();
        assert_eq!(c.pipeline.stages, 2);
        assert_eq!(c.pipeline.codec_backend, "hlo");
        assert_eq!(c.quant.method, Method::Aciq);
        assert_eq!(c.quant.calib_every, 10);
        assert!(matches!(c.adapt_config().unwrap().policy, Policy::Eq2));
        let tr = c.trace_for_link(0).unwrap();
        assert_eq!(tr.at(15.0), 400e6);
        assert!((c.link_faults().loss_p - 0.01).abs() < 1e-12);
        assert_eq!(c.run.microbatches, 500);
    }

    #[test]
    fn codec_threads_knob_parses_validates_and_defaults() {
        let c = Config::parse("{}").unwrap();
        assert_eq!(c.pipeline.codec_threads, 1, "multicore encode is opt-in");
        let c = Config::parse(r#"{"pipeline": {"codec_threads": 4}}"#).unwrap();
        assert_eq!(c.pipeline.codec_threads, 4);
        assert!(Config::parse(r#"{"pipeline": {"codec_threads": 0}}"#).is_err());
    }

    #[test]
    fn tiling_knobs_parse_validate_and_default() {
        let c = Config::parse("{}").unwrap();
        assert_eq!(c.pipeline.tile_elems, 0, "tiling is opt-in");
        assert!((c.pipeline.outlier_frac - 0.01).abs() < 1e-12);
        assert!(c.pipeline.codec_simd, "SIMD kernels are on by default");
        let c = Config::parse(
            r#"{"pipeline": {"tile_elems": 1024, "outlier_frac": 0.02, "codec_simd": false}}"#,
        )
        .unwrap();
        assert_eq!(c.pipeline.tile_elems, 1024);
        assert!((c.pipeline.outlier_frac - 0.02).abs() < 1e-12);
        assert!(!c.pipeline.codec_simd);
        // Tile size must stay group-aligned; the outlier budget is capped.
        assert!(Config::parse(r#"{"pipeline": {"tile_elems": 100}}"#).is_err());
        assert!(Config::parse(r#"{"pipeline": {"outlier_frac": 0.6}}"#).is_err());
        assert!(Config::parse(r#"{"pipeline": {"outlier_frac": -0.1}}"#).is_err());
    }

    #[test]
    fn serving_knobs_parse_validate_and_default() {
        let c = Config::parse("{}").unwrap();
        assert_eq!(c.pipeline.max_streams, 1, "multi-stream serving is opt-in");
        assert_eq!(c.pipeline.stream_queue_depth, 4);
        let c = Config::parse(
            r#"{"pipeline": {"max_streams": 8, "stream_queue_depth": 16}}"#,
        )
        .unwrap();
        assert_eq!(c.pipeline.max_streams, 8);
        assert_eq!(c.pipeline.stream_queue_depth, 16);
        // Both are "at least one" quantities.
        assert!(Config::parse(r#"{"pipeline": {"max_streams": 0}}"#).is_err());
        assert!(Config::parse(r#"{"pipeline": {"stream_queue_depth": 0}}"#).is_err());
    }

    #[test]
    fn budget_policy_string() {
        let mut c = Config::default();
        c.adapt.policy = "budget".into();
        assert!(matches!(c.adapt_config().unwrap().policy, Policy::Budget));
    }

    #[test]
    fn fixed_policy_string() {
        let mut c = Config::default();
        c.adapt.policy = "fixed:8".into();
        assert!(matches!(c.adapt_config().unwrap().policy, Policy::Fixed(8)));
        c.adapt.policy = "bogus".into();
        assert!(c.adapt_config().is_err());
    }

    #[test]
    fn per_link_traces() {
        let mut c = Config::default();
        c.net.traces = vec!["0:100M".into(), "0:50M".into()];
        assert_eq!(c.trace_for_link(0).unwrap().at(0.0), 100e6);
        assert_eq!(c.trace_for_link(1).unwrap().at(0.0), 50e6);
        assert!(c.trace_for_link(2).is_err());
    }

    #[test]
    fn bad_method_rejected() {
        assert!(Config::parse(r#"{"quant": {"method": "zap"}}"#).is_err());
    }

    #[test]
    fn transport_section_parses() {
        let c = Config::parse("{}").unwrap();
        assert_eq!(c.transport.mode, "inproc");
        assert_eq!(c.transport.sink_addr, "127.0.0.1:7710");
        let text = r#"{
            "transport": {
                "mode": "tcp",
                "stage_addrs": ["10.0.0.1:9000", "10.0.0.2:9000", "10.0.0.3:9000"],
                "sink_addr": "10.0.0.100:9100",
                "connect_retry_ms": 50,
                "connect_timeout_ms": 3000
            }
        }"#;
        let c = Config::parse(text).unwrap();
        assert_eq!(c.transport.mode, "tcp");
        assert_eq!(c.transport.stage_addrs.len(), 3);
        assert_eq!(c.transport.stage_addrs[2], "10.0.0.3:9000");
        assert_eq!(c.transport.sink_addr, "10.0.0.100:9100");
        assert_eq!(c.transport.connect_retry(), Duration::from_millis(50));
        assert_eq!(c.transport.connect_timeout(), Duration::from_millis(3000));
        assert!(Config::parse(r#"{"transport": {"mode": "carrier-pigeon"}}"#).is_err());
    }

    #[test]
    fn stripes_knob_parses_validates_and_defaults() {
        let c = Config::parse("{}").unwrap();
        assert_eq!(c.transport.stripes, 1, "striping is opt-in");
        let c = Config::parse(
            r#"{"transport": {"mode": "tcp", "resilient": true, "stripes": 4}}"#,
        )
        .unwrap();
        assert_eq!(c.transport.stripes, 4);
        // Striping rides the resilient session protocol.
        assert!(Config::parse(r#"{"transport": {"stripes": 4}}"#).is_err());
        assert!(Config::parse(r#"{"transport": {"resilient": true, "stripes": 0}}"#).is_err());
    }

    #[test]
    fn scenario_knob_parses_validates_and_defaults() {
        let c = Config::parse("{}").unwrap();
        assert_eq!(c.transport.scenario, "none", "chaos is opt-in");
        assert_eq!(c.transport.scenario_seed, 0);
        let c = Config::parse(
            r#"{"transport": {"mode": "tcp", "resilient": true, "stripes": 3,
                "scenario": "cellular_fade", "scenario_seed": 42}}"#,
        )
        .unwrap();
        assert_eq!(c.transport.scenario, "cellular_fade");
        assert_eq!(c.transport.scenario_seed, 42);
        assert_eq!(
            c.transport.scenario_kind().unwrap(),
            crate::net::scenario::ScenarioKind::CellularFade
        );
        // Unknown names are rejected at parse time, loudly.
        let err = Config::parse(r#"{"transport": {"resilient": true, "scenario": "tsunami"}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tsunami") && err.contains("cellular_fade"), "{err}");
        // Chaos kills conduits; only resilient links survive that.
        assert!(Config::parse(r#"{"transport": {"scenario": "kill_storm"}}"#).is_err());
        // Shapers attach to sockets: an in-process run must reject a
        // scenario loudly instead of silently ignoring it.
        let err = Config::parse(
            r#"{"transport": {"resilient": true, "scenario": "kill_storm"}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("transport.mode"), "{err}");
    }

    #[test]
    fn telemetry_knob_defaults_on_and_parses() {
        let c = Config::parse("{}").unwrap();
        assert!(c.transport.telemetry, "telemetry is on by default");
        let c = Config::parse(r#"{"transport": {"telemetry": false}}"#).unwrap();
        assert!(!c.transport.telemetry);
    }

    #[test]
    fn reactor_pin_core_parses_validates_and_defaults() {
        let c = Config::parse("{}").unwrap();
        assert_eq!(c.transport.reactor_pin_core, -1, "pinning is opt-in");
        let c = Config::parse(r#"{"transport": {"reactor_pin_core": 3}}"#).unwrap();
        assert_eq!(c.transport.reactor_pin_core, 3);
        let c = Config::parse(r#"{"transport": {"reactor_pin_core": -1}}"#).unwrap();
        assert_eq!(c.transport.reactor_pin_core, -1);
        assert!(Config::parse(r#"{"transport": {"reactor_pin_core": -2}}"#).is_err());
    }

    #[test]
    fn resilience_knobs_parse_and_default() {
        let c = Config::parse("{}").unwrap();
        assert!(!c.transport.resilient, "resilience is opt-in");
        assert_eq!(c.transport.replay_capacity, 128);
        let text = r#"{
            "transport": {
                "mode": "tcp",
                "resilient": true,
                "replay_capacity": 32,
                "reconnect_timeout_ms": 2500,
                "backoff_base_ms": 5,
                "backoff_max_ms": 250
            }
        }"#;
        let c = Config::parse(text).unwrap();
        assert!(c.transport.resilient);
        let r = c.transport.resilience_config();
        assert_eq!(r.replay_capacity, 32);
        assert_eq!(r.reconnect_timeout, Duration::from_millis(2500));
        // First connect rides the startup budget, not the reconnect one.
        assert_eq!(r.initial_timeout, Duration::from_millis(10_000));
        assert_eq!(r.backoff_base, Duration::from_millis(5));
        assert_eq!(r.backoff_max, Duration::from_millis(250));
    }
}
