//! Minimal host tensor types for the request path.
//!
//! The coordinator only ever handles dense row-major f32 activations and
//! i32 code tensors, so a thin (data, shape) pair keeps the hot path free
//! of generic-tensor machinery. Conversion to/from `xla::Literal` lives in
//! [`crate::runtime`].

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Row-major element storage.
    pub data: Vec<f32>,
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
}

impl Tensor {
    /// Wrap a data buffer with its shape (lengths must agree).
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor { data, shape }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Take the data buffer back (capacity intact). The pipeline's stage
    /// loops recycle decoded activations through a one-slot pool —
    /// decode into the pooled buffer, wrap it in a `Tensor` by move, and
    /// reclaim it here after compute — so steady state does zero
    /// per-microbatch payload allocation (was a full `clone()` per frame).
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Size in bytes at full (f32) precision — the `V × 32/q` numerator of
    /// the paper's Eq. 2.
    pub fn byte_len(&self) -> usize {
        self.data.len() * 4
    }

    /// Leading dimension (microbatch size for stage inputs/outputs).
    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    /// Argmax over the trailing dimension of a rank-2 tensor (logits ->
    /// predicted classes).
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.shape.len(), 2, "argmax_rows expects rank-2");
        let cols = self.shape[1];
        self.data
            .chunks_exact(cols)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_sizes() {
        let t = Tensor::zeros(&[64, 16, 128]);
        assert_eq!(t.elems(), 64 * 16 * 128);
        assert_eq!(t.byte_len(), 64 * 16 * 128 * 4);
        assert_eq!(t.batch(), 64);
    }

    #[test]
    fn argmax_rows_basic() {
        let t = Tensor::new(vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0], vec![2, 3]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn argmax_handles_nan_free_ties() {
        let t = Tensor::new(vec![1.0, 1.0, 0.5, 0.5], vec![2, 2]);
        // max_by keeps the last max under Ordering::Equal -> deterministic.
        let am = t.argmax_rows();
        assert_eq!(am.len(), 2);
    }
}
