//! Support for the paper-reproduction benches (criterion is unavailable
//! offline): wall-clock timing with warmup + repeats, simple statistics,
//! aligned table printing, and shared experiment plumbing used by
//! `rust/benches/*.rs` and `examples/*.rs`.

use crate::config::Config;
use crate::data::EvalSet;
use crate::net::link::SimLink;
use crate::net::transport::LinkSpec;
use crate::pipeline::{hlo_stage_factory, LinkQuant, PipelineSpec};
use crate::runtime::Manifest;
use crate::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Time `f` with `warmup` + `iters` runs; returns (mean, min, max).
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (Duration, Duration, Duration) {
    for _ in 0..warmup {
        f();
    }
    let mut total = Duration::ZERO;
    let mut min = Duration::MAX;
    let mut max = Duration::ZERO;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        total += dt;
        min = min.min(dt);
        max = max.max(dt);
    }
    (total / iters.max(1) as u32, min, max)
}

/// Human duration.
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (cells align under the headers).
    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    /// Print to stdout with right-aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Load manifest + eval set from the default artifacts dir, with a clear
/// message if `make artifacts` hasn't run.
pub fn load_artifacts() -> Result<(Manifest, PathBuf, Arc<EvalSet>)> {
    let dir = Manifest::default_dir();
    let (manifest, dir) = Manifest::load(&dir)?;
    let eval = Arc::new(EvalSet::load(dir.join(&manifest.eval.file))?);
    Ok((manifest, dir, eval))
}

/// Spec over the real HLO stages with per-link traces.
pub fn hlo_spec(
    manifest: &Manifest,
    dir: &Path,
    cfg: &Config,
    traces: Vec<crate::net::trace::BandwidthTrace>,
    quant: LinkQuant,
    adapt: Option<crate::adapt::AdaptConfig>,
) -> PipelineSpec {
    let n = manifest.stages.len();
    assert_eq!(traces.len(), n - 1, "need one trace per link");
    let hlo_codec = cfg.pipeline.codec_backend == "hlo";
    PipelineSpec {
        stages: (0..n)
            .map(|i| hlo_stage_factory(dir.to_path_buf(), manifest.clone(), i, hlo_codec))
            .collect(),
        links: traces
            .into_iter()
            .map(|t| {
                LinkSpec::Sim(Arc::new(SimLink::with_faults(
                    t,
                    Duration::from_micros(cfg.net.latency_us),
                    cfg.link_faults(),
                )))
            })
            .collect(),
        quant,
        adapt,
        window: cfg.adapt.window,
        inflight: cfg.pipeline.inflight,
    }
}

/// Headline section printer for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Write a machine-readable bench result (`BENCH_<name>.json`): a flat
/// object of numeric fields plus an optional nested value (e.g. a
/// bits-sequence array). Non-finite numbers map to `null` — JSON has no
/// Infinity/NaN and downstream perf tooling must get a parseable
/// document. Returns the path written.
pub fn write_bench_json(
    name: &str,
    fields: &[(&str, f64)],
    extra: &[(&str, crate::util::json::Value)],
) -> Result<PathBuf> {
    use crate::util::json::Value;
    let mut m = std::collections::BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), Value::num_or_null(*v));
    }
    for (k, v) in extra {
        m.insert(k.to_string(), v.clone());
    }
    let path = PathBuf::from(format!("BENCH_{name}.json"));
    std::fs::write(&path, Value::Obj(m).to_string_pretty())?;
    Ok(path)
}

/// One-line drift summary of `fields` against a committed baseline JSON
/// (the text of a prior `write_bench_json` output). Fields are treated as
/// costs (ns/elem): ratio > 1 means the current run is slower. Returns
/// `None` when the baseline is unparseable or shares no finite fields.
pub fn delta_vs_baseline(baseline_json: &str, fields: &[(&str, f64)]) -> Option<String> {
    use crate::util::json::Value;
    let base = Value::parse(baseline_json).ok()?;
    let mut log_sum = 0f64;
    let mut n = 0usize;
    let mut worst: Option<(&str, f64)> = None;
    for (k, cur) in fields {
        let Some(b) = base.at(k).ok().and_then(|v| v.as_f64().ok()) else { continue };
        if !(b > 0.0 && cur.is_finite() && *cur > 0.0) {
            continue;
        }
        let ratio = cur / b;
        log_sum += ratio.ln();
        n += 1;
        if worst.is_none_or(|(_, w)| ratio > w) {
            worst = Some((k, ratio));
        }
    }
    let (wk, wr) = worst?;
    Some(format!(
        "geomean {:.2}x of baseline over {n} fields (worst: {wk} {wr:.2}x)",
        (log_sum / n as f64).exp()
    ))
}

/// Print the [`delta_vs_baseline`] line against the checked-in
/// `BENCH_<name>.json` at the crate root, so every bench run ends with a
/// one-line answer to "did this change move the needle?". Baselines come
/// from a different machine, so this is a narrative aid, not a gate.
pub fn print_delta_vs_committed(name: &str, fields: &[(&str, f64)]) {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{name}.json"));
    match std::fs::read_to_string(&path).ok().as_deref().and_then(|t| delta_vs_baseline(t, fields))
    {
        Some(line) => println!("vs committed {}: {line}", path.display()),
        None => println!("no comparable committed baseline at {}", path.display()),
    }
}

/// Hard perf gate against a baseline JSON (the text of a prior
/// [`write_bench_json`] output). Every field is treated as a COST —
/// wall seconds, latency quantiles, ns/elem — so pass only
/// lower-is-better numbers; the gate fails if any shared finite field
/// regresses past `cur / baseline > max_ratio`, listing every violation.
/// Fields absent from the baseline are skipped (a new metric must not
/// fail old baselines).
pub fn gate_vs_baseline(
    baseline_json: &str,
    fields: &[(&str, f64)],
    max_ratio: f64,
) -> Result<()> {
    use crate::util::json::Value;
    anyhow::ensure!(
        max_ratio.is_finite() && max_ratio > 0.0,
        "bench gate wants a positive finite max ratio, got {max_ratio}"
    );
    let base = Value::parse(baseline_json)?;
    let mut violations = Vec::new();
    for (k, cur) in fields {
        let Some(b) = base.at(k).ok().and_then(|v| v.as_f64().ok()) else { continue };
        if !(b > 0.0 && cur.is_finite() && *cur > 0.0) {
            continue;
        }
        let ratio = cur / b;
        if ratio > max_ratio {
            violations.push(format!("{k} {ratio:.2}x of baseline ({cur:.4} vs {b:.4})"));
        }
    }
    anyhow::ensure!(
        violations.is_empty(),
        "bench gate (max {max_ratio:.2}x) failed: {}",
        violations.join("; ")
    );
    Ok(())
}

/// [`gate_vs_baseline`] against the checked-in `BENCH_<name>.json` at the
/// crate root. A missing or unparseable baseline passes with a notice —
/// a fresh checkout must not fail its first bench run — but a present
/// baseline gates hard.
pub fn gate_vs_committed(name: &str, fields: &[(&str, f64)], max_ratio: f64) -> Result<()> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{name}.json"));
    let Ok(text) = std::fs::read_to_string(&path) else {
        println!("bench gate: no committed baseline at {} (pass)", path.display());
        return Ok(());
    };
    if crate::util::json::Value::parse(&text).is_err() {
        println!("bench gate: unparseable baseline at {} (pass)", path.display());
        return Ok(());
    }
    gate_vs_baseline(&text, fields, max_ratio)
        .map_err(|e| e.context(format!("vs committed {}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_ordered_stats() {
        let (mean, min, max) = time(1, 5, || std::thread::sleep(Duration::from_micros(200)));
        assert!(min <= mean && mean <= max);
        assert!(min >= Duration::from_micros(150));
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "x".into()]);
        t.print();
    }

    #[test]
    fn bench_json_is_parseable_and_maps_nonfinite_to_null() {
        use crate::util::json::Value;
        // Written to the cwd like a real bench run; cleaned up after.
        let path = write_bench_json(
            "benchkit_selftest",
            &[("throughput", 123.5), ("bandwidth", f64::INFINITY)],
            &[("bits", Value::Arr(vec![Value::Num(32.0), Value::Num(8.0)]))],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let back = Value::parse(&text).unwrap();
        assert_eq!(back.at("throughput").unwrap().as_f64().unwrap(), 123.5);
        assert_eq!(back.at("bandwidth").unwrap(), &Value::Null);
        assert_eq!(back.at("bits").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn baseline_delta_reports_geomean_and_worst_field() {
        let baseline = r#"{"enc": 10.0, "dec": 4.0, "skipme": null, "other": 1.0}"#;
        // enc 2x slower, dec 0.5x: geomean = 1.0; worst = enc.
        let line =
            delta_vs_baseline(baseline, &[("enc", 20.0), ("dec", 2.0), ("new_field", 9.9)])
                .unwrap();
        assert!(line.contains("1.00x"), "{line}");
        assert!(line.contains("worst: enc 2.00x"), "{line}");
        assert!(line.contains("over 2 fields"), "{line}");
        // Unparseable or disjoint baselines degrade to None, not a panic.
        assert!(delta_vs_baseline("not json", &[("enc", 1.0)]).is_none());
        assert!(delta_vs_baseline(baseline, &[("unrelated", 1.0)]).is_none());
    }

    #[test]
    fn gate_passes_within_ratio_and_fails_past_it() {
        let baseline = r#"{"wall_secs": 10.0, "p99_latency_s": 0.5, "skipme": null}"#;
        // 1.4x on the worst field, gate at 1.5x: pass.
        gate_vs_baseline(baseline, &[("wall_secs", 14.0), ("p99_latency_s", 0.4)], 1.5).unwrap();
        // 1.6x on wall_secs: fail, naming the field and the ratio.
        let err = gate_vs_baseline(baseline, &[("wall_secs", 16.0), ("p99_latency_s", 0.4)], 1.5)
            .unwrap_err()
            .to_string();
        assert!(err.contains("wall_secs 1.60x"), "{err}");
        assert!(err.contains("max 1.50x"), "{err}");
        // Fields the baseline lacks are skipped, never a failure.
        gate_vs_baseline(baseline, &[("brand_new_metric", 1e9)], 1.5).unwrap();
        // A nonsense threshold is a loud error, not a silent pass.
        assert!(gate_vs_baseline(baseline, &[("wall_secs", 1.0)], f64::NAN).is_err());
        // An unparseable baseline is an error here (gate_vs_committed is
        // the lenient entry point for missing/rotten files).
        assert!(gate_vs_baseline("not json", &[("wall_secs", 1.0)], 1.5).is_err());
    }

    #[test]
    fn gate_vs_committed_passes_when_no_baseline_exists() {
        gate_vs_committed("no_such_bench_baseline", &[("wall_secs", 1.0)], 1.5).unwrap();
    }
}
