//! Bitwidth selection policies.

/// How the controller maps a required compression ratio to a bitwidth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// The paper's Eq. 2, literally: `q = 32 / 2^ceil(log2(ratio))` —
    /// powers of two only.
    Eq2,
    /// Highest supported bitwidth `{2,4,6,8,16,32}` whose volume fits the
    /// budget (the behaviour Fig 5 actually exhibits; includes 6-bit).
    #[default]
    Ladder,
    /// Pin a bitwidth (baselines/ablations).
    Fixed(u8),
    /// Ladder for the discrete decision, plus a continuous per-boundary
    /// *bit budget* (`Decision::avg_bits`) once the link drops into the
    /// sub-byte regime. The tiled codec spends that budget non-uniformly
    /// across tiles ([`crate::quant::tile`]), so e.g. a ratio of 6.5
    /// yields tiles averaging 4.9 bits instead of a uniform 4 — every
    /// wire byte the link affords actually gets used.
    Budget,
}

/// Continuous width the link budget affords: `32 / ratio`, clamped to
/// the tiled allocator's `[2, 8]` range. Only meaningful once the
/// discrete ladder has dropped to 8 bits or below.
pub fn budget_avg_bits(ratio: f64) -> f32 {
    ((32.0 / ratio.max(1e-300)) as f32).clamp(2.0, 8.0)
}

/// Supported ladder, descending (32 = no quantization).
pub const LADDER: [u8; 6] = [32, 16, 8, 6, 4, 2];

/// Eq. 2: required compression `ratio` → power-of-two bitwidth.
/// `ratio ≤ 1` means the link already fits full precision.
pub fn required_bits_eq2(ratio: f64) -> u8 {
    if !ratio.is_finite() {
        return 2;
    }
    if ratio <= 1.0 {
        return 32;
    }
    let e = ratio.log2().ceil() as i32; // compression exponent ≥ 1
    let bits = 32.0 / 2f64.powi(e);
    // Quantization floor: 2-bit is the smallest representable width.
    bits.max(2.0) as u8
}

/// One ladder step below `bits` (2-bit floor).
pub fn ladder_step_down(bits: u8) -> u8 {
    let idx = LADDER.iter().position(|&b| b == bits).unwrap_or(0);
    LADDER[(idx + 1).min(LADDER.len() - 1)]
}

/// Ladder: highest supported width with `width/32 ≤ 1/ratio`.
pub fn required_bits_ladder(ratio: f64) -> u8 {
    if !ratio.is_finite() {
        return 2;
    }
    for &b in LADDER.iter() {
        if (b as f64) / 32.0 <= 1.0 / ratio.max(1e-300) {
            return b;
        }
    }
    2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_table() {
        assert_eq!(required_bits_eq2(0.0), 32);
        assert_eq!(required_bits_eq2(1.0), 32);
        assert_eq!(required_bits_eq2(1.5), 16);
        assert_eq!(required_bits_eq2(2.0), 16);
        assert_eq!(required_bits_eq2(3.0), 8);
        assert_eq!(required_bits_eq2(4.0), 8);
        assert_eq!(required_bits_eq2(7.9), 4);
        assert_eq!(required_bits_eq2(16.0), 2);
        assert_eq!(required_bits_eq2(1e9), 2);
        assert_eq!(required_bits_eq2(f64::INFINITY), 2);
    }

    #[test]
    fn ladder_table() {
        assert_eq!(required_bits_ladder(0.5), 32);
        assert_eq!(required_bits_ladder(1.0), 32);
        assert_eq!(required_bits_ladder(1.01), 16);
        assert_eq!(required_bits_ladder(2.0), 16);
        assert_eq!(required_bits_ladder(3.9), 8);
        assert_eq!(required_bits_ladder(4.0), 8);
        assert_eq!(required_bits_ladder(5.0), 6);   // the Fig 5 step
        assert_eq!(required_bits_ladder(32.0 / 6.0), 6);
        assert_eq!(required_bits_ladder(6.0), 4);
        assert_eq!(required_bits_ladder(8.0), 4);
        assert_eq!(required_bits_ladder(16.0), 2);
        assert_eq!(required_bits_ladder(100.0), 2);
    }

    #[test]
    fn ladder_never_exceeds_budget() {
        for i in 0..1000 {
            let ratio = 0.1 + i as f64 * 0.05;
            let b = required_bits_ladder(ratio);
            // 2-bit is the quantization floor: beyond ratio 16 the budget
            // is simply unreachable and the ladder bottoms out.
            if b < 32 && ratio <= 16.0 {
                assert!(
                    (b as f64) / 32.0 <= 1.0 / ratio + 1e-12,
                    "ratio={ratio} bits={b}"
                );
            }
            if ratio > 16.0 {
                assert_eq!(b, 2, "ratio={ratio}");
            }
        }
    }

    #[test]
    fn budget_avg_tracks_the_ratio() {
        // ratio 6.5536 (the 1 Mbps Fig-5 window): uniform ladder says 4,
        // the continuous budget affords 4.88 average bits.
        let a = budget_avg_bits(6.5536);
        assert!((a - 4.8828).abs() < 1e-3, "{a}");
        // Clamps: huge ratio floors at 2, tiny ratio ceils at 8.
        assert_eq!(budget_avg_bits(1e9), 2.0);
        assert_eq!(budget_avg_bits(f64::INFINITY), 2.0);
        assert_eq!(budget_avg_bits(1.0), 8.0);
        assert_eq!(budget_avg_bits(0.0), 8.0);
    }

    #[test]
    fn eq2_at_least_as_aggressive_as_ladder() {
        // Eq2 skips 6-bit, so it must always pick ≤ the ladder's choice.
        for i in 0..1000 {
            let ratio = 0.1 + i as f64 * 0.1;
            assert!(required_bits_eq2(ratio) <= required_bits_ladder(ratio));
        }
    }
}
