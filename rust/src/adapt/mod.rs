//! Adaptive PDA module (paper §3, Eq. 2): pick the quantization bitwidth
//! that achieves the target output rate `R` under the measured bandwidth.
//!
//! ```text
//! q_{k,t+1} = 32 / 2^ceil( log2( (V·32/q_{k,t}) / ((S/R) · B_{k,t}) ) )   (Eq. 2)
//! ```
//!
//! `V·32/q` recovers the full-precision volume of one microbatch from the
//! measured quantized volume `V`; `(S/R)·B` is how much the link can move
//! in one microbatch's time budget. The ratio is the required compression
//! factor, rounded up to a power of two.
//!
//! Eq. 2 yields only power-of-two bitwidths {32,16,8,4,2}, yet the paper's
//! own Fig 5 shows a 6-bit step — their deployed system snaps to a ladder
//! of *supported* bitwidths. We implement both:
//! * [`Policy::Eq2`] — the literal equation;
//! * [`Policy::Ladder`] — highest supported bitwidth whose volume fits the
//!   budget (the deployed behaviour; default), with the same "maximize
//!   bitwidth subject to the rate constraint" objective.
//!
//! A hysteresis margin avoids bitwidth flapping when the measurement sits
//! exactly at a boundary (the Fig 5 "measurement latency" wobble).

pub mod policy;

pub use policy::{
    budget_avg_bits, ladder_step_down, required_bits_eq2, required_bits_ladder, Policy,
};

use crate::monitor::WindowStats;
use crate::quant::BITS_NONE;

/// Controller configuration (paper defaults: window 50, S = 64).
#[derive(Debug, Clone, Copy)]
pub struct AdaptConfig {
    /// Target output rate R, images/sec.
    pub target_rate: f64,
    /// Microbatch size S, images.
    pub microbatch: usize,
    /// Bitwidth selection policy.
    pub policy: Policy,
    /// Only raise the bitwidth if the higher width fits the budget with
    /// this much headroom (1.0 = none). Lowering is immediate.
    pub raise_margin: f64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        AdaptConfig {
            target_rate: 100.0,
            microbatch: 64,
            policy: Policy::Ladder,
            raise_margin: 1.1,
        }
    }
}

/// A bitwidth decision with its inputs, for logging/Fig 5 timelines.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    /// Bitwidth to use from now on.
    pub bits: u8,
    /// Bitwidth before this decision.
    pub prev_bits: u8,
    /// Window's measured bandwidth (bits/s).
    pub measured_bps: f64,
    /// Eq. 2 compression ratio demanded by the window.
    pub required_compression: f64,
    /// Did the bitwidth move?
    pub changed: bool,
    /// Continuous per-boundary bit budget ([`Policy::Budget`] only, and
    /// only once the discrete width is ≤ 8): the tiled codec allocates
    /// {2,4,6,8}-bit tiles averaging at most this. `None` = uniform.
    pub avg_bits: Option<f32>,
}

/// The adaptive PDA controller for one stage's output link.
#[derive(Debug, Clone)]
pub struct AdaptivePda {
    cfg: AdaptConfig,
    bits: u8,
}

impl AdaptivePda {
    /// Controller with no decision yet (starts at `BITS_NONE`).
    pub fn new(cfg: AdaptConfig) -> Self {
        AdaptivePda { cfg, bits: BITS_NONE }
    }

    /// Bitwidth currently in effect.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// The configuration this controller runs.
    pub fn config(&self) -> &AdaptConfig {
        &self.cfg
    }

    /// Feed one completed window; returns the (possibly unchanged) decision.
    pub fn on_window(&mut self, w: &WindowStats) -> Decision {
        let prev = self.bits;
        // Recover the full-precision per-microbatch volume from the
        // measured quantized volume (Eq. 2's V · 32/q term).
        let full_bits = w.mean_bytes * 8.0 * (32.0 / prev as f64);
        // Budget: what the link moves in one microbatch period at target R.
        let budget_bits = (self.cfg.microbatch as f64 / self.cfg.target_rate) * w.bandwidth_bps;

        // Unconstrained when the budget itself is infinite, OR when the
        // budget degenerated to <= 0 (S = 0, R = inf) on a link that
        // measures infinite bandwidth — an unconstrained link must never
        // be punished for a meaningless budget. A zero/negative budget on
        // a *finite* link is the opposite: nothing fits, full compression.
        let unconstrained =
            budget_bits.is_infinite() || (budget_bits <= 0.0 && w.bandwidth_bps.is_infinite());
        let ratio = if unconstrained {
            0.0
        } else if budget_bits <= 0.0 {
            f64::INFINITY
        } else {
            full_bits / budget_bits
        };

        let proposal = match self.cfg.policy {
            Policy::Eq2 => required_bits_eq2(ratio),
            Policy::Ladder | Policy::Budget => required_bits_ladder(ratio),
            Policy::Fixed(b) => b,
        };

        // Rate-violation trigger (§4.2: "QuantPipe measures that the output
        // rate falls below the constraint value"): if the achieved rate
        // misses the target while the link is saturated, step down one
        // ladder notch even when the bandwidth arithmetic says the current
        // width fits — the arithmetic is a model; the rate is ground truth.
        let rate_violated = w.rate < self.cfg.target_rate * 0.95 && w.link_utilization > 0.9;
        let proposal = if rate_violated && proposal >= prev && !matches!(self.cfg.policy, Policy::Fixed(_)) {
            ladder_step_down(prev)
        } else {
            proposal
        };

        // Hysteresis: lowering (congestion) is immediate; raising requires
        // the new width to fit with margin.
        let next = if proposal > prev {
            let with_margin = match self.cfg.policy {
                Policy::Eq2 => required_bits_eq2(ratio * self.cfg.raise_margin),
                Policy::Ladder | Policy::Budget => {
                    required_bits_ladder(ratio * self.cfg.raise_margin)
                }
                Policy::Fixed(b) => b,
            };
            if with_margin >= proposal {
                proposal
            } else {
                prev
            }
        } else {
            proposal
        };

        // Budget mode: alongside the discrete ladder width, publish the
        // *continuous* width the link affords. The discrete pick is the
        // largest supported uniform width under the budget; the tiled
        // allocator can average strictly more by mixing widths (e.g.
        // ratio 6.5 ⇒ ladder 4, budget average 4.88). A rate violation
        // caps the average at the stepped-down width — the ratio said
        // the old width fit, and the measured rate proved it wrong.
        let avg_bits = match self.cfg.policy {
            Policy::Budget if next <= 8 => {
                let a = budget_avg_bits(ratio);
                Some(if rate_violated { a.min(next as f32) } else { a })
            }
            _ => None,
        };

        self.bits = next;
        Decision {
            bits: next,
            prev_bits: prev,
            measured_bps: w.bandwidth_bps,
            required_compression: ratio,
            changed: next != prev,
            avg_bits,
        }
    }

    /// Force a bitwidth (tests / static-config deployments).
    pub fn set_bits(&mut self, bits: u8) {
        self.bits = bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(mean_bytes: f64, bandwidth_bps: f64) -> WindowStats {
        WindowStats {
            bandwidth_bps,
            rate: f64::INFINITY, // rate constraint satisfied by default
            mean_bytes,
            microbatches: 50,
            wall_secs: 1.0,
            link_utilization: 1.0,
        }
    }

    // Paper-like numbers: 64×16×128 f32 activation = 524288 B ≈ 4.19 Mbit
    // per microbatch; R = 100 img/s, S = 64 ⇒ 0.64 s budget per microbatch.
    const FULL_BYTES: f64 = 524288.0;

    fn ctl(policy: Policy) -> AdaptivePda {
        AdaptivePda::new(AdaptConfig { target_rate: 100.0, microbatch: 64, policy, raise_margin: 1.0 })
    }

    #[test]
    fn unlimited_bandwidth_means_no_quant() {
        let mut c = ctl(Policy::Ladder);
        let d = c.on_window(&window(FULL_BYTES, f64::INFINITY));
        assert_eq!(d.bits, 32);
        assert!(!d.changed);
    }

    #[test]
    fn zero_budget_window_forces_full_compression() {
        // Degenerate budget on a FINITE link (S = 0 ⇒ budget_bits = 0):
        // nothing fits in a zero budget, so the ratio is infinite and the
        // controller floors the bitwidth. The unconstrained-link escape
        // must NOT fire here — the link is measurably finite.
        let mut c = AdaptivePda::new(AdaptConfig {
            target_rate: 100.0,
            microbatch: 0,
            policy: Policy::Ladder,
            raise_margin: 1.0,
        });
        c.set_bits(32);
        let d = c.on_window(&window(FULL_BYTES, 50e6));
        assert_eq!(d.bits, 2, "{d:?}");
        assert!(d.required_compression.is_infinite(), "{d:?}");
    }

    #[test]
    fn infinite_bandwidth_window_is_unconstrained() {
        // An unconstrained link (never measurably busy ⇒ bandwidth = inf)
        // must settle at full precision regardless of the current width.
        let mut c = ctl(Policy::Ladder);
        c.set_bits(4);
        let d = c.on_window(&window(FULL_BYTES * 4.0 / 32.0, f64::INFINITY));
        assert_eq!(d.bits, 32, "{d:?}");
        assert_eq!(d.required_compression, 0.0, "{d:?}");
    }

    #[test]
    fn zero_bandwidth_window_floors_the_bitwidth() {
        // A dead link (measured bandwidth 0 ⇒ budget 0) cannot carry any
        // volume: shed to the 2-bit floor immediately, never divide by
        // zero into NaN.
        let mut c = ctl(Policy::Ladder);
        c.set_bits(32);
        let d = c.on_window(&window(FULL_BYTES, 0.0));
        assert_eq!(d.bits, 2, "{d:?}");
        assert!(d.required_compression.is_infinite(), "{d:?}");
    }

    #[test]
    fn fig5_phase_sequence() {
        // Phase 1: 400 Mbps. full = 4.19 Mb, budget = 0.64 × 400e6 = 256 Mb
        // ⇒ ratio ≈ 0.016 ⇒ 32-bit still fine… the paper's Fig 5 shows a
        // drop to 16-bit at 400 Mbps because *wall-clock* budget includes
        // compute; with S/R = 0.64 s the link is not the constraint. Use
        // the paper's actual regime: R = 100 img/s with ~0.1 s budget ⇒
        // microbatch budget chosen so 400 Mbps ⇒ 16-bit.
        let mut c = AdaptivePda::new(AdaptConfig {
            target_rate: 1000.0, // tighter budget: 0.064 s per microbatch
            microbatch: 64,
            policy: Policy::Ladder,
            raise_margin: 1.0,
        });
        // 400 Mbps: budget = 0.064 × 400e6 = 25.6 Mb; full = 33.5 Mb ⇒ ratio 1.31 ⇒ 16-bit.
        let d = c.on_window(&window(FULL_BYTES * 8.0, 400e6));
        assert_eq!(d.bits, 16, "{d:?}");
        // 50 Mbps: V now 16-bit (half volume). full = 33.5 Mb, budget = 3.2 Mb ⇒ ratio 10.5 ⇒ 2-bit.
        let d = c.on_window(&window(FULL_BYTES * 8.0 / 2.0, 50e6));
        assert_eq!(d.bits, 2, "{d:?}");
        // 200 Mbps: budget 12.8 Mb ⇒ ratio 2.62 ⇒ 8-bit fits (33.5/4 = 8.4 < 12.8: yes).
        let d = c.on_window(&window(FULL_BYTES * 8.0 / 16.0, 200e6));
        assert_eq!(d.bits, 8, "{d:?}");
        // Unlimited: back to 32.
        let d = c.on_window(&window(FULL_BYTES * 8.0 / 4.0, f64::INFINITY));
        assert_eq!(d.bits, 32, "{d:?}");
    }

    #[test]
    fn eq2_yields_powers_of_two_only() {
        let mut c = ctl(Policy::Eq2);
        for bw in [1e6, 5e6, 20e6, 80e6, 320e6, 1.28e9] {
            let d = c.on_window(&window(FULL_BYTES * (c.bits() as f64 / 32.0).max(0.0625), bw));
            assert!([2u8, 4, 8, 16, 32].contains(&d.bits), "{d:?}");
        }
    }

    #[test]
    fn ladder_can_pick_6_bits() {
        // Engineer a ratio in (4, 16/3]: 6-bit fits, 8-bit doesn't.
        let mut c = ctl(Policy::Ladder);
        c.set_bits(32);
        // ratio = full/budget = 5 ⇒ need q ≤ 32/5 = 6.4 ⇒ ladder picks 6.
        let full_bits = FULL_BYTES * 8.0;
        let budget = full_bits / 5.0;
        let bw = budget / 0.64;
        let d = c.on_window(&window(FULL_BYTES, bw));
        assert_eq!(d.bits, 6, "{d:?}");
    }

    #[test]
    fn volume_recovery_is_bitwidth_invariant() {
        // The same underlying tensor measured at different current bitwidths
        // must produce the same decision.
        for cur in [32u8, 16, 8, 4, 2] {
            let mut c = ctl(Policy::Ladder);
            c.set_bits(cur);
            let v = FULL_BYTES * cur as f64 / 32.0;
            let d = c.on_window(&window(v, 50e6));
            let mut c2 = ctl(Policy::Ladder);
            c2.set_bits(32);
            let d2 = c2.on_window(&window(FULL_BYTES, 50e6));
            assert_eq!(d.bits, d2.bits, "cur={cur}");
        }
    }

    #[test]
    fn hysteresis_blocks_marginal_raise() {
        let mut cfg = AdaptConfig::default();
        cfg.raise_margin = 1.25;
        cfg.target_rate = 100.0;
        let mut c = AdaptivePda::new(cfg);
        c.set_bits(8);
        // Ratio that BARELY admits 16-bit (16 fits at margin 1.0 but not 1.25).
        let full_bits = FULL_BYTES * 8.0;
        let budget = full_bits / 1.9; // 16-bit needs ratio ≤ 2
        let bw = budget / 0.64;
        let d = c.on_window(&window(FULL_BYTES * 0.25, bw));
        assert_eq!(d.bits, 8, "marginal raise should be held: {d:?}");
        // Lowering under congestion is immediate (no margin applied):
        // full = 4.19 Mb, budget = 0.64 Mb ⇒ ratio 6.55 ⇒ 4-bit.
        let d = c.on_window(&window(FULL_BYTES * 0.25, 1e6));
        assert_eq!(d.bits, 4, "{d:?}");
    }

    #[test]
    fn rate_violation_steps_down() {
        // Bandwidth arithmetic says 32-bit fits, but the achieved rate
        // misses the target on a saturated link -> step down one notch.
        let mut c = ctl(Policy::Ladder);
        c.set_bits(32);
        let mut w = window(FULL_BYTES, 60e6); // budget 38.4 Mb >> full 4.2 Mb
        w.rate = 50.0; // target is 100
        w.link_utilization = 1.0;
        let d = c.on_window(&w);
        assert_eq!(d.bits, 16, "{d:?}");
        // Again: steps to 8.
        let mut w2 = window(FULL_BYTES / 2.0, 60e6);
        w2.rate = 50.0;
        assert_eq!(c.on_window(&w2).bits, 8);
        // Rate recovered: bandwidth math takes over and raises again.
        let w3 = window(FULL_BYTES / 4.0, 60e6);
        assert_eq!(c.on_window(&w3).bits, 32);
    }

    #[test]
    fn rate_violation_needs_saturated_link() {
        // Rate misses but the link is idle (compute-bound): quantizing
        // cannot help, so hold the width.
        let mut c = ctl(Policy::Ladder);
        c.set_bits(32);
        let mut w = window(FULL_BYTES, f64::INFINITY);
        w.rate = 50.0;
        w.link_utilization = 0.1;
        assert_eq!(c.on_window(&w).bits, 32);
    }

    #[test]
    fn budget_policy_publishes_a_continuous_average() {
        let mut c = ctl(Policy::Budget);
        c.set_bits(32);
        // Unconstrained: full precision, no budget in play.
        let d = c.on_window(&window(FULL_BYTES, f64::INFINITY));
        assert_eq!(d.bits, 32);
        assert!(d.avg_bits.is_none());
        // 1 Mbps: ratio 6.5536 ⇒ ladder 4-bit, but the budget affords an
        // average of 32/6.5536 ≈ 4.88 — strictly more than uniform 4.
        let d = c.on_window(&window(FULL_BYTES, 1e6));
        assert_eq!(d.bits, 4, "{d:?}");
        let avg = d.avg_bits.unwrap();
        assert!((avg - 4.8828).abs() < 1e-3, "{avg}");
        assert!(avg > d.bits as f32, "budget average beats the uniform pick");
        // Dead link: both floor at 2.
        let d = c.on_window(&window(FULL_BYTES * 4.0 / 32.0, 0.0));
        assert_eq!(d.bits, 2);
        assert_eq!(d.avg_bits, Some(2.0));
    }

    #[test]
    fn budget_average_absent_above_8_bits() {
        // At 16/32-bit the codec runs flat — no tiled budget to publish.
        let mut c = ctl(Policy::Budget);
        c.set_bits(32);
        let full_bits = FULL_BYTES * 8.0;
        let bw = (full_bits / 1.5) / 0.64; // ratio 1.5 ⇒ 16-bit
        let d = c.on_window(&window(FULL_BYTES, bw));
        assert_eq!(d.bits, 16, "{d:?}");
        assert!(d.avg_bits.is_none());
    }

    #[test]
    fn budget_average_capped_by_rate_violation() {
        // The ratio claims plenty of headroom but the measured rate says
        // otherwise: the discrete width steps down and the average must
        // not exceed it (the ratio has been proven optimistic).
        let mut c = ctl(Policy::Budget);
        c.set_bits(6);
        let mut w = window(FULL_BYTES * 6.0 / 32.0, 60e6);
        w.rate = 50.0;
        w.link_utilization = 1.0;
        let d = c.on_window(&w);
        assert_eq!(d.bits, 4, "{d:?}");
        assert_eq!(d.avg_bits, Some(4.0), "{d:?}");
    }

    #[test]
    fn fixed_policy_never_moves() {
        let mut c = ctl(Policy::Fixed(8));
        for bw in [1e5, 1e9, f64::INFINITY] {
            assert_eq!(c.on_window(&window(FULL_BYTES, bw)).bits, 8);
        }
    }
}
