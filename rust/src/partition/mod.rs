//! PipeEdge-style optimal model partitioning ([15], Hu et al. DSD'22).
//!
//! Given per-block compute costs on each device and the communication cost
//! of cutting between blocks, choose contiguous block ranges (one per
//! device, in order) minimizing the pipeline bottleneck — the max over
//! stages of `compute(stage) + comm(outgoing cut)` — since steady-state
//! pipeline throughput is `1 / max_stage_time` (§2: "the overall
//! performance is bounded by the slowest stage").
//!
//! Solved exactly by binary search on the bottleneck T with a greedy
//! feasibility check (each device takes the longest prefix that fits T),
//! which is optimal for contiguous partitioning with monotone costs;
//! `partition_dp` is the O(n²·k) reference DP used to cross-check in
//! tests.

pub mod profile;

pub use profile::CostModel;

/// A partition: `cuts[i] = (lo, hi)` — device `i` runs blocks `lo..hi`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// `cuts[i] = (lo, hi)`: device `i` runs blocks `lo..hi`.
    pub cuts: Vec<(usize, usize)>,
}

impl Partition {
    /// Bottleneck stage time under `costs` (seconds).
    pub fn bottleneck(&self, costs: &CostModel) -> f64 {
        self.cuts
            .iter()
            .enumerate()
            .map(|(d, &(lo, hi))| costs.stage_time(d, lo, hi, hi < costs.blocks()))
            .fold(0.0, f64::max)
    }

    /// Steady-state pipeline throughput estimate, items/sec.
    pub fn throughput(&self, costs: &CostModel) -> f64 {
        1.0 / self.bottleneck(costs).max(1e-12)
    }
}

/// Feasibility: can `blocks` be split across `devices` with bottleneck ≤ t?
fn feasible(costs: &CostModel, devices: usize, t: f64) -> Option<Partition> {
    let n = costs.blocks();
    let mut cuts = Vec::with_capacity(devices);
    let mut lo = 0;
    for d in 0..devices {
        if lo == n {
            break;
        }
        // Longest prefix from `lo` that fits in t on device d.
        let mut hi = lo;
        let remaining_devices = devices - d - 1;
        while hi < n {
            let cand = hi + 1;
            let has_cut = cand < n;
            if costs.stage_time(d, lo, cand, has_cut) <= t {
                hi = cand;
            } else {
                break;
            }
        }
        if hi == lo {
            return None; // single block exceeds t on this device
        }
        // Leave at least one block per remaining device.
        let max_hi = n - remaining_devices;
        hi = hi.min(max_hi.max(lo + 1));
        cuts.push((lo, hi));
        lo = hi;
    }
    if lo == n && !cuts.is_empty() {
        Some(Partition { cuts })
    } else {
        None
    }
}

/// Optimal contiguous partition by binary search on the bottleneck.
pub fn partition(costs: &CostModel, devices: usize) -> Partition {
    let n = costs.blocks();
    let devices = devices.min(n).max(1);
    // Bounds: lo = max single-block time, hi = total on slowest device.
    let mut lo = 0f64;
    let mut hi = 0f64;
    for d in 0..devices {
        let mut tot = 0.0;
        for b in 0..n {
            let t = costs.stage_time(d, b, b + 1, true);
            lo = lo.max(t * 0.0); // keep lo at 0; greedy check handles the rest
            tot += t;
        }
        hi = hi.max(tot);
    }
    let mut best = feasible(costs, devices, hi).expect("total time must be feasible");
    for _ in 0..64 {
        let mid = (lo + hi) / 2.0;
        match feasible(costs, devices, mid) {
            Some(p) => {
                hi = mid;
                best = p;
            }
            None => lo = mid,
        }
    }
    best
}

/// Reference O(n²·k) DP (minimize bottleneck), for cross-checking.
pub fn partition_dp(costs: &CostModel, devices: usize) -> Partition {
    let n = costs.blocks();
    let k = devices.min(n).max(1);
    // dp[d][i] = min bottleneck splitting blocks[..i] over first d devices.
    let mut dp = vec![vec![f64::INFINITY; n + 1]; k + 1];
    let mut back = vec![vec![0usize; n + 1]; k + 1];
    dp[0][0] = 0.0;
    for d in 1..=k {
        for i in 1..=n {
            for j in 0..i {
                if dp[d - 1][j].is_finite() {
                    let t = costs.stage_time(d - 1, j, i, i < n);
                    let b = dp[d - 1][j].max(t);
                    if b < dp[d][i] {
                        dp[d][i] = b;
                        back[d][i] = j;
                    }
                }
            }
        }
    }
    // Use however many devices achieve the best bottleneck for all n blocks.
    let (best_d, _) = (1..=k)
        .map(|d| (d, dp[d][n]))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap();
    let mut cuts = Vec::new();
    let mut i = n;
    let mut d = best_d;
    while d > 0 {
        let j = back[d][i];
        cuts.push((j, i));
        i = j;
        d -= 1;
    }
    cuts.reverse();
    Partition { cuts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use profile::CostModel;

    fn uniform_costs(blocks: usize, devices: usize, block_s: f64, comm_s: f64) -> CostModel {
        CostModel::uniform(blocks, devices, block_s, comm_s)
    }

    #[test]
    fn even_split_for_uniform_costs() {
        let c = uniform_costs(8, 4, 1.0, 0.1);
        let p = partition(&c, 4);
        assert_eq!(p.cuts.len(), 4);
        let sizes: Vec<usize> = p.cuts.iter().map(|&(a, b)| b - a).collect();
        assert_eq!(sizes, vec![2, 2, 2, 2]);
    }

    #[test]
    fn matches_reference_dp() {
        // Heterogeneous: device speeds vary, comm costs vary.
        for seed in 0..20u64 {
            let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
            let mut next = || {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s >> 11) as f64 / (1u64 << 53) as f64
            };
            let blocks = 6 + (seed as usize % 6);
            let devices = 2 + (seed as usize % 3);
            let block_times: Vec<Vec<f64>> = (0..devices)
                .map(|_| (0..blocks).map(|_| 0.5 + next()).collect())
                .collect();
            let comm: Vec<f64> = (0..blocks).map(|_| next() * 0.5).collect();
            let c = CostModel::new(block_times, comm);
            let a = partition(&c, devices).bottleneck(&c);
            let b = partition_dp(&c, devices).bottleneck(&c);
            assert!(
                (a - b).abs() < 1e-6 || a <= b + 1e-6,
                "seed={seed}: greedy {a} vs dp {b}"
            );
        }
    }

    #[test]
    fn comm_cost_discourages_extra_cuts() {
        // Huge comm cost: best partition collapses to fewer, bigger stages
        // in the DP (which may use fewer devices).
        let c = uniform_costs(4, 4, 1.0, 100.0);
        let p = partition_dp(&c, 4);
        assert_eq!(p.cuts.len(), 1, "{p:?}");
        assert_eq!(p.cuts[0], (0, 4));
    }

    #[test]
    fn single_device_takes_all() {
        let c = uniform_costs(8, 1, 1.0, 0.1);
        let p = partition(&c, 1);
        assert_eq!(p.cuts, vec![(0, 8)]);
    }

    #[test]
    fn throughput_is_inverse_bottleneck() {
        let c = uniform_costs(8, 4, 1.0, 0.0);
        let p = partition(&c, 4);
        assert!((p.bottleneck(&c) - 2.0).abs() < 1e-9);
        assert!((p.throughput(&c) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn slow_device_gets_fewer_blocks() {
        // Device 0 is 3x slower: it should receive fewer blocks.
        let block_times = vec![vec![3.0; 8], vec![1.0; 8]];
        let c = CostModel::new(block_times, vec![0.01; 8]);
        let p = partition_dp(&c, 2);
        assert_eq!(p.cuts.len(), 2);
        let (a, b) = (p.cuts[0].1 - p.cuts[0].0, p.cuts[1].1 - p.cuts[1].0);
        assert!(a < b, "{p:?}");
    }
}
