//! Per-block / per-device cost profiles feeding the partitioner.

/// Costs for partitioning: `block_s[d][b]` = seconds for block `b` on
/// device `d`; `comm_s[b]` = seconds to ship the activation cut after
/// block `b` (at the current bandwidth and bitwidth).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// `block_s[d][b]`: seconds for block `b` on device `d`.
    pub block_s: Vec<Vec<f64>>,
    /// `comm_s[b]`: seconds to ship the cut after block `b`.
    pub comm_s: Vec<f64>,
}

impl CostModel {
    /// Validate and wrap the cost matrices.
    pub fn new(block_s: Vec<Vec<f64>>, comm_s: Vec<f64>) -> Self {
        assert!(!block_s.is_empty());
        let n = block_s[0].len();
        assert!(block_s.iter().all(|r| r.len() == n));
        assert_eq!(comm_s.len(), n);
        CostModel { block_s, comm_s }
    }

    /// Homogeneous devices + uniform blocks.
    pub fn uniform(blocks: usize, devices: usize, block_s: f64, comm_s: f64) -> Self {
        CostModel {
            block_s: vec![vec![block_s; blocks]; devices],
            comm_s: vec![comm_s; blocks],
        }
    }

    /// Build from measured quantities: per-block seconds, activation bytes
    /// at the cut, link bandwidth (bits/s) and quantization bitwidth.
    pub fn from_measurements(
        block_s: Vec<Vec<f64>>,
        cut_bytes: &[usize],
        bandwidth_bps: f64,
        bits: u8,
    ) -> Self {
        let comm_s = cut_bytes
            .iter()
            .map(|&b| {
                if bandwidth_bps.is_infinite() {
                    0.0
                } else {
                    (b as f64 * bits as f64 / 32.0) * 8.0 / bandwidth_bps
                }
            })
            .collect();
        CostModel::new(block_s, comm_s)
    }

    /// Number of model blocks.
    pub fn blocks(&self) -> usize {
        self.comm_s.len()
    }

    /// Number of devices.
    pub fn devices(&self) -> usize {
        self.block_s.len()
    }

    /// Stage time = compute of blocks `lo..hi` on device `d`, plus the
    /// outgoing communication if this stage has a downstream cut.
    pub fn stage_time(&self, device: usize, lo: usize, hi: usize, has_cut: bool) -> f64 {
        let d = device.min(self.block_s.len() - 1);
        let compute: f64 = self.block_s[d][lo..hi].iter().sum();
        let comm = if has_cut && hi > 0 { self.comm_s[hi - 1] } else { 0.0 };
        compute + comm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_time_sums_compute_and_cut() {
        let c = CostModel::uniform(4, 2, 1.0, 0.5);
        assert!((c.stage_time(0, 0, 2, true) - 2.5).abs() < 1e-12);
        assert!((c.stage_time(1, 2, 4, false) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn from_measurements_scales_with_bits() {
        let c32 = CostModel::from_measurements(vec![vec![1.0; 4]], &[1_000_000; 4], 8e6, 32);
        let c8 = CostModel::from_measurements(vec![vec![1.0; 4]], &[1_000_000; 4], 8e6, 8);
        assert!((c32.comm_s[0] - 1.0).abs() < 1e-9);
        assert!((c8.comm_s[0] - 0.25).abs() < 1e-9);
    }

    #[test]
    fn infinite_bandwidth_zero_comm() {
        let c = CostModel::from_measurements(vec![vec![1.0; 2]], &[999; 2], f64::INFINITY, 32);
        assert_eq!(c.comm_s, vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn mismatched_dims_panic() {
        CostModel::new(vec![vec![1.0; 3]], vec![0.0; 4]);
    }
}
