//! Seedable RNG + the sampling distributions used by tests, benches and
//! the fault injector. SplitMix64 core: tiny, fast, excellent statistical
//! quality for non-cryptographic use.

#[derive(Debug, Clone)]
/// SplitMix64 generator.
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Deterministic generator from a seed.
    pub fn seed(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [lo, hi).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() % (hi - lo).max(1) as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Laplace(0, b) via inverse CDF.
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.f64() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Vec of standard normals scaled by sigma.
    pub fn gaussian_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| (self.gaussian() * sigma as f64) as f32).collect()
    }

    /// Vec of Laplace(0, b) samples.
    pub fn laplace_vec(&mut self, n: usize, b: f32) -> Vec<f32> {
        (0..n).map(|_| self.laplace(b as f64) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::seed(7);
            (0..5).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::seed(7);
            (0..5).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Rng::seed(8);
            (0..5).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::seed(1);
        let mut sum = 0.0;
        for _ in 0..20000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 20000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed(2);
        let xs: Vec<f64> = (0..50000).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn laplace_mean_abs_is_b() {
        let mut r = Rng::seed(3);
        let xs: Vec<f64> = (0..50000).map(|_| r.laplace(0.7)).collect();
        let mean_abs = xs.iter().map(|x| x.abs()).sum::<f64>() / xs.len() as f64;
        assert!((mean_abs - 0.7).abs() < 0.02, "{mean_abs}");
    }

    #[test]
    fn usize_range() {
        let mut r = Rng::seed(4);
        for _ in 0..1000 {
            let v = r.usize(3, 10);
            assert!((3..10).contains(&v));
        }
    }
}
