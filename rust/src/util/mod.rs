//! In-tree utilities replacing unavailable ecosystem crates (the build
//! environment is fully offline): a JSON parser/writer, a seedable RNG
//! with the distributions the tests need, a micro property-testing
//! harness, a bounded exhaustive interleaving explorer, and
//! poison-tolerant lock-order-tracked locking.

pub mod explore;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
