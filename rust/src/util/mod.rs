//! In-tree utilities replacing unavailable ecosystem crates (the build
//! environment is fully offline): a JSON parser/writer, a seedable RNG
//! with the distributions the tests need, and a micro property-testing
//! harness.

pub mod json;
pub mod prop;
pub mod rng;
