//! Micro property-testing harness (proptest is unavailable offline).
//!
//! `forall(seed_cases, |rng| …)` runs a property across many seeded RNGs
//! and reports the first failing seed so cases reproduce exactly. Shrinking
//! is out of scope — failures print the seed, and properties take the RNG
//! directly so a failing case can be replayed in a unit test.

use super::rng::Rng;

/// Run `prop` for `cases` deterministic seeds; panics with the failing
/// seed on first failure.
pub fn forall<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(cases: u64, mut prop: F) {
    for seed in 0..cases {
        let mut rng = Rng::seed(0xDEAD_BEEF ^ (seed.wrapping_mul(0x1234_5678_9ABC_DEF1)));
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(25, |rng| {
            count += 1;
            let v = rng.f64();
            prop_assert!((0.0..1.0).contains(&v));
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn failing_property_reports_seed() {
        forall(10, |rng| {
            let v = rng.f64();
            prop_assert!(v < 0.5, "v was {v}");
            Ok(())
        });
    }
}
