//! Bounded exhaustive state-space exploration (a mini-loom).
//!
//! [`crate::util::prop`] samples random schedules; this module enumerates
//! *all* of them up to a bound. A [`Model`] exposes its nondeterminism as
//! an explicit action set — "deliver the next frame on conduit 1", "kill
//! conduit 0", "process an ACK" — and the explorer drives a depth-first
//! search over every interleaving, checking the model's invariants after
//! every transition and at every terminal (quiescent) state.
//!
//! States are deduplicated by a model-supplied fingerprint: two schedule
//! prefixes that land in identical protocol states explore their shared
//! future once. That prunes the factorial schedule tree to the (small)
//! reachable state graph, which is what makes exhaustive coverage of the
//! session protocol feasible at useful depths. Pruning is sound here
//! because every property checked is a *safety* property evaluated on
//! states/transitions, not a property of full histories.
//!
//! On a violation the explorer reports the exact action trace from the
//! initial state, which replays deterministically — failures found by
//! exhaustive search become pinned regression tests (see
//! `rust/tests/interleavings.rs`).

use std::collections::HashSet;

/// A nondeterministic system under test.
pub trait Model {
    /// Snapshot of the whole system (cheap to clone at small bounds).
    type State: Clone;
    /// One schedulable transition.
    type Action: Clone + std::fmt::Debug;

    /// The initial state.
    fn initial(&self) -> Self::State;

    /// All actions enabled in `state`, pushed into `out` (cleared by the
    /// explorer). An empty set marks a terminal state.
    fn actions(&self, state: &Self::State, out: &mut Vec<Self::Action>);

    /// Apply `action` to a clone of the state. `Err` is an invariant
    /// violation and aborts the search with a trace.
    fn apply(&self, state: &Self::State, action: &Self::Action) -> Result<Self::State, String>;

    /// Checked at quiescent states (no enabled actions) — e.g. "every
    /// frame was delivered and the session drained".
    fn check_terminal(&self, state: &Self::State) -> Result<(), String> {
        let _ = state;
        Ok(())
    }

    /// Collision-resistant state fingerprint for deduplication. Fold the
    /// full protocol-relevant state through [`Fnv`]; omitting a field
    /// that can differ weakens coverage (two distinct states merge), so
    /// include everything.
    fn fingerprint(&self, state: &Self::State) -> u64;
}

/// Search bounds; exceeded bounds are an error (the space must be fully
/// covered, not silently truncated).
#[derive(Debug, Clone, Copy)]
pub struct Bounds {
    /// Maximum schedule length before the search reports overflow.
    pub max_depth: usize,
    /// Maximum distinct states before the search reports overflow.
    pub max_states: usize,
}

impl Default for Bounds {
    fn default() -> Self {
        Bounds { max_depth: 64, max_states: 1 << 20 }
    }
}

/// Exhaustive-search statistics (proof of coverage for test assertions).
#[derive(Debug, Default, Clone)]
pub struct Coverage {
    /// Distinct states visited (post-dedup).
    pub states: usize,
    /// Transitions executed.
    pub transitions: usize,
    /// Terminal (quiescent) states checked.
    pub terminals: usize,
    /// Transitions skipped because the successor state was already seen.
    pub deduped: usize,
    /// Deepest schedule explored.
    pub max_depth_seen: usize,
}

/// A failed search: the invariant message plus the exact action schedule
/// that reaches it from the initial state.
#[derive(Debug)]
pub struct Violation {
    /// Invariant failure message from the model.
    pub message: String,
    /// Action schedule (debug-formatted) from the initial state.
    pub trace: Vec<String>,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "invariant violated: {}", self.message)?;
        writeln!(f, "schedule ({} steps):", self.trace.len())?;
        for (i, a) in self.trace.iter().enumerate() {
            writeln!(f, "  {i:3}. {a}")?;
        }
        Ok(())
    }
}

/// Explore every interleaving of `model` within `bounds`. Returns
/// coverage stats, or the first violation with its reproducing schedule.
pub fn explore<M: Model>(model: &M, bounds: Bounds) -> Result<Coverage, Box<Violation>> {
    let mut cov = Coverage::default();
    let mut visited: HashSet<u64> = HashSet::new();
    let initial = model.initial();
    visited.insert(model.fingerprint(&initial));
    cov.states = 1;
    let mut trace: Vec<M::Action> = Vec::new();
    dfs(model, &initial, &bounds, &mut visited, &mut cov, &mut trace)?;
    Ok(cov)
}

fn dfs<M: Model>(
    model: &M,
    state: &M::State,
    bounds: &Bounds,
    visited: &mut HashSet<u64>,
    cov: &mut Coverage,
    trace: &mut Vec<M::Action>,
) -> Result<(), Box<Violation>> {
    cov.max_depth_seen = cov.max_depth_seen.max(trace.len());
    let mut actions = Vec::new();
    model.actions(state, &mut actions);
    if actions.is_empty() {
        cov.terminals += 1;
        return model.check_terminal(state).map_err(|message| violation(message, trace));
    }
    if trace.len() >= bounds.max_depth {
        return Err(violation(
            format!(
                "exploration exceeded max_depth={} with actions still enabled: {:?}",
                bounds.max_depth, actions
            ),
            trace,
        ));
    }
    for action in actions {
        trace.push(action.clone());
        let next = match model.apply(state, &action) {
            Ok(next) => next,
            Err(message) => return Err(violation(message, trace)),
        };
        cov.transitions += 1;
        if visited.insert(model.fingerprint(&next)) {
            cov.states += 1;
            if cov.states > bounds.max_states {
                return Err(violation(
                    format!("exploration exceeded max_states={}", bounds.max_states),
                    trace,
                ));
            }
            dfs(model, &next, bounds, visited, cov, trace)?;
        } else {
            cov.deduped += 1;
        }
        trace.pop();
    }
    Ok(())
}

fn violation<A: std::fmt::Debug>(message: String, trace: &[A]) -> Box<Violation> {
    Box::new(Violation { message, trace: trace.iter().map(|a| format!("{a:?}")).collect() })
}

/// Replay an explicit action schedule against a model, checking every
/// invariant on the way — the regression-corpus entry point. Returns the
/// final state.
pub fn replay<M: Model>(model: &M, schedule: &[M::Action]) -> Result<M::State, Box<Violation>> {
    let mut state = model.initial();
    for (i, action) in schedule.iter().enumerate() {
        state = match model.apply(&state, action) {
            Ok(next) => next,
            Err(message) => return Err(violation(message, &schedule[..=i])),
        };
    }
    Ok(state)
}

/// FNV-1a hasher for model fingerprints: deterministic across runs and
/// platforms (unlike `DefaultHasher`, whose algorithm is unspecified).
#[derive(Debug, Clone)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
}

impl Fnv {
    /// Fold a byte slice into the hash.
    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self
    }

    /// Fold a u64 (length-prefixed fields avoid ambiguity by construction
    /// when callers hash counts before sequences).
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    /// Finish the hash.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy model: `n` independent counters, each stepped 0..limit; every
    /// interleaving of increments. State count = (limit+1)^n, terminals
    /// all hit the all-full state (1 after dedup).
    struct Counters {
        n: usize,
        limit: u8,
        poison: Option<(usize, u8)>,
    }

    impl Model for Counters {
        type State = Vec<u8>;
        type Action = usize;

        fn initial(&self) -> Vec<u8> {
            vec![0; self.n]
        }

        fn actions(&self, state: &Vec<u8>, out: &mut Vec<usize>) {
            for (i, &v) in state.iter().enumerate() {
                if v < self.limit {
                    out.push(i);
                }
            }
        }

        fn apply(&self, state: &Vec<u8>, action: &usize) -> Result<Vec<u8>, String> {
            let mut next = state.clone();
            next[*action] += 1;
            if let Some((idx, val)) = self.poison {
                if next[idx] == val {
                    return Err(format!("poison state reached: counter {idx} hit {val}"));
                }
            }
            Ok(next)
        }

        fn check_terminal(&self, state: &Vec<u8>) -> Result<(), String> {
            if state.iter().all(|&v| v == self.limit) {
                Ok(())
            } else {
                Err(format!("terminal state not full: {state:?}"))
            }
        }

        fn fingerprint(&self, state: &Vec<u8>) -> u64 {
            Fnv::default().bytes(state).finish()
        }
    }

    #[test]
    fn explores_exact_state_count() {
        let m = Counters { n: 3, limit: 2, poison: None };
        let cov = explore(&m, Bounds::default()).expect("no violations");
        assert_eq!(cov.states, 27, "3 counters x 3 values each");
        assert_eq!(cov.terminals, 1, "single all-full terminal after dedup");
        assert_eq!(cov.max_depth_seen, 6, "depth = total increments");
        assert!(cov.deduped > 0, "diamond interleavings must dedup");
    }

    #[test]
    fn violation_reports_minimal_trace() {
        let m = Counters { n: 2, limit: 3, poison: Some((1, 2)) };
        let v = explore(&m, Bounds::default()).expect_err("poison must be found");
        assert!(v.message.contains("poison state"), "{v}");
        // DFS order reaches it via some schedule; the trace must replay
        // to the same violation.
        let schedule: Vec<usize> =
            v.trace.iter().map(|s| s.parse().expect("usize debug")).collect();
        let r = replay(&m, &schedule).expect_err("replay must reproduce");
        assert!(r.message.contains("poison state"), "{r}");
    }

    #[test]
    fn depth_bound_overflow_is_an_error() {
        let m = Counters { n: 2, limit: 10, poison: None };
        let v = explore(&m, Bounds { max_depth: 3, max_states: 1 << 20 })
            .expect_err("depth bound must trip");
        assert!(v.message.contains("max_depth"), "{v}");
    }

    #[test]
    fn fnv_is_stable() {
        // Pinned digest: fingerprints must not drift across runs/builds,
        // or regression schedules stop being comparable.
        assert_eq!(Fnv::default().bytes(b"quantpipe").finish(), 0x7568_5ec4_c056_6210);
    }
}
