//! Poison-tolerant locking + a lockdep-style lock-order detector.
//!
//! **Poison tolerance.** A panicked stage or sender thread poisons every
//! mutex it held; the default `lock().unwrap()` then turns that single
//! panic into a cascade of `PoisonError` panics across unrelated threads,
//! and the *original* failure drowns in the noise. All the pipeline's
//! shared maps hold plain data (counters, timelines, label maps) whose
//! invariants survive a mid-update panic, so the right move is to take
//! the data anyway and let `RunReport.errors` report the root cause.
//!
//! **Lock-order detection.** [`TrackedMutex`] is the instrumented mutex
//! every shared-state lock site in the crate goes through (the
//! self-hosted lint in [`crate::analysis`] bans bare `.lock()` calls
//! outside this module). In debug/test builds each acquisition records a
//! `held → acquiring` edge in a global lock-class graph, keyed by the
//! class name given at construction; if an acquisition would close a
//! cycle (the classic ABBA inversion) it panics *immediately* — on the
//! thread that would have deadlocked, before blocking — with the source
//! locations of both conflicting acquisition orders. Same-class nested
//! acquisition panics too: no code path in the crate legitimately holds
//! two locks of one class. In release builds (`debug_assertions` off)
//! tracking compiles away to a plain poison-tolerant lock.
//!
//! The detector is *order*-based, like the kernel's lockdep: it fires on
//! the first inverted pair ever observed, even if the two threads never
//! actually race, so a potential deadlock cannot hide behind a lucky
//! schedule.

use std::ops::{Deref, DerefMut};
use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard from a poisoned mutex instead of
/// panicking. Use for shared state that stays valid across a peer
/// thread's panic.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A mutex with poison tolerance and (in debug builds) lock-order
/// tracking. `name` identifies the *lock class*: all instances guarding
/// the same kind of state (e.g. every `SimLink`'s internal state) share
/// one class, and ordering constraints are recorded between classes.
pub struct TrackedMutex<T> {
    name: &'static str,
    inner: Mutex<T>,
}

impl<T> TrackedMutex<T> {
    /// Wrap `value` in a tracked mutex belonging to lock class `name`.
    pub fn new(name: &'static str, value: T) -> Self {
        TrackedMutex { name, inner: Mutex::new(value) }
    }

    /// Lock class name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Acquire the lock (poison-tolerant). In debug builds, records the
    /// acquisition in the lock-order graph and panics with both traces if
    /// it would invert an order observed anywhere before.
    #[track_caller]
    pub fn guard(&self) -> TrackedGuard<'_, T> {
        // Record the edge and check for cycles BEFORE blocking, so the
        // thread that closes a real deadlock cycle panics instead of
        // deadlocking.
        let token = lockdep::acquire(self.name);
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        TrackedGuard { inner, _token: token }
    }

    /// Consume the mutex, returning the inner value (poison-tolerant).
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(|e| e.into_inner())
    }
}

impl<T> std::fmt::Debug for TrackedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TrackedMutex").field("name", &self.name).finish_non_exhaustive()
    }
}

/// Guard returned by [`TrackedMutex::guard`]; releases the lock and pops
/// the lockdep held-stack entry on drop.
pub struct TrackedGuard<'a, T> {
    inner: MutexGuard<'a, T>,
    _token: lockdep::Held,
}

impl<T> Deref for TrackedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for TrackedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

/// An epoch-counting wakeup latch: the missed-notification-proof
/// primitive behind the reactor's "bytes may have arrived" signal.
///
/// A plain `Condvar` loses notifications that fire between a caller's
/// check and its wait. `Notify` closes that race with a monotonically
/// increasing epoch: readers snapshot [`Notify::epoch`] *before*
/// checking their condition, and [`Notify::wait_past`] returns
/// immediately if any notification has happened since that snapshot —
/// the notification cannot be lost, only observed early.
///
/// Uses a raw `Mutex`/`Condvar` pair (this module is the one place
/// allowed to): the lock is held for a single integer bump, is a leaf
/// (nothing else is ever acquired under it), and `Condvar::wait_timeout`
/// needs the real `MutexGuard` type.
#[derive(Debug, Default)]
pub struct Notify {
    epoch: Mutex<u64>,
    cv: std::sync::Condvar,
}

impl Notify {
    /// A latch at epoch 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Wake every current and future waiter: bump the epoch and signal.
    pub fn notify(&self) {
        *lock(&self.epoch) += 1;
        self.cv.notify_all();
    }

    /// Current epoch. Snapshot this *before* checking the condition the
    /// notification guards, then pass it to [`Notify::wait_past`].
    pub fn epoch(&self) -> u64 {
        *lock(&self.epoch)
    }

    /// Block until the epoch moves past `seen` or `timeout` elapses
    /// (whichever first); returns the epoch at wakeup. Returns
    /// immediately if a notification already happened after the `seen`
    /// snapshot was taken.
    pub fn wait_past(&self, seen: u64, timeout: std::time::Duration) -> u64 {
        let deadline = std::time::Instant::now() + timeout;
        let mut guard = lock(&self.epoch);
        while *guard <= seen {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _) = self
                .cv
                .wait_timeout(guard, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            guard = g;
        }
        *guard
    }
}

/// Debug-build lock-order tracking. Everything here compiles to nothing
/// when `debug_assertions` is off.
#[cfg(debug_assertions)]
mod lockdep {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::panic::Location;
    use std::sync::{Mutex, OnceLock};

    /// One recorded ordering edge: some thread acquired class `to` while
    /// holding class `from`.
    struct Edge {
        /// Where the held (`from`) lock was acquired.
        from_site: &'static Location<'static>,
        /// Where the `to` lock was acquired on top of it.
        to_site: &'static Location<'static>,
    }

    #[derive(Default)]
    struct Registry {
        /// Class name → dense class id.
        classes: HashMap<&'static str, usize>,
        /// Class id → name (reverse of `classes`).
        names: Vec<&'static str>,
        /// Adjacency: from-class → (to-class → first edge observed).
        edges: HashMap<usize, HashMap<usize, Edge>>,
    }

    impl Registry {
        fn intern(&mut self, name: &'static str) -> usize {
            if let Some(&id) = self.classes.get(name) {
                return id;
            }
            let id = self.names.len();
            self.names.push(name);
            self.classes.insert(name, id);
            id
        }

        /// Edges along some path `from →* to`, or `None` if unreachable.
        fn find_path(&self, from: usize, to: usize) -> Option<Vec<(usize, usize)>> {
            let mut parent: HashMap<usize, usize> = HashMap::new();
            let mut queue = std::collections::VecDeque::from([from]);
            while let Some(node) = queue.pop_front() {
                if node == to {
                    let mut path = Vec::new();
                    let mut cur = to;
                    while cur != from {
                        let p = parent[&cur];
                        path.push((p, cur));
                        cur = p;
                    }
                    path.reverse();
                    return Some(path);
                }
                if let Some(nexts) = self.edges.get(&node) {
                    for &next in nexts.keys() {
                        if next != from && !parent.contains_key(&next) {
                            parent.insert(next, node);
                            queue.push_back(next);
                        }
                    }
                }
            }
            None
        }

        fn describe_path(&self, path: &[(usize, usize)]) -> String {
            path.iter()
                .map(|&(a, b)| {
                    let e = &self.edges[&a][&b];
                    format!(
                        "'{}' (acquired at {}) -> '{}' (acquired at {})",
                        self.names[a], e.from_site, self.names[b], e.to_site
                    )
                })
                .collect::<Vec<_>>()
                .join("; ")
        }
    }

    fn registry() -> &'static Mutex<Registry> {
        static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(Registry::default()))
    }

    thread_local! {
        /// Lock classes this thread currently holds, acquisition order,
        /// with the site of each acquisition.
        static HELD: RefCell<Vec<(usize, &'static Location<'static>)>> =
            const { RefCell::new(Vec::new()) };
    }

    /// Held-stack token; popping happens on drop (i.e. guard release).
    pub(super) struct Held {
        class: usize,
    }

    impl Drop for Held {
        fn drop(&mut self) {
            HELD.with(|h| {
                let mut held = h.borrow_mut();
                if let Some(pos) = held.iter().rposition(|&(c, _)| c == self.class) {
                    held.remove(pos);
                }
            });
        }
    }

    /// Validate + record an acquisition of `name` at the caller's site.
    /// Panics (before the caller blocks) if the acquisition closes a
    /// cycle in the global lock-order graph.
    #[track_caller]
    pub(super) fn acquire(name: &'static str) -> Held {
        let site = Location::caller();
        // The registry's own mutex is the one lock not tracked by itself;
        // it is a leaf (nothing is acquired while holding it).
        let mut reg = lockdep_lock(registry());
        let class = reg.intern(name);
        HELD.with(|h| {
            let held = h.borrow();
            for &(held_class, held_site) in held.iter() {
                if held_class == class {
                    panic!(
                        "lock-order violation: same-class nested acquisition of '{name}' \
                         at {site} while already holding '{name}' (acquired at {held_site})"
                    );
                }
                if let Some(path) = reg.find_path(class, held_class) {
                    panic!(
                        "lock-order cycle (potential deadlock): acquiring '{}' at {} while \
                         holding '{}' (acquired at {}), but the reverse order was already \
                         observed: {}",
                        name,
                        site,
                        reg.names[held_class],
                        held_site,
                        reg.describe_path(&path)
                    );
                }
            }
            for &(held_class, held_site) in held.iter() {
                reg.edges
                    .entry(held_class)
                    .or_default()
                    .entry(class)
                    .or_insert(Edge { from_site: held_site, to_site: site });
            }
        });
        drop(reg);
        HELD.with(|h| h.borrow_mut().push((class, site)));
        Held { class }
    }

    /// Poison-tolerant lock for the registry itself (a lockdep panic
    /// inside `acquire` poisons it; later acquisitions must still work).
    fn lockdep_lock(m: &Mutex<Registry>) -> std::sync::MutexGuard<'_, Registry> {
        m.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Release-build stub: tracking compiles away entirely.
#[cfg(not(debug_assertions))]
mod lockdep {
    /// Zero-sized token; no tracking in release builds.
    pub(super) struct Held;

    #[inline(always)]
    pub(super) fn acquire(_name: &'static str) -> Held {
        Held
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_survives_poison() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        // The helper still yields the data.
        lock(&m).push(4);
        assert_eq!(*lock(&m), vec![1, 2, 3, 4]);
    }

    #[test]
    fn tracked_mutex_survives_poison() {
        let m = Arc::new(TrackedMutex::new("test.sync.poison", vec![1, 2, 3]));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.guard();
            panic!("poison it");
        })
        .join();
        m.guard().push(4);
        assert_eq!(*m.guard(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn consistent_order_is_quiet_across_threads() {
        let a = Arc::new(TrackedMutex::new("test.sync.quiet_a", 0u32));
        let b = Arc::new(TrackedMutex::new("test.sync.quiet_b", 0u32));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let (a, b) = (a.clone(), b.clone());
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let mut ga = a.guard();
                    let mut gb = b.guard();
                    *ga += 1;
                    *gb += 1;
                }
            }));
        }
        for h in handles {
            h.join().expect("consistent a->b order must never trip lockdep");
        }
        assert_eq!(*a.guard(), 200);
    }

    /// The seeded ABBA cycle: establish a→b, then acquire b→a. The
    /// detector must fire on the second thread with both traces, without
    /// any actual deadlock (the first pair is already released).
    #[test]
    fn lockdep_detects_abba_cycle_with_both_traces() {
        let a = Arc::new(TrackedMutex::new("test.sync.abba_a", ()));
        let b = Arc::new(TrackedMutex::new("test.sync.abba_b", ()));
        {
            let _ga = a.guard();
            let _gb = b.guard(); // records abba_a -> abba_b
        }
        let (a2, b2) = (a.clone(), b.clone());
        let result = std::thread::spawn(move || {
            let _gb = b2.guard();
            let _ga = a2.guard(); // must panic: would record abba_b -> abba_a
        })
        .join();
        let payload = result.expect_err("lockdep must fire on the inverted order");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("lock-order cycle"), "unexpected panic: {msg}");
        assert!(msg.contains("test.sync.abba_a"), "missing class a in: {msg}");
        assert!(msg.contains("test.sync.abba_b"), "missing class b in: {msg}");
        // Both traces: the blocked acquisition site and the previously
        // recorded edge's sites are all in this file.
        assert!(msg.matches("sync.rs").count() >= 2, "expected both traces in: {msg}");
    }

    #[test]
    fn lockdep_rejects_same_class_nesting() {
        let a = Arc::new(TrackedMutex::new("test.sync.nest", ()));
        let a2 = a.clone();
        let result = std::thread::spawn(move || {
            let _g1 = a2.guard();
            let _g2 = a2.guard(); // self-deadlock: must panic, not hang
        })
        .join();
        let payload = result.expect_err("same-class nesting must trip lockdep");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("same-class nested acquisition"), "unexpected panic: {msg}");
    }

    #[test]
    fn transitive_cycle_is_detected() {
        let a = Arc::new(TrackedMutex::new("test.sync.tri_a", ()));
        let b = Arc::new(TrackedMutex::new("test.sync.tri_b", ()));
        let c = Arc::new(TrackedMutex::new("test.sync.tri_c", ()));
        {
            let _ga = a.guard();
            let _gb = b.guard(); // a -> b
        }
        {
            let _gb = b.guard();
            let _gc = c.guard(); // b -> c
        }
        let (a2, c2) = (a.clone(), c.clone());
        let result = std::thread::spawn(move || {
            let _gc = c2.guard();
            let _ga = a2.guard(); // closes a ->* c -> a
        })
        .join();
        let payload = result.expect_err("transitive inversion must trip lockdep");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("lock-order cycle"), "unexpected panic: {msg}");
        assert!(
            msg.contains("tri_a") && msg.contains("tri_b") && msg.contains("tri_c"),
            "path through all three classes should be reported: {msg}"
        );
    }

    #[test]
    fn into_inner_returns_value() {
        let m = TrackedMutex::new("test.sync.into_inner", 41u32);
        *m.guard() += 1;
        assert_eq!(m.into_inner(), 42);
    }

    #[test]
    fn notify_wakes_a_waiter() {
        let n = Arc::new(Notify::new());
        let seen = n.epoch();
        let n2 = n.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            n2.notify();
        });
        let after = n.wait_past(seen, std::time::Duration::from_secs(5));
        assert!(after > seen, "wait_past must observe the notification");
        h.join().unwrap();
    }

    #[test]
    fn notify_between_snapshot_and_wait_is_not_lost() {
        // The race a bare Condvar loses: notification fires after the
        // epoch snapshot but before the wait. wait_past must return
        // immediately instead of eating the full timeout.
        let n = Notify::new();
        let seen = n.epoch();
        n.notify();
        let t0 = std::time::Instant::now();
        let after = n.wait_past(seen, std::time::Duration::from_secs(5));
        assert!(after > seen);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(1),
            "already-notified wait must not block"
        );
    }

    #[test]
    fn notify_wait_times_out_quietly() {
        let n = Notify::new();
        let seen = n.epoch();
        let after = n.wait_past(seen, std::time::Duration::from_millis(5));
        assert_eq!(after, seen, "no notification: epoch unchanged after timeout");
    }
}
