//! Poison-tolerant locking.
//!
//! A panicked stage or sender thread poisons every mutex it held; the
//! default `lock().unwrap()` then turns that single panic into a cascade
//! of `PoisonError` panics across unrelated threads, and the *original*
//! failure drowns in the noise. All the pipeline's shared maps hold plain
//! data (counters, timelines, label maps) whose invariants survive a
//! mid-update panic, so the right move is to take the data anyway and let
//! `RunReport.errors` report the root cause.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard from a poisoned mutex instead of
/// panicking. Use for shared state that stays valid across a peer
/// thread's panic.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_survives_poison() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        // The helper still yields the data.
        lock(&m).push(4);
        assert_eq!(*lock(&m), vec![1, 2, 3, 4]);
    }
}
