//! Minimal JSON: full parser + pretty writer.
//!
//! Handles everything the repo's interchange files use (manifest.json,
//! golden.json, config files, cost profiles): objects, arrays, strings
//! with escapes, numbers (f64), bools, null. Not a general-purpose
//! validator — it accepts a small superset (e.g. trailing whitespace) and
//! reports byte offsets on errors.

use crate::Result;
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
/// A parsed JSON value.
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (keys sorted — deterministic output).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// A number, or `null` when non-finite — JSON has no Infinity/NaN
    /// (e.g. an unconstrained link measures "infinite" bandwidth), and
    /// every report writer shares this one spelling of the rule so the
    /// formats cannot diverge.
    pub fn num_or_null(v: f64) -> Value {
        if v.is_finite() {
            Value::Num(v)
        } else {
            Value::Null
        }
    }

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    /// Object field lookup (`None` for missing keys or non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access with a helpful error.
    pub fn at(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }

    /// The number, or an error for non-numbers.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => anyhow::bail!("expected number, got {self:?}"),
        }
    }

    /// The number truncated to `usize`.
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// The number truncated to `u64`.
    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    /// The boolean, or an error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => anyhow::bail!("expected bool, got {self:?}"),
        }
    }

    /// The string, or an error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }

    /// The array's elements, or an error.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => anyhow::bail!("expected array, got {self:?}"),
        }
    }

    /// The array as a `Vec<usize>`.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// The array as a `Vec<f64>`.
    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // -- writer ---------------------------------------------------------------

    /// Serialize (two-space-indented objects); always re-parseable.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no Infinity/NaN; `{n}` would emit "inf" and
                    // corrupt the document (e.g. a WindowMonitor measuring
                    // an unconstrained link reports infinite bandwidth).
                    // Emit null so the output always re-parses.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write(out, indent);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                let pad = " ".repeat((indent + 1) * 2);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent * 2));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON at byte {}", self.i))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        anyhow::ensure!(self.peek()? == c, "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.obj(),
            b'[' => self.arr(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.num(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn num(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number {s:?} at byte {start}: {e}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i - 1),
                    }
                }
                c => {
                    // Re-assemble multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                        let bytes = &self.b[self.i - 1..self.i - 1 + len];
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i += len - 1;
                    }
                }
            }
        }
    }

    fn arr(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                c => anyhow::bail!("expected , or ] got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn obj(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                c => anyhow::bail!("expected , or }} got {:?} at byte {}", c as char, self.i),
            }
        }
    }
}

// Convenience constructors.
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse(r#""hi\n\"there\"""#).unwrap(), Value::Str("hi\n\"there\"".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.at("c").unwrap().as_str().unwrap(), "x");
        let arr = v.at("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].at("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn roundtrip_pretty() {
        let src = r#"{"model": {"dim": 128, "ok": true}, "xs": [1.5, 2, 3], "name": "vit"}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""héllo A ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo A ✓");
        let back = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn errors_are_located() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
        assert!(Value::parse("12 34").unwrap_err().to_string().contains("trailing"));
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Value::parse(r#"{"a": 1}"#).unwrap();
        assert!(v.at("missing").is_err());
        assert!(v.at("a").unwrap().as_str().is_err());
        assert_eq!(v.at("a").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        let v = Value::Arr(vec![
            Value::Num(f64::INFINITY),
            Value::Num(f64::NEG_INFINITY),
            Value::Num(f64::NAN),
            Value::Num(1.5),
        ]);
        let s = v.to_string_pretty();
        assert!(!s.contains("inf") && !s.contains("NaN"), "{s}");
        let back = Value::parse(&s).unwrap();
        let arr = back.as_arr().unwrap();
        assert_eq!(arr[0], Value::Null);
        assert_eq!(arr[1], Value::Null);
        assert_eq!(arr[2], Value::Null);
        assert_eq!(arr[3], Value::Num(1.5));
    }

    #[test]
    fn vec_helpers() {
        let v = Value::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.f64_vec().unwrap(), vec![1.0, 2.0, 3.0]);
    }
}
