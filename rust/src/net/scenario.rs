//! Named, timed impairment schedules for the chaos transport lab.
//!
//! A scenario turns one `(name, seed)` pair into a full per-stripe set
//! of [`super::shaper::ShaperSpec`]s: which stripes are shaped, whether
//! they share one token bucket (a boundary-level radio link carrying
//! every stripe) or get independent ones (per-path impairment), and the
//! exact fade/partition/loss timeline — all deterministic, so a failing
//! chaos run replays from its printed seed.
//!
//! Plumbing: `transport.scenario` + `transport.scenario_seed` in the
//! config, `--scenario NAME [--scenario-seed S]` on `quantpipe worker` /
//! `quantpipe coordinate`, and [`ScenarioKind::build`] wherever a
//! [`super::stripe::StripedTx`] is constructed. `"none"` (the default)
//! builds no shapers at all — the hot path is byte-identical to a
//! scenario-free build (regression-tested via
//! [`super::shaper::hot_touches`]).
//!
//! Timescales are sized for seconds-scale localhost experiments (the
//! scale of the Fig-5 replay and the chaos soak), not for day-long runs:
//! every named scenario plays out within roughly five seconds.

use super::shaper::{LinkShaper, ShaperSpec};
use super::trace::BandwidthTrace;
use super::{mbps, Bps};
use crate::util::rng::Rng;
use crate::Result;
use std::sync::Arc;
use std::time::Duration;

/// Every legal `transport.scenario` value, including the default.
pub const NAMES: &[&str] = &[
    "none",
    "cellular_fade",
    "satellite_pass",
    "flash_crowd",
    "drone_handoff",
    "partitioned_stripe",
    "kill_storm",
    "composite_chaos",
];

/// Whether a scenario shapes the boundary as one shared medium or each
/// stripe as its own path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// One shared [`LinkShaper`] (one token bucket) across all stripes:
    /// the boundary rides a single radio link.
    Boundary,
    /// Independent shapers per stripe: multi-path impairment, possibly
    /// leaving some stripes unshaped.
    PerStripe,
}

/// A named impairment schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    /// No shaping at all (the default; byte-identical to pre-chaos-lab
    /// behavior).
    None,
    /// Deep cellular fade: full rate → 40 Mbps shoulder → seeded trough
    /// (4–10 Mbps) → recovery, with light delay/jitter. The Fig-5 shape
    /// compressed into one fade cycle.
    CellularFade,
    /// LEO pass: capacity rises toward zenith and falls back to the
    /// horizon floor, under high fixed delay, ending in a short
    /// handover blackhole.
    SatellitePass,
    /// Competing flash crowd: capacity steps down as the crowd arrives,
    /// heavy jitter and light loss, then recovers.
    FlashCrowd,
    /// Drone formation handoffs (pairs with `examples/drone_formation`):
    /// each stripe periodically blackholes for a handoff window, at
    /// staggered offsets, over a moderate shared-rate radio.
    DroneHandoff,
    /// One seeded victim stripe is partitioned and lossy while its
    /// siblings stay clean — the asymmetric-stripe case the striped
    /// scheduler's least-stalled bias exists for.
    PartitionedStripe,
    /// High frame-loss storm on every stripe: each loss is a conduit
    /// kill, so this is a reconnect/replay stress test.
    KillStorm,
    /// The chaos-soak composite: a fade trace on every stripe plus
    /// corruption on stripe 0, loss on stripe 1 (when present) and a
    /// partition window on the last stripe.
    CompositeChaos,
}

impl ScenarioKind {
    /// Parse a `transport.scenario` / `--scenario` value. Unknown names
    /// fail loudly with the full list of valid ones.
    pub fn parse(name: &str) -> Result<ScenarioKind> {
        Ok(match name {
            "none" => ScenarioKind::None,
            "cellular_fade" => ScenarioKind::CellularFade,
            "satellite_pass" => ScenarioKind::SatellitePass,
            "flash_crowd" => ScenarioKind::FlashCrowd,
            "drone_handoff" => ScenarioKind::DroneHandoff,
            "partitioned_stripe" => ScenarioKind::PartitionedStripe,
            "kill_storm" => ScenarioKind::KillStorm,
            "composite_chaos" => ScenarioKind::CompositeChaos,
            other => anyhow::bail!(
                "unknown scenario {other:?} (valid: {})",
                NAMES.join(", ")
            ),
        })
    }

    /// The canonical name (`ScenarioKind::parse` round-trips it).
    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::None => "none",
            ScenarioKind::CellularFade => "cellular_fade",
            ScenarioKind::SatellitePass => "satellite_pass",
            ScenarioKind::FlashCrowd => "flash_crowd",
            ScenarioKind::DroneHandoff => "drone_handoff",
            ScenarioKind::PartitionedStripe => "partitioned_stripe",
            ScenarioKind::KillStorm => "kill_storm",
            ScenarioKind::CompositeChaos => "composite_chaos",
        }
    }

    /// All named (non-`none`) scenarios.
    pub fn all() -> Vec<ScenarioKind> {
        vec![
            ScenarioKind::CellularFade,
            ScenarioKind::SatellitePass,
            ScenarioKind::FlashCrowd,
            ScenarioKind::DroneHandoff,
            ScenarioKind::PartitionedStripe,
            ScenarioKind::KillStorm,
            ScenarioKind::CompositeChaos,
        ]
    }

    /// How this scenario's shapers are shared across stripes.
    pub fn placement(&self) -> Placement {
        match self {
            ScenarioKind::None
            | ScenarioKind::CellularFade
            | ScenarioKind::SatellitePass
            | ScenarioKind::FlashCrowd => Placement::Boundary,
            ScenarioKind::DroneHandoff
            | ScenarioKind::PartitionedStripe
            | ScenarioKind::KillStorm
            | ScenarioKind::CompositeChaos => Placement::PerStripe,
        }
    }

    /// One spec slot per stripe (`None` = that stripe stays unshaped).
    /// Pure in `(self, seed, stripes)`.
    pub fn specs(&self, seed: u64, stripes: usize) -> Vec<Option<ShaperSpec>> {
        let stripes = stripes.max(1);
        let base = mix(seed, self.name());
        match self {
            ScenarioKind::None => vec![None; stripes],
            ScenarioKind::CellularFade => {
                let mut r = Rng::seed(base);
                let t0 = r.range(0.8, 1.6);
                let trough = mbps(r.range(4.0, 10.0));
                let d = r.range(1.5, 3.0);
                let spec = ShaperSpec {
                    trace: BandwidthTrace::from_points(&[
                        (0.0, f64::INFINITY),
                        (t0, mbps(40.0)),
                        (t0 + 0.3 * d, trough),
                        (t0 + 0.7 * d, mbps(40.0)),
                        (t0 + d, f64::INFINITY),
                    ]),
                    delay: Duration::from_millis(2),
                    jitter: Duration::from_millis(3),
                    seed: base,
                    ..ShaperSpec::default()
                };
                vec![Some(spec); stripes]
            }
            ScenarioKind::SatellitePass => {
                let mut r = Rng::seed(base);
                let t0 = r.range(0.5, 1.0);
                let d = r.range(2.0, 4.0);
                let spec = ShaperSpec {
                    trace: BandwidthTrace::from_points(&[
                        (0.0, mbps(8.0)),
                        (t0, mbps(20.0)),
                        (t0 + d / 3.0, mbps(80.0)),
                        (t0 + 2.0 * d / 3.0, mbps(20.0)),
                        (t0 + d, mbps(8.0)),
                    ]),
                    delay: Duration::from_millis(40),
                    jitter: Duration::from_millis(5),
                    partitions: vec![(t0 + d, t0 + d + 0.25)],
                    seed: base,
                    ..ShaperSpec::default()
                };
                vec![Some(spec); stripes]
            }
            ScenarioKind::FlashCrowd => {
                let mut r = Rng::seed(base);
                let t0 = r.range(0.4, 1.0);
                let surge = r.range(1.5, 2.5);
                let spec = ShaperSpec {
                    trace: BandwidthTrace::from_points(&[
                        (0.0, f64::INFINITY),
                        (t0, mbps(60.0)),
                        (t0 + 0.3, mbps(24.0)),
                        (t0 + 0.8, mbps(12.0)),
                        (t0 + 0.8 + surge, mbps(60.0)),
                        (t0 + 1.3 + surge, f64::INFINITY),
                    ]),
                    jitter: Duration::from_millis(6),
                    loss_p: 0.005,
                    seed: base,
                    ..ShaperSpec::default()
                };
                vec![Some(spec); stripes]
            }
            ScenarioKind::DroneHandoff => (0..stripes)
                .map(|k| {
                    let mut r = Rng::seed(base ^ k as u64);
                    let period = r.range(1.2, 2.0);
                    let width = r.range(0.15, 0.35);
                    let offset = r.range(0.2, 0.8) + k as f64 * period / stripes as f64;
                    Some(ShaperSpec {
                        trace: BandwidthTrace::constant(mbps(40.0)),
                        jitter: Duration::from_millis(1),
                        loss_p: 0.01,
                        partitions: (0..3)
                            .map(|j| {
                                let s = offset + j as f64 * period;
                                (s, s + width)
                            })
                            .collect(),
                        seed: base ^ k as u64,
                        ..ShaperSpec::default()
                    })
                })
                .collect(),
            ScenarioKind::PartitionedStripe => {
                let mut r = Rng::seed(base);
                let victim = r.usize(0, stripes);
                let t0 = r.range(0.5, 1.0);
                let d = r.range(0.5, 1.5);
                (0..stripes)
                    .map(|k| {
                        (k == victim).then(|| ShaperSpec {
                            partitions: vec![(t0, t0 + d)],
                            loss_p: 0.05,
                            seed: base ^ k as u64,
                            ..ShaperSpec::default()
                        })
                    })
                    .collect()
            }
            ScenarioKind::KillStorm => (0..stripes)
                .map(|k| {
                    let mut r = Rng::seed(base ^ k as u64);
                    Some(ShaperSpec {
                        loss_p: r.range(0.05, 0.15),
                        seed: base ^ k as u64,
                        ..ShaperSpec::default()
                    })
                })
                .collect(),
            ScenarioKind::CompositeChaos => {
                let mut r = Rng::seed(base);
                let trough = mbps(r.range(6.0, 10.0));
                let p = r.range(0.5, 0.8);
                let pt = r.range(1.0, 1.6);
                let fade = BandwidthTrace::from_points(&[
                    (0.0, f64::INFINITY),
                    (p, mbps(24.0)),
                    (2.0 * p, trough),
                    (3.0 * p, mbps(24.0)),
                    (4.0 * p, f64::INFINITY),
                ]);
                (0..stripes)
                    .map(|k| {
                        let mut spec = ShaperSpec {
                            trace: fade.clone(),
                            delay: Duration::from_micros(100),
                            jitter: Duration::from_micros(400),
                            seed: base ^ k as u64,
                            ..ShaperSpec::default()
                        };
                        if k == 0 {
                            // High enough that a soak of ~40+ frames on
                            // this stripe observes corruption for any
                            // seed (P(none) < 1e-5 at 40 draws).
                            spec.corrupt_p = 0.25;
                        }
                        if k == 1 && stripes > 2 {
                            spec.loss_p = 0.02;
                        }
                        if k == stripes - 1 && stripes > 1 {
                            spec.partitions = vec![(pt, pt + 0.12)];
                        }
                        Some(spec)
                    })
                    .collect()
            }
        }
    }

    /// Instantiate the shapers for a `stripes`-wide boundary. Boundary
    /// scenarios return one shared `Arc` (one token bucket) cloned into
    /// every slot; per-stripe scenarios return independent shapers.
    pub fn build(&self, seed: u64, stripes: usize) -> Vec<Option<Arc<LinkShaper>>> {
        let specs = self.specs(seed, stripes);
        match self.placement() {
            Placement::Boundary => {
                let shared = specs
                    .iter()
                    .flatten()
                    .next()
                    .cloned()
                    .map(|s| Arc::new(LinkShaper::new(s)));
                specs
                    .iter()
                    .map(|s| if s.is_some() { shared.clone() } else { None })
                    .collect()
            }
            Placement::PerStripe => specs
                .into_iter()
                .map(|s| s.map(|spec| Arc::new(LinkShaper::new(spec))))
                .collect(),
        }
    }

    /// Human-readable deterministic event timeline: one line per stripe
    /// slot describing its full impairment schedule. Pure in
    /// `(self, seed, stripes)` — the unit tests pin this.
    pub fn timeline(&self, seed: u64, stripes: usize) -> Vec<String> {
        let placement = match self.placement() {
            Placement::Boundary => "shared",
            Placement::PerStripe => "per-stripe",
        };
        self.specs(seed, stripes)
            .iter()
            .enumerate()
            .map(|(k, slot)| match slot {
                None => format!("stripe {k}: unshaped"),
                Some(s) => {
                    let segs: Vec<String> = s
                        .trace
                        .segments
                        .iter()
                        .map(|seg| format!("{:.2}s:{}", seg.start, fmt_bps(seg.bps)))
                        .collect();
                    let parts: Vec<String> = s
                        .partitions
                        .iter()
                        .map(|(a, b)| format!("[{a:.2}s,{b:.2}s)"))
                        .collect();
                    format!(
                        "stripe {k} [{placement}]: trace {}; delay {:?}; jitter {:?}; \
                         corrupt {:.3}; loss {:.3}; partitions {}",
                        segs.join(","),
                        s.delay,
                        s.jitter,
                        s.corrupt_p,
                        s.loss_p,
                        if parts.is_empty() { "-".to_string() } else { parts.join(" ") },
                    )
                }
            })
            .collect()
    }
}

fn fmt_bps(bps: Bps) -> String {
    if bps.is_infinite() {
        "inf".to_string()
    } else {
        format!("{:.1}M", bps / 1e6)
    }
}

/// FNV-style fold of the scenario name into the user seed, so two
/// scenarios at the same seed still draw independent parameters.
fn mix(seed: u64, name: &str) -> u64 {
    name.bytes()
        .fold(seed ^ 0x9E37_79B9_7F4A_7C15, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01B3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_name() {
        for name in NAMES {
            let kind = ScenarioKind::parse(name).unwrap();
            assert_eq!(kind.name(), *name);
        }
    }

    #[test]
    fn unknown_name_is_loud_and_lists_the_valid_set() {
        let err = ScenarioKind::parse("celular_fade").unwrap_err().to_string();
        assert!(err.contains("celular_fade"), "{err}");
        for name in NAMES {
            assert!(err.contains(name), "{err} should list {name}");
        }
    }

    #[test]
    fn timelines_are_deterministic_per_seed() {
        for kind in ScenarioKind::all() {
            let a = kind.timeline(7, 3);
            let b = kind.timeline(7, 3);
            let c = kind.timeline(8, 3);
            assert_eq!(a, b, "{}", kind.name());
            assert_ne!(a, c, "{} must vary with the seed", kind.name());
        }
    }

    #[test]
    fn none_builds_no_shapers() {
        let specs = ScenarioKind::None.specs(7, 3);
        assert_eq!(specs.len(), 3);
        assert!(specs.iter().all(|s| s.is_none()));
        assert!(ScenarioKind::None.build(7, 3).iter().all(|s| s.is_none()));
    }

    #[test]
    fn boundary_scenarios_share_one_token_bucket() {
        let boundary =
            [ScenarioKind::CellularFade, ScenarioKind::SatellitePass, ScenarioKind::FlashCrowd];
        for kind in boundary {
            let shapers = kind.build(7, 3);
            assert_eq!(shapers.len(), 3);
            let first = shapers[0].as_ref().unwrap();
            for s in &shapers[1..] {
                assert!(Arc::ptr_eq(first, s.as_ref().unwrap()), "{}", kind.name());
            }
        }
    }

    #[test]
    fn per_stripe_scenarios_get_independent_shapers() {
        let per_stripe =
            [ScenarioKind::DroneHandoff, ScenarioKind::KillStorm, ScenarioKind::CompositeChaos];
        for kind in per_stripe {
            let shapers = kind.build(7, 3);
            let a = shapers[0].as_ref().unwrap();
            let b = shapers[1].as_ref().unwrap();
            assert!(!Arc::ptr_eq(a, b), "{}", kind.name());
        }
    }

    #[test]
    fn partitioned_stripe_impairs_exactly_one_victim() {
        let specs = ScenarioKind::PartitionedStripe.specs(7, 4);
        let shaped = specs.iter().filter(|s| s.is_some()).count();
        assert_eq!(shaped, 1);
    }

    #[test]
    fn composite_chaos_covers_every_fault_axis() {
        let specs = ScenarioKind::CompositeChaos.specs(7, 3);
        let s0 = specs[0].as_ref().unwrap();
        assert!(s0.corrupt_p > 0.0);
        assert!(!s0.trace.segments.is_empty());
        let s1 = specs[1].as_ref().unwrap();
        assert!(s1.loss_p > 0.0);
        let s2 = specs[2].as_ref().unwrap();
        assert!(!s2.partitions.is_empty());
    }
}
