//! The **conduit layer**: one physical connection of a reliability
//! session. A conduit knows how to dial (with backoff + jitter), how to
//! read whatever bytes are available without committing to a blocking
//! wait, and how to die quietly — every protocol decision (what those
//! bytes mean, what must be replayed) lives in [`super::session`].
//!
//! A stage boundary owns 1..N conduits ([`super::stripe`]); the plain
//! resilient link is simply the 1-conduit case ([`super::resilient`]).

use super::session::{append_telemetry_record, ctrl_record, CTRL_LEN};
use super::tcp::{connect_until, Backoff};
use crate::util::sync::TrackedMutex;
use crate::Result;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Test/ops lever: force-kill a conduit's active socket to simulate a
/// transient failure (both ends observe it and run their resync paths).
/// Cloned handles share the same slot; a striped boundary hands out one
/// switch per stripe.
#[derive(Clone)]
pub struct LinkKillSwitch(Arc<TrackedMutex<Option<TcpStream>>>);

impl Default for LinkKillSwitch {
    fn default() -> Self {
        LinkKillSwitch(Arc::new(TrackedMutex::new("conduit.killswitch", None)))
    }
}

impl LinkKillSwitch {
    /// Empty switch; arms when a conduit registers its stream.
    pub fn new() -> Self {
        Self::default()
    }

    /// Shut down the currently registered connection. Returns `false` if
    /// the conduit has never connected.
    pub fn kill(&self) -> bool {
        match &*self.0.guard() {
            Some(s) => {
                let _ = s.shutdown(Shutdown::Both);
                true
            }
            None => false,
        }
    }

    pub(crate) fn register(&self, stream: &TcpStream) {
        *self.0.guard() = stream.try_clone().ok();
    }
}

/// Per-endpoint jitter-seed nonce: endpoints sharing one config (the
/// normal case — one config file per fleet) must still draw DIFFERENT
/// backoff jitter, or a fleet-wide outage retries in lockstep and the
/// jitter defends nothing. Process id decorrelates across processes, the
/// counter decorrelates endpoints within one.
pub(crate) fn endpoint_nonce() -> u64 {
    static COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    (std::process::id() as u64) << 32 | n
}

/// `write_all` that also services **nonblocking** sockets: every
/// reactor-registered conduit is permanently O_NONBLOCK (the flag lives
/// on the socket, shared by all duplicated handles), so write paths must
/// absorb `WouldBlock` by retrying after a short sleep. The retry time
/// is still part of the caller-measured write duration — a congested
/// socket reads as a long (stalled) write either way, which is exactly
/// the bandwidth signal the adaptive controller feeds on. On a blocking
/// stream this reduces to plain `write_all`.
fn write_all_nb(s: &mut TcpStream, mut bytes: &[u8]) -> std::io::Result<()> {
    while !bytes.is_empty() {
        match s.write(bytes) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "socket accepted zero bytes",
                ))
            }
            Ok(n) => bytes = &bytes[n..],
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_micros(200));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Write one length-prefixed record (a serialized frame).
pub(crate) fn write_frame_bytes(s: &mut TcpStream, bytes: &[u8]) -> std::io::Result<()> {
    write_all_nb(s, &(bytes.len() as u32).to_le_bytes())?;
    write_all_nb(s, bytes)?;
    s.flush()
}

/// Write one 13-byte control record.
pub(crate) fn write_ctrl(s: &mut TcpStream, kind: u8, seq: u64) -> std::io::Result<()> {
    write_all_nb(s, &ctrl_record(kind, seq))?;
    s.flush()
}

/// Write a prebuilt record verbatim (HELLO/FIN records the session layer
/// already serialized).
pub(crate) fn write_raw(s: &mut TcpStream, rec: &[u8]) -> std::io::Result<()> {
    write_all_nb(s, rec)?;
    s.flush()
}

/// Write one telemetry record (header + payload) in a single buffered
/// write, reusing `scratch` so the hot path allocates nothing. Oversized
/// payloads surface as an error before any byte hits the wire.
pub(crate) fn write_telemetry(
    s: &mut TcpStream,
    payload: &[u8],
    scratch: &mut Vec<u8>,
) -> crate::Result<()> {
    scratch.clear();
    append_telemetry_record(scratch, payload)?;
    write_raw(s, scratch)?;
    Ok(())
}

/// Outcome of a non-blocking read sweep (a direct [`read_available`]
/// call or a reactor inbox drain via
/// [`super::reactor::Registration::drain_into`]).
pub enum ReadSweep {
    /// Bytes (possibly zero) drained; the connection is still alive.
    Alive,
    /// EOF or I/O error: the connection is gone (whatever was read
    /// before the end is still in `into`).
    Dead,
}

/// Drain whatever is available on `stream` into `into` without blocking
/// (the stream is returned to blocking mode before this returns).
///
/// Pre-registration use only: once a stream is handed to the reactor
/// ([`super::reactor::Reactor::register`]) its inbox drain replaces
/// this, and the blocking-mode restore here would fight the reactor's
/// permanent O_NONBLOCK. The remaining caller is the dial handshake,
/// which sweeps control bytes off the fresh, not-yet-registered stream.
pub(crate) fn read_available(stream: &mut TcpStream, into: &mut Vec<u8>) -> ReadSweep {
    if stream.set_nonblocking(true).is_err() {
        return ReadSweep::Dead;
    }
    let mut tmp = [0u8; 4096];
    let alive = loop {
        match stream.read(&mut tmp) {
            Ok(0) => break false,
            Ok(n) => into.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => break true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break false,
        }
    };
    if !alive || stream.set_nonblocking(false).is_err() {
        return ReadSweep::Dead;
    }
    ReadSweep::Alive
}

/// Read exactly one control record with a bounded blocking wait (the
/// dialer waiting for the receiver's `HELLO` on a fresh connection).
pub(crate) fn read_ctrl_timeout(stream: &mut TcpStream, budget: Duration) -> Result<[u8; CTRL_LEN]> {
    stream
        .set_read_timeout(Some(budget.max(Duration::from_millis(1))))
        .ok();
    let mut rec = [0u8; CTRL_LEN];
    stream
        .read_exact(&mut rec)
        .map_err(|e| anyhow::anyhow!("no HELLO from peer: {e}"))?;
    stream.set_read_timeout(None).ok();
    Ok(rec)
}

/// Accept every connection currently queued on `listener` without
/// blocking (a striped receiver greets however many stripes dial in).
pub(crate) fn accept_pending(listener: &TcpListener) -> Vec<TcpStream> {
    let mut out = Vec::new();
    if listener.set_nonblocking(true).is_err() {
        return out;
    }
    loop {
        match listener.accept() {
            Ok((stream, _)) => out.push(stream),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break, // WouldBlock or a real error: stop sweeping
        }
    }
    listener.set_nonblocking(false).ok();
    out
}

/// Dialing side of one connection: the socket slot plus redial
/// bookkeeping. The owning boundary decides when to dial and performs
/// the session handshake on the fresh stream.
pub(crate) struct DialConduit {
    pub conn: Option<TcpStream>,
    /// Reactor registration for the current connection: the reactor
    /// sweeps inbound bytes into its inbox and fires the boundary's
    /// `Notify`. Dropped (deregistering) whenever the conduit goes down.
    pub reg: Option<super::reactor::Registration>,
    /// Incremental decoder over inbound control bytes from the current
    /// connection (one wire parser for both directions — see
    /// [`super::session::WireDecoder`]).
    pub decoder: super::session::WireDecoder,
    pub kill: LinkKillSwitch,
    /// Decorrelates this conduit's backoff jitter from its fleet-mates'.
    pub nonce: u64,
    pub dials: u64,
    pub ever_connected: bool,
    /// When the conduit went down (None while connected or never used).
    pub down_since: Option<Instant>,
    /// Earliest next opportunistic revival attempt while other stripes
    /// keep the boundary alive.
    pub next_retry: Option<Instant>,
    retry_delay: Duration,
    /// EWMA of recent write stall, µs (the least-stalled stripe bias).
    pub stall_ewma_us: f64,
}

impl Default for DialConduit {
    fn default() -> Self {
        Self::new()
    }
}

impl DialConduit {
    pub fn new() -> Self {
        DialConduit {
            conn: None,
            reg: None,
            decoder: super::session::WireDecoder::new(),
            kill: LinkKillSwitch::new(),
            nonce: endpoint_nonce(),
            dials: 0,
            ever_connected: false,
            down_since: None,
            next_retry: None,
            retry_delay: Duration::from_millis(1),
            stall_ewma_us: 0.0,
        }
    }

    pub fn is_connected(&self) -> bool {
        self.conn.is_some()
    }

    /// Drop the connection and start the revival schedule.
    pub fn mark_down(&mut self, base: Duration) {
        if let Some(s) = &self.conn {
            let _ = s.shutdown(Shutdown::Both);
        }
        self.conn = None;
        self.reg = None; // deregisters from the reactor
        self.decoder = super::session::WireDecoder::new();
        let now = Instant::now();
        if self.down_since.is_none() {
            self.down_since = Some(now);
        }
        self.retry_delay = base.max(Duration::from_millis(1));
        self.next_retry = Some(now + self.retry_delay);
    }

    /// A revival attempt failed: back off the schedule.
    pub fn retry_failed(&mut self, max: Duration) {
        self.retry_delay = (self.retry_delay * 2).min(max.max(Duration::from_millis(1)));
        self.next_retry = Some(Instant::now() + self.retry_delay);
    }

    /// Is an opportunistic revival attempt due?
    pub fn revival_due(&self) -> bool {
        !self.is_connected() && self.next_retry.map_or(false, |t| Instant::now() >= t)
    }

    /// Install a freshly handshaken stream, registering it with the
    /// process reactor (which flips it nonblocking for good; the write
    /// helpers handle that). Failure to register leaves the conduit
    /// down — the caller's normal revival schedule retries.
    pub fn install(
        &mut self,
        stream: TcpStream,
        notify: &Arc<crate::util::sync::Notify>,
    ) -> std::io::Result<()> {
        let reg = super::reactor::global()?.register(&stream, notify.clone())?;
        self.kill.register(&stream);
        self.reg = Some(reg);
        self.conn = Some(stream);
        self.down_since = None;
        self.next_retry = None;
        self.ever_connected = true;
        Ok(())
    }

    /// Fold one measured write stall into the bias EWMA.
    pub fn note_stall(&mut self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.stall_ewma_us = 0.8 * self.stall_ewma_us + 0.2 * us;
    }

    /// One quick, bounded dial (revival while other stripes carry the
    /// boundary — must never stall the send path for long).
    pub fn dial_quick(&mut self, peer: &str, budget: Duration) -> std::io::Result<TcpStream> {
        self.dials += 1;
        let addr = peer
            .to_socket_addrs()
            .ok()
            .and_then(|mut a| a.next())
            .ok_or_else(|| std::io::Error::new(ErrorKind::InvalidInput, "unresolvable peer"))?;
        TcpStream::connect_timeout(&addr, budget.max(Duration::from_millis(1)))
    }

    /// Dial until `deadline`, sleeping per the backoff schedule (the
    /// full-outage path: nothing else is carrying the boundary).
    pub fn dial_blocking(
        &mut self,
        peer: &str,
        deadline: Instant,
        backoff: &mut Backoff,
    ) -> Result<TcpStream> {
        self.dials += 1;
        connect_until(peer, deadline, backoff)
    }
}

impl Drop for DialConduit {
    fn drop(&mut self) {
        // Without an explicit drain the peer sees EOF-without-FIN and
        // treats it as the failure it is. Never block in drop.
        if let Some(s) = &self.conn {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

/// Accepted side of one connection: the stream plus its incremental
/// decode buffer (a striped receiver cannot block on one conduit while
/// another has data, so all reads are sweeps).
pub(crate) struct AcceptedConduit {
    pub stream: TcpStream,
    pub decoder: super::session::WireDecoder,
    /// Reactor registration: inbound bytes arrive via its inbox.
    pub reg: super::reactor::Registration,
}

impl AcceptedConduit {
    /// Register `stream` with the process reactor under the boundary's
    /// `notify`. Failure means the conduit never joins the boundary —
    /// the peer redials, exactly as for a failed greeting.
    pub fn new(
        stream: TcpStream,
        notify: &Arc<crate::util::sync::Notify>,
    ) -> std::io::Result<Self> {
        let reg = super::reactor::global()?.register(&stream, notify.clone())?;
        Ok(AcceptedConduit { stream, decoder: super::session::WireDecoder::new(), reg })
    }
}

impl Drop for AcceptedConduit {
    fn drop(&mut self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }
}
