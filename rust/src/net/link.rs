//! Serialization-delay link model with trace-driven capacity.
//!
//! Replaces `tc`-shaped Ethernet between Jetsons. A send occupies the link
//! for `bytes / capacity(t)` (integrated across capacity changes), plus a
//! fixed propagation latency and optional jitter; loss injection re-sends
//! after a timeout, consuming extra link time — the observable effect the
//! adaptive controller must react to.
//!
//! Implementation: the link keeps a `busy_until` watermark (serialization
//! is serial); senders compute their completion instant under the trace
//! and sleep until it. The model is *time-based*, not token-based, so the
//! sleep maths is exact and unit-tested against the pure
//! [`BandwidthTrace::transmit_secs`].

use super::trace::BandwidthTrace;
use crate::util::sync::TrackedMutex;
use std::time::{Duration, Instant};

/// Link impairment/failure-injection knobs.
#[derive(Debug, Clone, Copy)]
pub struct LinkFaults {
    /// Per-frame loss probability; each loss costs one extra latency +
    /// a full re-serialization.
    pub loss_p: f64,
    /// Uniform extra jitter bound (seconds) added per frame.
    pub jitter_s: f64,
    /// Deterministic seed for reproducible fault schedules.
    pub seed: u64,
}

impl Default for LinkFaults {
    fn default() -> Self {
        LinkFaults { loss_p: 0.0, jitter_s: 0.0, seed: 0 }
    }
}

/// A shaped, unidirectional link.
pub struct SimLink {
    trace: BandwidthTrace,
    /// One-way propagation latency.
    latency: Duration,
    faults: LinkFaults,
    state: TrackedMutex<LinkState>,
    epoch: Instant,
}

#[derive(Debug)]
struct LinkState {
    /// Seconds-from-epoch when the serializer frees up.
    busy_until: f64,
    /// xorshift state for fault injection.
    rng: u64,
    bytes_sent: u64,
    frames_sent: u64,
    frames_lost: u64,
}

impl SimLink {
    /// Trace-shaped link with no latency or faults.
    pub fn new(trace: BandwidthTrace) -> Self {
        Self::with_faults(trace, Duration::from_micros(200), LinkFaults::default())
    }

    /// Trace-shaped link with propagation latency + fault injection.
    pub fn with_faults(trace: BandwidthTrace, latency: Duration, faults: LinkFaults) -> Self {
        SimLink {
            trace,
            latency,
            faults,
            state: TrackedMutex::new(
                "link.state",
                LinkState {
                    busy_until: 0.0,
                    rng: faults.seed | 1,
                    bytes_sent: 0,
                    frames_sent: 0,
                    frames_lost: 0,
                },
            ),
            epoch: Instant::now(),
        }
    }

    /// Infinite-bandwidth link (no shaping).
    pub fn unlimited() -> Self {
        Self::new(BandwidthTrace::unlimited())
    }

    /// Capacity currently configured (what `tc` would report — the
    /// controller must NOT call this; it measures instead).
    pub fn capacity_now(&self) -> f64 {
        self.trace.at(self.epoch.elapsed().as_secs_f64())
    }

    /// (bytes, frames, lost) counters for offline analysis.
    pub fn counters(&self) -> (u64, u64, u64) {
        let s = self.state.guard();
        (s.bytes_sent, s.frames_sent, s.frames_lost)
    }

    fn xorshift(rng: &mut u64) -> f64 {
        let mut x = *rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *rng = x;
        (x >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Transmit `bytes`, blocking until the last byte has arrived at the
    /// receiver. Returns the seconds the link was occupied (serialization
    /// + queueing, excluding propagation) — the sender-side "output
    /// bandwidth" measurement uses this.
    pub fn send(&self, bytes: usize) -> Duration {
        let (done_rel, occupied) = {
            let mut st = self.state.guard();
            let now_rel = self.epoch.elapsed().as_secs_f64();
            let start_rel = st.busy_until.max(now_rel);
            let mut ser_secs = self.trace.transmit_secs(bytes, start_rel);

            // Fault injection: a lost frame is retransmitted after one
            // latency timeout, costing latency + a full re-serialization.
            let mut lost = 0u64;
            while self.faults.loss_p > 0.0 && Self::xorshift(&mut st.rng) < self.faults.loss_p {
                lost += 1;
                ser_secs = ser_secs * 2.0 + self.latency.as_secs_f64();
                if lost >= 4 {
                    break; // retry cap: bound worst-case occupancy
                }
            }
            let jitter = if self.faults.jitter_s > 0.0 {
                Self::xorshift(&mut st.rng) * self.faults.jitter_s
            } else {
                0.0
            };

            // Clamp runaway serialization (e.g. zero-capacity trace tails).
            let ser_secs = ser_secs.min(3600.0);
            let done_rel = start_rel + ser_secs;
            st.busy_until = done_rel;
            st.bytes_sent += bytes as u64;
            st.frames_sent += 1;
            st.frames_lost += lost;
            (done_rel + self.latency.as_secs_f64() + jitter, done_rel - now_rel)
        };
        // Sleep off the remaining wait (other senders may have queued more
        // behind us meanwhile; our own completion time is already fixed).
        loop {
            let now_rel = self.epoch.elapsed().as_secs_f64();
            if now_rel >= done_rel {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64((done_rel - now_rel).min(0.05)));
        }
        Duration::from_secs_f64(occupied.max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mbps;
    use std::sync::Arc;

    #[test]
    fn unlimited_link_is_latency_only() {
        let link = SimLink::with_faults(
            BandwidthTrace::unlimited(),
            Duration::from_millis(1),
            LinkFaults::default(),
        );
        let t0 = Instant::now();
        link.send(10 << 20);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn serialization_matches_capacity() {
        // 100 KB over 8 Mbps = 100 ms.
        let link = SimLink::new(BandwidthTrace::constant(mbps(8.0)));
        let t0 = Instant::now();
        let occ = link.send(100_000);
        let dt = t0.elapsed().as_secs_f64();
        assert!((dt - 0.1).abs() < 0.03, "{dt}");
        assert!((occ.as_secs_f64() - 0.1).abs() < 0.02, "{occ:?}");
    }

    #[test]
    fn back_to_back_sends_queue() {
        let link = Arc::new(SimLink::new(BandwidthTrace::constant(mbps(8.0))));
        let t0 = Instant::now();
        let a = link.clone();
        let h1 = std::thread::spawn(move || a.send(50_000));
        let b = link.clone();
        let h2 = std::thread::spawn(move || b.send(50_000));
        h1.join().unwrap();
        h2.join().unwrap();
        // Two 50 ms serializations share the link -> ~100 ms total.
        let dt = t0.elapsed().as_secs_f64();
        assert!((0.08..0.2).contains(&dt), "{dt}");
    }

    #[test]
    fn capacity_change_mid_send() {
        // First 50 ms at 8 Mbps, then 80 Mbps: 100 KB = 50 KB + 50 KB.
        let tr = BandwidthTrace::from_points(&[(0.0, mbps(8.0)), (0.05, mbps(80.0))]);
        let link = SimLink::new(tr);
        let t0 = Instant::now();
        link.send(100_000); // 50 KB in 0.05 s + 50 KB in 0.005 s
        let dt = t0.elapsed().as_secs_f64();
        assert!((dt - 0.055).abs() < 0.025, "{dt}");
    }

    #[test]
    fn loss_injection_slows_link() {
        let faults = LinkFaults { loss_p: 1.0, jitter_s: 0.0, seed: 42 };
        let lossy = SimLink::with_faults(
            BandwidthTrace::constant(mbps(80.0)),
            Duration::from_millis(1),
            faults,
        );
        let clean = SimLink::new(BandwidthTrace::constant(mbps(80.0)));
        let t0 = Instant::now();
        clean.send(100_000);
        let clean_dt = t0.elapsed();
        let t1 = Instant::now();
        lossy.send(100_000);
        let lossy_dt = t1.elapsed();
        assert!(lossy_dt > clean_dt * 2, "{clean_dt:?} vs {lossy_dt:?}");
        assert!(lossy.counters().2 > 0);
    }

    #[test]
    fn counters_accumulate() {
        let link = SimLink::unlimited();
        link.send(100);
        link.send(200);
        let (bytes, frames, lost) = link.counters();
        assert_eq!(bytes, 300);
        assert_eq!(frames, 2);
        assert_eq!(lost, 0);
    }
}
