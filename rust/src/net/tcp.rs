//! TCP transport for multi-process deployments: each stage process owns
//! its shard and connects to its neighbours over real sockets (the frame
//! format is identical to the in-proc path, so the pipeline logic is
//! transport-agnostic).
//!
//! Frames go over the socket length-prefixed (`u32 LE length || frame
//! bytes`); the frame's own header/CRC provide integrity. Bandwidth is
//! whatever the real network (or an external `tc` config) provides — this
//! path exists to show the system runs across real sockets, while the
//! simulated in-proc transport is the measurement substrate.

use super::frame::Frame;
use crate::Result;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

pub struct TcpFrameSender {
    stream: TcpStream,
}

pub struct TcpFrameReceiver {
    stream: TcpStream,
    buf: Vec<u8>,
}

/// Split a connected stream into framed halves.
pub fn framed(stream: TcpStream) -> Result<(TcpFrameSender, TcpFrameReceiver)> {
    stream.set_nodelay(true).ok();
    let rx_stream = stream.try_clone()?;
    Ok((
        TcpFrameSender { stream },
        TcpFrameReceiver { stream: rx_stream, buf: Vec::new() },
    ))
}

/// Connect to a downstream worker.
pub fn connect(addr: &str) -> Result<(TcpFrameSender, TcpFrameReceiver)> {
    framed(TcpStream::connect(addr)?)
}

/// Accept one upstream connection.
pub fn accept_one(listener: &TcpListener) -> Result<(TcpFrameSender, TcpFrameReceiver)> {
    let (stream, _) = listener.accept()?;
    framed(stream)
}

impl Drop for TcpFrameSender {
    fn drop(&mut self) {
        // Half-close so the peer's reader sees EOF even while our own
        // receiver clone keeps the socket alive.
        let _ = self.stream.shutdown(std::net::Shutdown::Write);
    }
}

impl TcpFrameSender {
    /// Ship one frame; returns seconds spent writing (the socket's own
    /// backpressure is the bandwidth signal in TCP mode).
    pub fn send(&mut self, frame: Frame) -> Result<f64> {
        let bytes = frame.to_bytes();
        let t0 = Instant::now();
        self.stream.write_all(&(bytes.len() as u32).to_le_bytes())?;
        self.stream.write_all(&bytes)?;
        self.stream.flush()?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

impl TcpFrameReceiver {
    /// Next frame; `None` on EOF/abort. CRC failures skip the frame.
    pub fn recv(&mut self) -> Option<Frame> {
        loop {
            let mut len = [0u8; 4];
            self.stream.read_exact(&mut len).ok()?;
            let n = u32::from_le_bytes(len) as usize;
            if n > 1 << 30 {
                return None; // absurd length: treat as corrupt stream
            }
            self.buf.resize(n, 0);
            self.stream.read_exact(&mut self.buf).ok()?;
            match Frame::from_bytes(&self.buf) {
                Ok(f) => return Some(f),
                Err(_) => continue,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codec::Codec;
    use crate::quant::Method;

    fn frame(seq: u64, n: usize) -> Frame {
        let x: Vec<f32> = (0..n).map(|i| ((i + seq as usize) as f32).sin()).collect();
        let mut c = Codec::default();
        Frame::new(seq, vec![n], c.encode(&x, Method::Pda, 4).unwrap())
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            let mut seqs = Vec::new();
            while let Some(f) = rx.recv() {
                seqs.push(f.seq);
                if seqs.len() == 5 {
                    break;
                }
            }
            seqs
        });
        let (mut tx, _rx) = connect(&addr).unwrap();
        for seq in 0..5 {
            tx.send(frame(seq, 512)).unwrap();
        }
        assert_eq!(server.join().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn tcp_large_frame() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv().unwrap()
        });
        let (mut tx, _rx) = connect(&addr).unwrap();
        let f = frame(9, 1024 * 256); // 256k elements, 4-bit → 128 KB payload
        tx.send(f.clone()).unwrap();
        assert_eq!(server.join().unwrap(), f);
    }

    #[test]
    fn tcp_eof_returns_none() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv()
        });
        let (tx, _rx) = connect(&addr).unwrap();
        drop(tx); // close without sending
        assert!(server.join().unwrap().is_none());
    }
}
