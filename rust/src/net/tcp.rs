//! TCP transport for multi-process deployments: each stage process owns
//! its shard and connects to its neighbours over real sockets (the frame
//! format is identical to the in-proc path, so the pipeline logic is
//! transport-agnostic).
//!
//! Frames go over the socket length-prefixed (`u32 LE length || frame
//! bytes`); the frame's own header/CRC provide integrity. Bandwidth is
//! whatever the real network (or an external `tc` config) provides; the
//! adaptive controller infers it from measured write-stall time — a full
//! kernel send buffer blocks `write`, and that backpressure IS the
//! congestion signal, exactly as on the paper's testbed.
//!
//! Receive-side error taxonomy (see [`TcpFrameReceiver::recv`]):
//! * `Ok(Some(frame))` — next frame;
//! * `Ok(None)` — clean shutdown: the peer closed between frames;
//! * `Err(..)` — link failure: I/O error, EOF mid-frame, a corrupt
//!   length prefix, **or a frame failing its CRC/header check**. The
//!   driver reports these instead of treating them as a quiet end of
//!   stream. Plain TCP has no replay buffer, so "skipping" a corrupt
//!   frame would be a permanent sequence gap — silent data loss; the
//!   session-bearing transports ([`super::resilient`],
//!   [`super::stripe`]) instead treat corruption as a conduit desync and
//!   recover the frame by reconnect + replay.

use super::frame::Frame;
use super::session::{
    append_telemetry_record, parse_ctrl, CTRL_LEN, CTRL_MARKER, K_TELEMETRY, MAX_TELEMETRY_BYTES,
};
use super::transport::{FrameRx, FrameTx, PreparedFrame};
use crate::Result;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Upper bound on an incoming frame's length prefix; anything larger is a
/// corrupt or hostile stream, not a real activation frame. (Owned by the
/// session layer, which shares the wire format; re-exported here for the
/// plain-TCP receiver's historical import path.)
pub use super::session::MAX_FRAME_BYTES;

/// Sender half of a plain (non-resilient) TCP stage boundary.
pub struct TcpFrameSender {
    stream: TcpStream,
    /// Per-link wire buffer: frames serialize into it ([`Frame::write_into`])
    /// instead of allocating a fresh `Vec` per frame.
    wire: Vec<u8>,
    /// Written-out [`PreparedFrame`] buffers awaiting
    /// [`FrameTx::reclaim_wire`], so the producing stage can reuse them.
    spares: Vec<Vec<u8>>,
}

/// Receiver half of a plain (non-resilient) TCP stage boundary.
pub struct TcpFrameReceiver {
    stream: TcpStream,
    buf: Vec<u8>,
    /// Telemetry payloads read off the stream, awaiting
    /// [`FrameRx::poll_telemetry`].
    tele_inbox: Vec<Vec<u8>>,
}

/// Split a connected stream into framed halves.
pub fn framed(stream: TcpStream) -> Result<(TcpFrameSender, TcpFrameReceiver)> {
    stream.set_nodelay(true).ok();
    let rx_stream = stream.try_clone()?;
    Ok((
        TcpFrameSender { stream, wire: Vec::new(), spares: Vec::new() },
        TcpFrameReceiver { stream: rx_stream, buf: Vec::new(), tele_inbox: Vec::new() },
    ))
}

/// Connect to a downstream worker.
pub fn connect(addr: &str) -> Result<(TcpFrameSender, TcpFrameReceiver)> {
    framed(TcpStream::connect(addr)?)
}

/// Exponential backoff with deterministic jitter, shared by startup
/// connect-retry and the resilient layer's mid-run reconnects. Delays
/// double from `base` up to `max`; each is scaled by a factor drawn
/// uniformly from `[1 - jitter, 1]` so a fleet of peers retrying the same
/// dead link doesn't thundering-herd it back up in lockstep.
#[derive(Debug)]
pub struct Backoff {
    next: Duration,
    base: Duration,
    max: Duration,
    jitter: f64,
    rng: crate::util::rng::Rng,
}

impl Backoff {
    /// Schedule starting at `base`, doubling up to `max`, jittered per `seed`.
    pub fn new(base: Duration, max: Duration, jitter: f64, seed: u64) -> Self {
        let base = base.max(Duration::from_millis(1));
        Backoff {
            next: base,
            base,
            max: max.max(base),
            jitter: jitter.clamp(0.0, 1.0),
            rng: crate::util::rng::Rng::seed(seed),
        }
    }

    /// Fixed-interval "backoff" (the startup connect-retry behaviour).
    pub fn constant(interval: Duration) -> Self {
        Backoff::new(interval, interval, 0.0, 0)
    }

    /// Next sleep, advancing the schedule.
    pub fn next_delay(&mut self) -> Duration {
        let d = self.next;
        self.next = (self.next * 2).min(self.max);
        let scale = 1.0 - self.jitter * self.rng.f64();
        Duration::from_secs_f64(d.as_secs_f64() * scale).max(Duration::from_millis(1))
    }

    /// Back to the initial delay (call after a successful attempt).
    pub fn reset(&mut self) {
        self.next = self.base;
    }
}

/// Dial `addr` until it succeeds or `deadline` passes, sleeping per the
/// backoff schedule between attempts. The raw-stream primitive under both
/// [`connect_retry`] and the resilient layer's reconnect loop.
pub fn connect_until(addr: &str, deadline: Instant, backoff: &mut Backoff) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    anyhow::bail!("connect to {addr} timed out: {e}");
                }
                std::thread::sleep(backoff.next_delay());
            }
        }
    }
}

/// Connect with retries until `timeout` elapses (multi-process startup is
/// order-independent: workers and the coordinator may launch in any order).
pub fn connect_retry(
    addr: &str,
    timeout: Duration,
    interval: Duration,
) -> Result<(TcpFrameSender, TcpFrameReceiver)> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Backoff::constant(interval);
    let stream = connect_until(addr, deadline, &mut backoff)
        .map_err(|e| anyhow::anyhow!("{e} (gave up after {timeout:?})"))?;
    framed(stream)
}

/// Accept one upstream connection.
pub fn accept_one(listener: &TcpListener) -> Result<(TcpFrameSender, TcpFrameReceiver)> {
    let (stream, _) = listener.accept()?;
    framed(stream)
}

/// A connected localhost socket pair: `(connector side, acceptor side)`.
/// Single-process deployments of the TCP path (tests, demos) use one
/// direction of it per stage boundary.
pub fn loopback_pair(
) -> Result<((TcpFrameSender, TcpFrameReceiver), (TcpFrameSender, TcpFrameReceiver))> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    // lint: allow(thread-spawn): short-lived connect helper for the
    // loopback handshake, joined before this function returns — not a
    // per-conduit reader loop (those belong to the reactor).
    let connector = std::thread::spawn(move || TcpStream::connect(addr));
    let (accepted, _) = listener.accept()?;
    let connected = connector
        .join()
        .map_err(|_| anyhow::anyhow!("loopback connect thread panicked"))??;
    Ok((framed(connected)?, framed(accepted)?))
}

impl Drop for TcpFrameSender {
    fn drop(&mut self) {
        // Half-close so the peer's reader sees EOF even while our own
        // receiver clone keeps the socket alive.
        let _ = self.stream.shutdown(std::net::Shutdown::Write);
    }
}

impl TcpFrameSender {
    /// Ship one frame; returns seconds spent writing (the socket's own
    /// backpressure is the bandwidth signal in TCP mode). Serializes into
    /// the link's reused wire buffer — no per-frame allocation.
    pub fn send(&mut self, frame: Frame) -> Result<f64> {
        frame.write_into(&mut self.wire);
        let t0 = Instant::now();
        self.stream.write_all(&(self.wire.len() as u32).to_le_bytes())?;
        self.stream.write_all(&self.wire)?;
        self.stream.flush()?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

impl TcpFrameSender {
    /// Ship one telemetry record interleaved with the frame stream (the
    /// plain-TCP boundary speaks just this one control record; the
    /// receiver rejects every other kind as a desync).
    pub fn send_telemetry(&mut self, payload: &[u8]) -> Result<()> {
        self.wire.clear();
        append_telemetry_record(&mut self.wire, payload)?;
        self.stream.write_all(&self.wire)?;
        self.stream.flush()?;
        Ok(())
    }
}

impl FrameTx for TcpFrameSender {
    fn send(&mut self, frame: Frame) -> Result<f64> {
        TcpFrameSender::send(self, frame)
    }

    fn send_prepared(&mut self, prepared: PreparedFrame) -> Result<f64> {
        // Already serialized: write the bytes straight out, then park the
        // buffer for reclaim_wire so the stage loop can reuse it.
        let t0 = Instant::now();
        self.stream.write_all(&(prepared.wire.len() as u32).to_le_bytes())?;
        self.stream.write_all(&prepared.wire)?;
        self.stream.flush()?;
        let busy = t0.elapsed().as_secs_f64();
        if self.spares.len() < 4 {
            self.spares.push(prepared.wire);
        }
        Ok(busy)
    }

    fn reclaim_wire(&mut self) -> Option<Vec<u8>> {
        self.spares.pop()
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn send_telemetry(&mut self, payload: &[u8]) -> Result<()> {
        TcpFrameSender::send_telemetry(self, payload)
    }
}

enum Prefix {
    Len(usize),
    CleanEof,
}

impl TcpFrameReceiver {
    /// Next frame. `Ok(None)` = clean shutdown (EOF exactly on a frame
    /// boundary); `Err` = I/O failure, EOF mid-frame, corrupt length
    /// prefix, or a frame failing its CRC/header check. A corrupt frame
    /// is a hard error — plain TCP has no replay buffer, so skipping it
    /// would leave a permanent sequence gap (silent data loss); run
    /// `--resilient` (or `--stripes N`) if the link is expected to
    /// corrupt, and corruption becomes a recoverable desync instead.
    pub fn recv(&mut self) -> Result<Option<Frame>> {
        loop {
            let n = match self.read_prefix()? {
                Prefix::CleanEof => return Ok(None),
                Prefix::Len(n) => n,
            };
            if n == CTRL_MARKER as usize {
                // The one control record plain TCP understands: a
                // telemetry payload interleaved with the frames. Any
                // other kind means a resilient peer on a plain link —
                // a misconfiguration, not a recoverable stream.
                self.read_telemetry()?;
                continue;
            }
            if n > MAX_FRAME_BYTES {
                anyhow::bail!(
                    "corrupt stream: frame length prefix {n} exceeds {MAX_FRAME_BYTES}"
                );
            }
            self.buf.resize(n, 0);
            self.stream.read_exact(&mut self.buf).map_err(|e| {
                anyhow::anyhow!("link failed mid-frame ({n}-byte frame): {e}")
            })?;
            return match Frame::from_bytes(&self.buf) {
                Ok(f) => Ok(Some(f)),
                Err(e) => Err(e.context(
                    "corrupt frame on a plain TCP link (no replay buffer to recover it; \
                     use --resilient for links that corrupt)",
                )),
            };
        }
    }

    /// Finish reading a control record whose marker prefix was already
    /// consumed; only `TELEMETRY{len}` is legal on a plain link.
    fn read_telemetry(&mut self) -> Result<()> {
        let mut rest = [0u8; CTRL_LEN];
        rest[0..4].copy_from_slice(&CTRL_MARKER.to_le_bytes());
        self.stream.read_exact(&mut rest[4..]).map_err(|e| {
            anyhow::anyhow!("link truncated mid-control-record: {e}")
        })?;
        let (kind, len) = parse_ctrl(&rest);
        anyhow::ensure!(
            kind == K_TELEMETRY,
            "unexpected control record kind {kind} on a plain TCP link \
             (is the peer running --resilient against a non-resilient endpoint?)"
        );
        anyhow::ensure!(
            len <= MAX_TELEMETRY_BYTES as u64,
            "corrupt stream: telemetry payload length {len} exceeds {MAX_TELEMETRY_BYTES}"
        );
        let mut payload = vec![0u8; len as usize];
        self.stream.read_exact(&mut payload).map_err(|e| {
            anyhow::anyhow!("link truncated mid-telemetry-record: {e}")
        })?;
        self.tele_inbox.push(payload);
        Ok(())
    }

    /// Read the 4-byte length prefix, distinguishing EOF on the boundary
    /// (clean shutdown) from EOF inside it (truncated stream).
    fn read_prefix(&mut self) -> Result<Prefix> {
        let mut len = [0u8; 4];
        let mut filled = 0usize;
        while filled < len.len() {
            match self.stream.read(&mut len[filled..]) {
                Ok(0) => {
                    if filled == 0 {
                        return Ok(Prefix::CleanEof);
                    }
                    anyhow::bail!(
                        "link truncated mid-length-prefix ({filled}/4 bytes read)"
                    );
                }
                Ok(k) => filled += k,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(anyhow::anyhow!("socket read failed: {e}")),
            }
        }
        Ok(Prefix::Len(u32::from_le_bytes(len) as usize))
    }
}

impl FrameRx for TcpFrameReceiver {
    fn recv(&mut self) -> Result<Option<Frame>> {
        TcpFrameReceiver::recv(self)
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn poll_telemetry(&mut self) -> Vec<Vec<u8>> {
        std::mem::take(&mut self.tele_inbox)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codec::Codec;
    use crate::quant::Method;

    fn frame(seq: u64, n: usize) -> Frame {
        let x: Vec<f32> = (0..n).map(|i| ((i + seq as usize) as f32).sin()).collect();
        let mut c = Codec::default();
        Frame::new(seq, vec![n], c.encode(&x, Method::Pda, 4).unwrap())
    }

    #[test]
    fn tcp_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            let mut seqs = Vec::new();
            while let Some(f) = rx.recv().unwrap() {
                seqs.push(f.seq);
                if seqs.len() == 5 {
                    break;
                }
            }
            seqs
        });
        let (mut tx, _rx) = connect(&addr).unwrap();
        for seq in 0..5 {
            tx.send(frame(seq, 512)).unwrap();
        }
        assert_eq!(server.join().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn tcp_large_frame() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv().unwrap().unwrap()
        });
        let (mut tx, _rx) = connect(&addr).unwrap();
        let f = frame(9, 1024 * 256); // 256k elements, 4-bit → 128 KB payload
        tx.send(f.clone()).unwrap();
        assert_eq!(server.join().unwrap(), f);
    }

    #[test]
    fn tcp_eof_is_clean_none() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv()
        });
        let (tx, _rx) = connect(&addr).unwrap();
        drop(tx); // close without sending
        assert!(server.join().unwrap().unwrap().is_none());
    }

    #[test]
    fn truncation_mid_frame_is_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv()
        });
        // Claim a 100-byte frame, deliver 10, then close: not a clean EOF.
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&100u32.to_le_bytes()).unwrap();
        raw.write_all(&[0u8; 10]).unwrap();
        drop(raw);
        let err = server.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("mid-frame"), "{err:#}");
    }

    #[test]
    fn truncation_mid_prefix_is_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv()
        });
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&[7u8, 7]).unwrap(); // 2 of 4 prefix bytes
        drop(raw);
        let err = server.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("mid-length-prefix"), "{err:#}");
    }

    #[test]
    fn absurd_length_is_error() {
        // u32::MAX is the control marker now, so the absurd-but-plausible
        // length is one past the frame bound.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv()
        });
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes()).unwrap();
        let err = server.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("corrupt stream"), "{err:#}");
        drop(raw);
    }

    #[test]
    fn telemetry_records_interleave_with_plain_tcp_frames() {
        use crate::net::transport::FrameRx as _;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            let a = rx.recv().unwrap().unwrap();
            // Telemetry between the frames is invisible to recv()…
            let b = rx.recv().unwrap().unwrap();
            assert!(rx.recv().unwrap().is_none());
            // …and waits in the inbox, in arrival order.
            let telemetry = rx.poll_telemetry();
            assert!(rx.poll_telemetry().is_empty(), "poll drains the inbox");
            (a.seq, b.seq, telemetry)
        });
        let (mut tx, _rx) = connect(&addr).unwrap();
        tx.send(frame(0, 64)).unwrap();
        tx.send_telemetry(b"snapshot-0").unwrap();
        tx.send(frame(1, 64)).unwrap();
        tx.send_telemetry(b"snapshot-1").unwrap();
        drop(tx);
        let (a, b, telemetry) = server.join().unwrap();
        assert_eq!((a, b), (0, 1));
        assert_eq!(telemetry, vec![b"snapshot-0".to_vec(), b"snapshot-1".to_vec()]);
    }

    #[test]
    fn non_telemetry_control_record_on_plain_link_is_an_error() {
        // A resilient peer aimed at a plain endpoint desyncs on its first
        // HELLO/ACK — that must be a loud misconfiguration error.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv()
        });
        let mut raw = TcpStream::connect(&addr).unwrap();
        raw.write_all(&crate::net::session::ctrl_record(crate::net::session::K_ACK, 5))
            .unwrap();
        let err = server.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("control record"), "{err:#}");
        drop(raw);
    }

    #[test]
    fn crc_corrupt_frame_is_a_hard_error() {
        // Plain TCP has no replay buffer: "skipping" a corrupt frame
        // would be a silent, permanent loss of its sequence number. The
        // receiver must surface corruption loudly and point at the
        // resilient mode that can actually recover it.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (_tx, mut rx) = accept_one(&listener).unwrap();
            rx.recv()
        });
        let mut raw = TcpStream::connect(&addr).unwrap();
        let mut bad = frame(0, 64).to_bytes();
        let n = bad.len();
        bad[n - 1] ^= 0xff; // payload corruption -> CRC mismatch
        raw.write_all(&(bad.len() as u32).to_le_bytes()).unwrap();
        raw.write_all(&bad).unwrap();
        let err = server.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("corrupt frame"), "{err:#}");
        assert!(err.to_string().contains("--resilient"), "{err:#}");
        drop(raw);
    }

    #[test]
    fn loopback_pair_is_connected_both_ways() {
        let ((mut a_tx, mut a_rx), (mut b_tx, mut b_rx)) = loopback_pair().unwrap();
        a_tx.send(frame(3, 32)).unwrap();
        assert_eq!(b_rx.recv().unwrap().unwrap().seq, 3);
        b_tx.send(frame(4, 32)).unwrap();
        assert_eq!(a_rx.recv().unwrap().unwrap().seq, 4);
    }

    #[test]
    fn backoff_doubles_caps_and_jitters_down_only() {
        let mut b = Backoff::new(
            Duration::from_millis(10),
            Duration::from_millis(80),
            0.5,
            7,
        );
        let mut expected = 10u64;
        for _ in 0..6 {
            let d = b.next_delay().as_secs_f64();
            let nominal = expected as f64 / 1e3;
            assert!(d <= nominal + 1e-9, "jitter must never extend the delay: {d} > {nominal}");
            assert!(d >= nominal * 0.5 - 1e-9, "jitter floor violated: {d} < {}", nominal * 0.5);
            expected = (expected * 2).min(80);
        }
        b.reset();
        assert!(b.next_delay() <= Duration::from_millis(10));
        // Deterministic per seed.
        let seq = |seed| {
            let mut b = Backoff::new(Duration::from_millis(5), Duration::from_millis(40), 0.9, seed);
            (0..5).map(|_| b.next_delay()).collect::<Vec<_>>()
        };
        assert_eq!(seq(3), seq(3));
    }

    #[test]
    fn connect_retry_times_out_cleanly() {
        // Nothing listens on this freshly-bound-then-dropped port.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = connect_retry(
            &addr,
            Duration::from_millis(80),
            Duration::from_millis(20),
        )
        .unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err:#}");
    }
}
