//! Piecewise-constant bandwidth traces.
//!
//! A trace is the experiment's external schedule of link-capacity changes
//! (the paper drives these with Linux `tc` "at roughly 200-microbatch
//! intervals"). QuantPipe itself never reads the trace — only the link
//! does; the adaptive controller must infer capacity from its own window
//! measurements.

use super::{mbps, Bps};

/// One segment: from `start` seconds onward, capacity is `bps`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    /// Seconds from run start when this capacity takes effect.
    pub start: f64,
    /// Capacity from `start` onward (bits/s).
    pub bps: Bps,
}

/// Piecewise-constant bandwidth over time. Segments are sorted by start;
/// capacity before the first segment is unlimited.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BandwidthTrace {
    /// Segments sorted by `start` (capacity before the first is unlimited).
    pub segments: Vec<Segment>,
}

impl BandwidthTrace {
    /// Constant-capacity trace.
    pub fn constant(bps: Bps) -> Self {
        BandwidthTrace { segments: vec![Segment { start: 0.0, bps }] }
    }

    /// Unlimited capacity (nominal state).
    pub fn unlimited() -> Self {
        Self::constant(f64::INFINITY)
    }

    /// Build from (start_secs, bps) pairs; sorts by start.
    pub fn from_points(points: &[(f64, Bps)]) -> Self {
        let mut segments: Vec<Segment> =
            points.iter().map(|&(start, bps)| Segment { start, bps }).collect();
        segments.sort_by(|a, b| a.start.total_cmp(&b.start));
        BandwidthTrace { segments }
    }

    /// The paper's Fig 5 schedule, parameterized by phase length in seconds:
    /// unlimited → 400 Mbps → 50 Mbps → 200 Mbps → unlimited.
    pub fn fig5(phase_secs: f64) -> Self {
        Self::from_points(&[
            (0.0, f64::INFINITY),
            (phase_secs, mbps(400.0)),
            (2.0 * phase_secs, mbps(50.0)),
            (3.0 * phase_secs, mbps(200.0)),
            (4.0 * phase_secs, f64::INFINITY),
        ])
    }

    /// Capacity at absolute time `t` seconds.
    pub fn at(&self, t: f64) -> Bps {
        let mut bw = f64::INFINITY;
        for s in &self.segments {
            if s.start <= t {
                bw = s.bps;
            } else {
                break;
            }
        }
        bw
    }

    /// Next capacity-change instant strictly after `t`, if any.
    pub fn next_change(&self, t: f64) -> Option<f64> {
        self.segments.iter().map(|s| s.start).find(|&s| s > t)
    }

    /// Time to serialize `bytes` onto the link starting at time `t`,
    /// integrating across capacity changes. Returns `f64::INFINITY` if the
    /// trace pins capacity at zero forever.
    pub fn transmit_secs(&self, bytes: usize, t: f64) -> f64 {
        let mut remaining_bits = bytes as f64 * 8.0;
        let mut now = t;
        let mut elapsed = 0.0;
        // Bounded iteration: at most segments + 1 spans.
        for _ in 0..=self.segments.len() + 1 {
            if remaining_bits <= 0.0 {
                return elapsed;
            }
            let bw = self.at(now);
            let until = self.next_change(now);
            if bw.is_infinite() {
                match until {
                    // Unlimited: everything flushes instantly.
                    _ => return elapsed,
                }
            }
            if bw <= 0.0 {
                match until {
                    Some(u) => {
                        elapsed += u - now;
                        now = u;
                        continue;
                    }
                    None => return f64::INFINITY,
                }
            }
            let span = until.map(|u| u - now).unwrap_or(f64::INFINITY);
            let can_send = bw * span;
            if can_send >= remaining_bits {
                return elapsed + remaining_bits / bw;
            }
            remaining_bits -= can_send;
            elapsed += span;
            now += span;
        }
        elapsed
    }

    /// Parse `"0:inf,10:400M,20:50M"` → trace (seconds:capacity; suffixes
    /// K/M/G are bits/s multipliers, `inf` = unlimited).
    pub fn parse(s: &str) -> crate::Result<Self> {
        let mut points = Vec::new();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let (t, bw) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad trace segment {part:?} (want time:bw)"))?;
            let t: f64 = t.trim().parse()?;
            let bw = bw.trim();
            let bps = if bw.eq_ignore_ascii_case("inf") {
                f64::INFINITY
            } else {
                let (num, mult) = match bw.chars().last() {
                    Some('K') | Some('k') => (&bw[..bw.len() - 1], 1e3),
                    Some('M') | Some('m') => (&bw[..bw.len() - 1], 1e6),
                    Some('G') | Some('g') => (&bw[..bw.len() - 1], 1e9),
                    _ => (bw, 1.0),
                };
                num.trim().parse::<f64>()? * mult
            };
            points.push((t, bps));
        }
        anyhow::ensure!(!points.is_empty(), "empty bandwidth trace");
        Ok(Self::from_points(&points))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_piecewise() {
        let tr = BandwidthTrace::from_points(&[(0.0, 100.0), (10.0, 50.0), (20.0, 200.0)]);
        assert_eq!(tr.at(0.0), 100.0);
        assert_eq!(tr.at(9.99), 100.0);
        assert_eq!(tr.at(10.0), 50.0);
        assert_eq!(tr.at(25.0), 200.0);
        assert_eq!(tr.next_change(0.0), Some(10.0));
        assert_eq!(tr.next_change(10.0), Some(20.0));
        assert_eq!(tr.next_change(20.0), None);
    }

    #[test]
    fn transmit_constant() {
        let tr = BandwidthTrace::constant(mbps(8.0)); // 1 MB/s
        let dt = tr.transmit_secs(1_000_000, 0.0);
        assert!((dt - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transmit_across_change() {
        // 1 MB at 8 Mbps for 0.5 s (0.5 MB) then 16 Mbps (0.5 MB in 0.25 s).
        let tr = BandwidthTrace::from_points(&[(0.0, mbps(8.0)), (0.5, mbps(16.0))]);
        let dt = tr.transmit_secs(1_000_000, 0.0);
        assert!((dt - 0.75).abs() < 1e-9, "{dt}");
    }

    #[test]
    fn transmit_unlimited_is_instant() {
        let tr = BandwidthTrace::unlimited();
        assert_eq!(tr.transmit_secs(1 << 30, 5.0), 0.0);
    }

    #[test]
    fn transmit_through_outage() {
        // Zero capacity until t=2, then 8 Mbps.
        let tr = BandwidthTrace::from_points(&[(0.0, 0.0), (2.0, mbps(8.0))]);
        let dt = tr.transmit_secs(1_000_000, 0.0);
        assert!((dt - 3.0).abs() < 1e-9, "{dt}");
        // Permanent outage -> infinite.
        let dead = BandwidthTrace::constant(0.0);
        assert!(dead.transmit_secs(1, 0.0).is_infinite());
    }

    #[test]
    fn parse_roundtrip() {
        let tr = BandwidthTrace::parse("0:inf, 10:400M, 20:50M, 30:1.5G").unwrap();
        assert_eq!(tr.segments.len(), 4);
        assert!(tr.at(0.0).is_infinite());
        assert_eq!(tr.at(15.0), 400e6);
        assert_eq!(tr.at(35.0), 1.5e9);
        assert!(BandwidthTrace::parse("").is_err());
        assert!(BandwidthTrace::parse("nope").is_err());
    }

    #[test]
    fn fig5_phases() {
        let tr = BandwidthTrace::fig5(10.0);
        assert!(tr.at(5.0).is_infinite());
        assert_eq!(tr.at(15.0), mbps(400.0));
        assert_eq!(tr.at(25.0), mbps(50.0));
        assert_eq!(tr.at(35.0), mbps(200.0));
        assert!(tr.at(45.0).is_infinite());
    }
}
