//! The process-wide **read reactor**: one thread sweeping every
//! registered conduit socket, replacing the per-conduit blocking reads
//! (and their 1–20 ms sleep/timeout loops) scattered across boundaries.
//!
//! Design:
//!
//! * Registration is per-socket. [`Reactor::register`] flips the socket
//!   to nonblocking **permanently** (O_NONBLOCK is shared by every
//!   duplicated handle of the socket, so there is no per-caller mode),
//!   keeps a `try_clone` for the reactor thread, and hands back a
//!   [`Registration`] whose inbox the reactor fills.
//! * The reactor thread (`qp-reactor`, spawned lazily on first
//!   registration) loops: snapshot the registration list, nonblocking
//!   read sweep over every live socket, append whatever arrived to the
//!   owning registration's inbox, and fire that registration's
//!   [`Notify`] so the boundary thread wakes. EOF or a hard read error
//!   marks the registration dead — the final bytes are still delivered.
//! * Writes stay on the boundary threads: measured write-stall time *is*
//!   the bandwidth signal the adaptive controller feeds on, so the
//!   reactor deliberately owns reads only.
//! * Idle behaviour: when a full sweep moves no bytes the reactor parks
//!   in a ~1 ms timed read on its **wake pipe** — a loopback TCP pair
//!   built without helper threads (connect completes against the
//!   listener backlog, then accept). Registering or dropping a
//!   registration writes one byte to the pipe so membership changes are
//!   seen promptly. No epoll/kqueue binding exists in `std`, so this
//!   millisecond-bounded poll is the portable stand-in; under load the
//!   sweep runs back-to-back and the timeout never enters the picture.
//! * An optional core-affinity pin ([`set_pin_core`], config knob
//!   `transport.reactor_pin_core`) applies best-effort CPU pinning to
//!   the reactor thread at spawn via `taskset`.
//!
//! Lock discipline (checked by the debug-build lockdep in
//! [`crate::util::sync`]): the registry lock (`reactor.registry`) is
//! released before any inbox lock (`reactor.inbox`) is taken, and no two
//! inboxes are ever held together.

use super::conduit::ReadSweep;
use crate::util::sync::{Notify, TrackedMutex};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Requested CPU core for the reactor thread; `-1` = no pinning.
static PIN_CORE: AtomicI64 = AtomicI64::new(-1);

/// Request that the reactor thread be pinned to `core`. Takes effect
/// only if called **before** the first registration spawns the thread
/// (wire it from config at process start); pinning is best-effort via
/// `taskset` and silently skipped where that isn't available.
pub fn set_pin_core(core: usize) {
    PIN_CORE.store(core as i64, Ordering::Relaxed);
}

/// Per-registration shared state: the reactor appends, the owner drains.
struct RegSlot {
    /// The reactor's duplicated handle of the registered socket.
    stream: TcpStream,
    /// Bytes swept off the socket, awaiting [`Registration::drain_into`].
    inbox: TrackedMutex<Vec<u8>>,
    /// Undrained inbox size — lock-free gauge for congestion weighting.
    queued: AtomicUsize,
    /// EOF or hard read error observed; final bytes still deliverable.
    dead: AtomicBool,
    /// Owner dropped the registration; reactor prunes it next sweep.
    removed: AtomicBool,
    /// Fired whenever bytes land in (or death is recorded on) this slot.
    notify: Arc<Notify>,
}

/// Handle to one registered socket. Dropping it deregisters: the reactor
/// prunes the slot and closes its duplicated handle on the next sweep.
pub struct Registration {
    slot: Arc<RegSlot>,
    inner: Arc<Inner>,
}

impl Registration {
    /// Move everything the reactor has swept so far into `into`
    /// (appending). Returns [`ReadSweep::Dead`] once the socket has hit
    /// EOF or a hard read error — any bytes swept before death are still
    /// delivered by the same call, so no tail is lost.
    pub fn drain_into(&self, into: &mut Vec<u8>) -> ReadSweep {
        {
            let mut inbox = self.slot.inbox.guard();
            into.extend_from_slice(&inbox);
            inbox.clear();
        }
        self.slot.queued.store(0, Ordering::Relaxed);
        if self.slot.dead.load(Ordering::Relaxed) {
            ReadSweep::Dead
        } else {
            ReadSweep::Alive
        }
    }

    /// Bytes currently swept but not yet drained — the reactor-side
    /// queue depth, folded into stripe selection as a congestion signal.
    pub fn queued_bytes(&self) -> usize {
        self.slot.queued.load(Ordering::Relaxed)
    }

    /// Has the reactor observed EOF or a hard read error on this socket?
    pub fn is_dead(&self) -> bool {
        self.slot.dead.load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for Registration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registration")
            .field("queued", &self.queued_bytes())
            .field("dead", &self.is_dead())
            .finish()
    }
}

impl Drop for Registration {
    // A short or failed wake write is fine: a full pipe already
    // guarantees a pending wakeup, so the byte count is meaningless.
    #[allow(clippy::unused_io_amount)]
    fn drop(&mut self) {
        self.slot.removed.store(true, Ordering::Relaxed);
        // Wake the reactor so it prunes promptly (and closes its clone).
        let _ = (&self.inner.wake_tx).write(&[1u8]);
    }
}

/// State shared between registrants and the reactor thread.
struct Inner {
    /// Every live registration. Snapshot-and-release: the reactor clones
    /// this list out before touching any inbox.
    registry: TrackedMutex<Vec<Arc<RegSlot>>>,
    /// Write end of the wake pipe (nonblocking; a full pipe already
    /// guarantees a pending wakeup, so failed writes are ignored).
    wake_tx: TcpStream,
    /// Cumulative bytes ever swept — observability for tests/metrics.
    swept: AtomicU64,
}

/// The process-wide read reactor. Obtain via [`global`]; there is one
/// per process, and its thread lives for the process lifetime.
pub struct Reactor {
    inner: Arc<Inner>,
}

impl Reactor {
    /// Register `stream` for reactor-driven reads. The socket is set
    /// nonblocking permanently (writes through other handles must
    /// tolerate `WouldBlock`; the conduit write helpers do). Bytes the
    /// reactor sweeps land in the returned [`Registration`]'s inbox, and
    /// each sweep that moves bytes (or records death) fires `notify`.
    // A short or failed wake write is fine: a full pipe already
    // guarantees a pending wakeup, so the byte count is meaningless.
    #[allow(clippy::unused_io_amount)]
    pub fn register(&self, stream: &TcpStream, notify: Arc<Notify>) -> io::Result<Registration> {
        stream.set_nonblocking(true)?;
        let clone = stream.try_clone()?;
        let slot = Arc::new(RegSlot {
            stream: clone,
            inbox: TrackedMutex::new("reactor.inbox", Vec::new()),
            queued: AtomicUsize::new(0),
            dead: AtomicBool::new(false),
            removed: AtomicBool::new(false),
            notify,
        });
        self.inner.registry.guard().push(slot.clone());
        let _ = (&self.inner.wake_tx).write(&[1u8]);
        Ok(Registration { slot, inner: self.inner.clone() })
    }

    /// Cumulative bytes swept off all registered sockets since the
    /// reactor started. Monotonic; never resets.
    pub fn bytes_swept(&self) -> u64 {
        self.inner.swept.load(Ordering::Relaxed)
    }
}

/// The process-wide reactor, spawning its thread on first use. Fails
/// only if the wake pipe cannot be built (loopback bind refused) or the
/// thread cannot spawn — and then fails the same way on every call.
pub fn global() -> io::Result<&'static Reactor> {
    static GLOBAL: OnceLock<Option<Reactor>> = OnceLock::new();
    match GLOBAL.get_or_init(|| build().ok()) {
        Some(r) => Ok(r),
        None => Err(io::Error::other("reactor unavailable: wake pipe or thread spawn failed")),
    }
}

/// Construct the reactor: wake pipe first (single-threaded loopback TCP
/// — connect completes against the listener backlog, then accept), then
/// the sweep thread.
fn build() -> io::Result<Reactor> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let wake_tx = TcpStream::connect(listener.local_addr()?)?;
    let (wake_rx, _) = listener.accept()?;
    drop(listener);
    wake_tx.set_nonblocking(true)?;
    wake_tx.set_nodelay(true)?;
    wake_rx.set_read_timeout(Some(Duration::from_millis(1)))?;
    let inner = Arc::new(Inner {
        registry: TrackedMutex::new("reactor.registry", Vec::new()),
        wake_tx,
        swept: AtomicU64::new(0),
    });
    let thread_inner = inner.clone();
    std::thread::Builder::new()
        .name("qp-reactor".into())
        .spawn(move || run_loop(thread_inner, wake_rx))?;
    Ok(Reactor { inner })
}

/// Best-effort CPU pin for the current thread: resolve our tid through
/// `/proc/thread-self` and shell out to `taskset`. Any failure (no
/// procfs, no taskset, cpuset restrictions) silently leaves the thread
/// unpinned — affinity is an optimisation, never a correctness need.
fn apply_pin() {
    let core = PIN_CORE.load(Ordering::Relaxed);
    if core < 0 {
        return;
    }
    let Ok(link) = std::fs::read_link("/proc/thread-self") else {
        return;
    };
    let Some(tid) = link.file_name().and_then(|s| s.to_str()) else {
        return;
    };
    let _ = std::process::Command::new("taskset")
        .args(["-cp", &core.to_string(), tid])
        .output();
}

/// Per-slot, per-sweep read budget: bounds how long one firehosing
/// socket can monopolise the sweep before its peers get a turn.
const SLOT_READ_CHUNKS: usize = 16;

/// The reactor thread body: sweep every live registration, then park on
/// the wake pipe when a whole sweep moves nothing.
// The idle read's byte count (and error) are meaningless: any outcome —
// wake bytes, timeout, interrupt — just restarts the sweep.
#[allow(clippy::unused_io_amount)]
fn run_loop(inner: Arc<Inner>, wake_rx: TcpStream) {
    apply_pin();
    let mut buf = [0u8; 4096];
    loop {
        // Snapshot the registration list and release the registry lock
        // before touching any inbox (lock-order discipline), pruning
        // dropped registrations on the way.
        let regs: Vec<Arc<RegSlot>> = {
            let mut g = inner.registry.guard();
            g.retain(|s| !s.removed.load(Ordering::Relaxed));
            g.clone()
        };
        let mut moved = 0usize;
        for slot in &regs {
            if slot.dead.load(Ordering::Relaxed) {
                continue;
            }
            for _ in 0..SLOT_READ_CHUNKS {
                match (&slot.stream).read(&mut buf) {
                    Ok(0) => {
                        slot.dead.store(true, Ordering::Relaxed);
                        slot.notify.notify();
                        break;
                    }
                    Ok(n) => {
                        {
                            let mut inbox = slot.inbox.guard();
                            inbox.extend_from_slice(&buf[..n]);
                            slot.queued.store(inbox.len(), Ordering::Relaxed);
                        }
                        inner.swept.fetch_add(n as u64, Ordering::Relaxed);
                        moved += n;
                        slot.notify.notify();
                        if n < buf.len() {
                            break; // short read: socket likely drained
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        slot.dead.store(true, Ordering::Relaxed);
                        slot.notify.notify();
                        break;
                    }
                }
            }
        }
        if moved == 0 {
            // Idle: park up to the wake pipe's ~1 ms read timeout. Any
            // outcome — wake byte, timeout, interrupt — just restarts
            // the sweep; the byte itself carries no information.
            let mut wb = [0u8; 64];
            let _ = (&wake_rx).read(&mut wb);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn loopback() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let a = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn reactor_sweeps_bytes_into_the_inbox_and_notifies() {
        let (a, b) = loopback();
        let notify = Arc::new(Notify::new());
        let r = global().unwrap();
        let reg = r.register(&b, notify.clone()).unwrap();
        let swept_before = r.bytes_swept();
        (&a).write_all(b"hello reactor").unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.len() < 13 && Instant::now() < deadline {
            let seen = notify.epoch();
            reg.drain_into(&mut got);
            if got.len() < 13 {
                notify.wait_past(seen, Duration::from_millis(50));
            }
        }
        assert_eq!(got, b"hello reactor");
        assert!(r.bytes_swept() >= swept_before + 13, "sweep counter must advance");
        assert_eq!(reg.queued_bytes(), 0, "drained inbox reads as empty queue");
    }

    #[test]
    fn reactor_reports_death_after_final_bytes() {
        let (a, b) = loopback();
        let notify = Arc::new(Notify::new());
        let reg = global().unwrap().register(&b, notify.clone()).unwrap();
        (&a).write_all(b"tail").unwrap();
        drop(a); // EOF after the final bytes
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let seen = notify.epoch();
            if matches!(reg.drain_into(&mut got), ReadSweep::Dead) {
                break;
            }
            assert!(Instant::now() < deadline, "death must be observed promptly");
            notify.wait_past(seen, Duration::from_millis(50));
        }
        assert_eq!(got, b"tail", "bytes written before EOF must still arrive");
    }

    #[test]
    fn dropping_a_registration_prunes_it() {
        let (_a, b) = loopback();
        let notify = Arc::new(Notify::new());
        let r = global().unwrap();
        let reg = r.register(&b, notify).unwrap();
        let slot = reg.slot.clone();
        drop(reg);
        let deadline = Instant::now() + Duration::from_secs(5);
        // The reactor drops its Arc on the next sweep; only our local
        // clone remains.
        while Arc::strong_count(&slot) > 1 {
            assert!(Instant::now() < deadline, "reactor must prune dropped registrations");
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}
