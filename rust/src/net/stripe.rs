//! Connection **striping**: one reliability session fanned out over N
//! conduits per stage boundary.
//!
//! QuantPipe's premise is that the edge link — not compute — bounds
//! pipeline throughput, so the transport must extract every bit the link
//! offers. On high-BDP or multi-path edge links a single TCP connection
//! leaves bandwidth on the table: one congestion window, one head-of-line
//! queue. [`StripedTx`]/[`StripedRx`] stripe a boundary across N
//! connections while keeping the session semantics of the resilient
//! layer — the [`super::session`] sequence space is *shared*, so the
//! receiver reorders across conduits, replay/ACK resync works no matter
//! which conduit died, and the FIN/FIN_ACK drain completes even when
//! stripes finish out of order.
//!
//! Scheduling: the sender round-robins frames over connected conduits
//! with a least-stalled bias (a conduit whose recent writes stalled well
//! above its siblings is skipped until it recovers). All stall time —
//! ordinary write backpressure, opportunistic revival dials, full-outage
//! reconnects — returns from `send` as busy time, so the `WindowMonitor`
//! measures the *aggregate* bandwidth of the boundary and the
//! `AdaptivePda` sees a lost stripe as partial bandwidth collapse:
//!
//! * while the session has replay slack, frames keep flowing over the
//!   surviving stripes and only the (bounded) revival attempts stall;
//! * once the dead stripe's unacked tail jams the cumulative ACK stream,
//!   the replay buffer fills and `send` blocks — the same collapsed-
//!   bandwidth signal a single-link outage produces — until a revived
//!   conduit's `HELLO` handshake replays the gap.
//!
//! The single-connection resilient link ([`super::resilient`]) is exactly
//! this machinery with N = 1 and a strict (reorder-free) receiver.

use super::conduit::{
    accept_pending, read_available, read_ctrl_timeout, write_ctrl, write_frame_bytes, write_raw,
    write_telemetry, AcceptedConduit, DialConduit, LinkKillSwitch, ReadSweep,
};
use super::frame::Frame;
use super::session::{
    ctrl_record, parse_ctrl, ResilienceConfig, RxStep, SessionRx, SessionTx, WireDecoder,
    WireItem, CTRL_MARKER, K_ACK, K_FIN, K_FIN_ACK, K_HAVE, K_HELLO, MAX_TELEMETRY_BYTES,
};
use super::shaper::{corrupt_into, LinkShaper, Verdict};
use super::tcp::Backoff;
use super::transport::{FrameRx, FrameTx, PreparedFrame};
use crate::metrics::{ResilienceStats, StripeStats};
use crate::util::sync::Notify;
use crate::Result;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Drain inbound acks at most every this many sends (sooner when the
/// replay buffer passes half capacity) — the drain costs syscalls and the
/// ACK scheme is cumulative, so per-send pumping buys nothing.
const PUMP_EVERY: u32 = 16;

/// Budget for one opportunistic revival dial while other stripes carry
/// the boundary: long enough for a LAN SYN/ACK, short enough that a dead
/// stripe costs bounded stall per attempt (the backoff schedule spaces
/// the attempts out).
const REVIVAL_DIAL_BUDGET: Duration = Duration::from_millis(250);

// ---------------------------------------------------------------------------
// Sender: StripedTx
// ---------------------------------------------------------------------------

/// Striped sender half: one [`SessionTx`] fanned over N dialing conduits.
pub struct StripedTx {
    peer: String,
    cfg: ResilienceConfig,
    stats: Arc<ResilienceStats>,
    stripe_stats: Vec<Arc<StripeStats>>,
    session: SessionTx,
    conduits: Vec<DialConduit>,
    /// Round-robin cursor over connected conduits.
    rr: usize,
    /// Session-level: the first establish uses the generous startup
    /// budget (order-independent launch), later ones are outages.
    ever_connected: bool,
    /// A conduit died while frames were unacked — some of them may have
    /// died in its kernel buffers, so the next handshake must replay the
    /// tail. Cleared once a handshake has replayed. Keeps clean startups
    /// replay-free: bringing up extra stripes must not echo frames the
    /// first stripe already carried (the dedup counter means "a replay
    /// event happened", and a clean run must report zero).
    dirty: bool,
    finished: bool,
    sends_since_pump: u32,
    /// Read-sweep scratch shared across pumps.
    scratch: Vec<u8>,
    /// Serialization scratch for outbound telemetry records.
    tele_scratch: Vec<u8>,
    /// Chaos-lab shaper per stripe (`None` = unshaped; the default). A
    /// `None` slot adds exactly one `if let` to the write path — no
    /// shaper code runs at all, asserted by the `hot_touches` regression
    /// test in `tests/chaos_soak.rs`.
    shapers: Vec<Option<Arc<LinkShaper>>>,
    /// Wire-copy scratch for shaper-corrupted writes (the replay buffer
    /// keeps the pristine bytes).
    shape_scratch: Vec<u8>,
    /// Fired by the reactor whenever inbound bytes (acks) land on any of
    /// this boundary's conduits — the backpressure waits park on it
    /// instead of sleeping blind.
    notify: Arc<Notify>,
}

impl StripedTx {
    /// Lazily-connecting striped sender toward `peer`: all `stripes`
    /// conduits dial the same address (the receiver multiplexes its one
    /// listener), so no per-stripe port plumbing is needed.
    pub fn connect_to(
        peer: impl Into<String>,
        stripes: usize,
        cfg: ResilienceConfig,
        stats: Arc<ResilienceStats>,
    ) -> Self {
        let stripes = stripes.max(1);
        StripedTx {
            peer: peer.into(),
            session: SessionTx::new(cfg.replay_capacity),
            cfg,
            stats,
            stripe_stats: (0..stripes).map(|_| Arc::new(StripeStats::default())).collect(),
            conduits: (0..stripes).map(|_| DialConduit::new()).collect(),
            rr: 0,
            ever_connected: false,
            dirty: false,
            finished: false,
            sends_since_pump: 0,
            scratch: Vec::new(),
            tele_scratch: Vec::new(),
            shapers: (0..stripes).map(|_| None).collect(),
            shape_scratch: Vec::new(),
            notify: Arc::new(Notify::new()),
        }
    }

    /// Attach (or clear) the chaos-lab shaper for stripe `i`. Shaping is
    /// sender-side only: the sleep a shaped write incurs is real write
    /// stall, which is exactly the bandwidth signal the adaptive
    /// controller measures.
    pub fn set_shaper(&mut self, i: usize, shaper: Option<Arc<LinkShaper>>) {
        self.shapers[i] = shaper;
    }

    /// Attach one shaper slot per stripe (see
    /// [`super::scenario::ScenarioKind::build`]); missing trailing slots
    /// stay unshaped.
    pub fn set_shapers(&mut self, shapers: Vec<Option<Arc<LinkShaper>>>) {
        for (i, s) in shapers.into_iter().enumerate().take(self.shapers.len()) {
            self.shapers[i] = s;
        }
    }

    /// Shared resilience counters (one block per boundary).
    pub fn stats(&self) -> Arc<ResilienceStats> {
        self.stats.clone()
    }

    /// Live per-stripe counters (one per conduit, stable order).
    pub fn stripe_stats(&self) -> Vec<Arc<StripeStats>> {
        self.stripe_stats.clone()
    }

    /// Number of conduits this boundary fans over.
    pub fn stripes(&self) -> usize {
        self.conduits.len()
    }

    /// Handle that can kill stripe `i`'s active socket (fault injection).
    pub fn kill_switch_for(&self, i: usize) -> LinkKillSwitch {
        self.conduits[i].kill.clone()
    }

    /// Frames recorded but not yet acknowledged by the peer.
    pub fn unacked(&self) -> usize {
        self.session.unacked()
    }

    /// Drain any acks the peer has pushed without blocking. `send` does
    /// this itself on a schedule (every [`PUMP_EVERY`] sends, or sooner
    /// when the replay buffer passes half capacity).
    pub fn pump(&mut self) {
        self.pump_all();
    }

    /// Ship one frame over the least-stalled connected stripe. Blocks
    /// through replay-buffer backpressure and any reconnect + replay
    /// cycle; returns the seconds spent, which is the busy time the
    /// `WindowMonitor` turns into measured bandwidth — a full outage *is*
    /// the bandwidth signal, and a single lost stripe shows up as the
    /// partial collapse its revival stalls add up to.
    pub fn send(&mut self, frame: Frame) -> Result<f64> {
        anyhow::ensure!(!self.finished, "send on a finished striped link");
        let seq = frame.seq;
        // Serialize into a buffer recycled from previously acked frames —
        // the replay buffer owns each frame's bytes until the cumulative
        // ack releases them, so steady state allocates nothing per frame.
        let mut bytes = self.session.take_buf();
        frame.write_into(&mut bytes);
        self.send_bytes(seq, bytes)
    }

    /// The send core behind both [`StripedTx::send`] and the copy-free
    /// [`super::transport::FrameTx::send_prepared`] path: takes the
    /// frame's already-serialized wire bytes, which the replay buffer
    /// then owns until the cumulative ack releases them. The socket
    /// write borrows the bytes out of the replay buffer, so no payload
    /// copy happens past this point.
    fn send_bytes(&mut self, seq: u64, bytes: Vec<u8>) -> Result<f64> {
        anyhow::ensure!(!self.finished, "send on a finished striped link");
        let t0 = Instant::now();
        self.sends_since_pump += 1;
        if self.sends_since_pump >= PUMP_EVERY
            || self.session.unacked() + 1 >= self.session.capacity() / 2
        {
            self.pump_all();
            self.sends_since_pump = 0;
        }
        self.wait_for_room()?;
        self.session.record_send(seq, bytes)?;
        loop {
            if !self.any_connected() {
                let deadline = Instant::now() + self.connect_budget();
                if self.establish_by(deadline)? {
                    // The handshake replayed the unacked tail — including
                    // the frame just recorded — nothing left to write.
                    break;
                }
                // Clean session on a fresh conduit (no replay owed):
                // fall through and write the frame directly.
                continue;
            }
            self.revive_due();
            let Some(i) = self.pick_conduit() else {
                // Every conduit died between the any_connected() check and
                // the pick: loop back into the full-outage path.
                continue;
            };
            let wt0 = Instant::now();
            let Some(wire) = self.session.latest().map(<[u8]>::len) else {
                // record_send succeeded above, so the only way the frame is
                // gone is a cumulative ack that already covers it (a pump
                // raced ahead) — nothing left to write.
                break;
            };
            // Chaos-lab shaping, sender-side only (see `super::shaper`):
            // the sleep below is real write stall — it lands in this
            // send's busy time and in the stripe's stall EWMA, so the
            // adaptive controller and the least-stalled picker both see
            // the impairment without ever being told about it.
            let mut corrupt_at = None;
            if let Some(shaper) = self.shapers[i].clone() {
                match shaper.decide(wire) {
                    Verdict::Lose => {
                        // The link ate the frame: kill the conduit instead
                        // of writing, and let reconnect + replay recover.
                        self.down(i);
                        continue;
                    }
                    Verdict::Ship { delay, corrupt_at: at } => {
                        if delay > Duration::ZERO {
                            std::thread::sleep(delay);
                        }
                        corrupt_at = at;
                    }
                }
            }
            let Some(bytes) = self.session.latest() else {
                break;
            };
            if let Some(at) = corrupt_at {
                // Corrupt a throwaway copy; the pristine frame stays in
                // the replay buffer for the post-desync replay.
                corrupt_into(bytes, at, &mut self.shape_scratch);
            }
            let ok = match self.conduits[i].conn.as_mut() {
                Some(stream) => {
                    let out = if corrupt_at.is_some() { &self.shape_scratch } else { bytes };
                    write_frame_bytes(stream, out).is_ok()
                }
                None => false, // raced with a concurrent death sweep
            };
            if ok {
                self.conduits[i].note_stall(wt0.elapsed());
                let s = &self.stripe_stats[i];
                s.frames.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                s.bytes.fetch_add(wire as u64, std::sync::atomic::Ordering::Relaxed);
                break;
            }
            self.down(i); // loop → reroute / reconnect
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Ship one telemetry record on **every** connected conduit,
    /// interleaved with the data frames. Broadcast, not round-robin: the
    /// receiver holds its FIN_ACK only for missing *frames*, so the one
    /// stream whose FIN triggers the drain must itself carry the final
    /// snapshot ahead of that FIN — per-conduit byte order then
    /// guarantees the record is decoded first, whichever stripe wins.
    /// Duplicates are cheap (relay hops and the report merge dedup by
    /// snapshot identity); a record on a dying conduit is simply lost
    /// (best effort, never a send failure); with no conduit connected the
    /// record is dropped outright rather than stalling the data plane.
    pub fn send_telemetry(&mut self, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(
            payload.len() <= MAX_TELEMETRY_BYTES,
            "telemetry payload of {} bytes exceeds {MAX_TELEMETRY_BYTES}",
            payload.len()
        );
        if self.finished {
            return Ok(());
        }
        let mut scratch = std::mem::take(&mut self.tele_scratch);
        for i in 0..self.conduits.len() {
            let ok = match self.conduits[i].conn.as_mut() {
                Some(stream) => write_telemetry(stream, payload, &mut scratch).is_ok(),
                None => continue, // down conduit: best effort, skip
            };
            if !ok {
                self.down(i);
            }
        }
        self.tele_scratch = scratch;
        Ok(())
    }

    /// Drain protocol: make sure every frame is delivered, send
    /// `FIN{next_seq}` (on every connected stripe — any of them may carry
    /// the FIN_ACK back) and wait for the confirmation. The receiver
    /// holds its FIN_ACK until the frames still in flight on *other*
    /// stripes have arrived, so an out-of-order stripe finish drains
    /// cleanly.
    pub fn finish(&mut self) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        let deadline = Instant::now() + self.cfg.drain_timeout;
        self.session.clear_fin_ack();
        loop {
            anyhow::ensure!(
                Instant::now() < deadline,
                "drain of link to {} timed out after {:?} ({} frames unacked)",
                self.peer,
                self.cfg.drain_timeout,
                self.session.unacked()
            );
            if !self.any_connected() {
                self.establish_by(deadline)?;
            }
            let fin = self.session.fin_record();
            for i in 0..self.conduits.len() {
                let ok = match self.conduits[i].conn.as_mut() {
                    Some(stream) => write_raw(stream, &fin).is_ok(),
                    None => continue, // down conduit: another stripe FINs
                };
                if !ok {
                    self.down(i);
                }
            }
            // Wait one bounded slice for FIN_ACK; a stripe that died
            // holding undelivered frames is revived (its handshake
            // replays the tail), then the outer loop re-FINs — FIN is
            // idempotent on the receiver.
            let slice_end = Instant::now() + Duration::from_millis(50);
            while !self.session.fin_acked()
                && self.any_connected()
                && Instant::now() < slice_end.min(deadline)
            {
                let seen = self.notify.epoch();
                self.pump_all();
                if self.session.fin_acked() {
                    break;
                }
                self.notify.wait_past(seen, Duration::from_millis(2));
            }
            self.revive_due();
            if self.session.fin_acked() {
                self.finished = true;
                for c in &mut self.conduits {
                    c.mark_down(self.cfg.backoff_base);
                }
                return Ok(());
            }
        }
    }

    fn any_connected(&self) -> bool {
        self.conduits.iter().any(|c| c.is_connected())
    }

    /// Take conduit `i` down. If frames were unacked at death, some of
    /// them may have been lost in its buffers — mark the session dirty so
    /// the next handshake replays the tail.
    fn down(&mut self, i: usize) {
        if self.session.unacked() > 0 {
            self.dirty = true;
        }
        self.conduits[i].mark_down(self.cfg.backoff_base);
    }

    /// Budget for (re)establishing from a full outage: the first
    /// connection of a session is startup (order-independent, generous);
    /// later ones are outages.
    fn connect_budget(&self) -> Duration {
        if self.ever_connected {
            self.cfg.reconnect_timeout
        } else {
            self.cfg.initial_timeout.max(self.cfg.reconnect_timeout)
        }
    }

    /// Congestion cost of conduit `i`: the write-stall EWMA plus a
    /// penalty for inbound bytes the reactor has swept off this conduit
    /// that the boundary hasn't drained yet. A backlogged inbox means
    /// the conduit's ack stream is running behind its siblings' — the
    /// reactor's registration state is the live congestion signal the
    /// old blocking sweeps never had. The pump drains all inboxes every
    /// cycle, so with idle queues this reduces exactly to the
    /// least-stalled EWMA bias. Scale: ~1 µs of penalty per 16 queued
    /// bytes, putting a few KB of backlog on par with a sub-millisecond
    /// stall.
    fn conduit_cost(&self, i: usize) -> f64 {
        let queued = self.conduits[i].reg.as_ref().map_or(0, |r| r.queued_bytes());
        self.conduits[i].stall_ewma_us + queued as f64 / 16.0
    }

    /// Round-robin over connected conduits, skipping any whose
    /// congestion cost (recent write stall + undrained reactor inbox)
    /// sits well above the best sibling's (an absolute 1 ms slack keeps
    /// noise from defeating the rotation).
    fn pick_conduit(&mut self) -> Option<usize> {
        let connected: Vec<usize> = (0..self.conduits.len())
            .filter(|&i| self.conduits[i].is_connected())
            .collect();
        if connected.is_empty() {
            return None;
        }
        let min_cost = connected
            .iter()
            .map(|&i| self.conduit_cost(i))
            .fold(f64::INFINITY, f64::min);
        self.rr = self.rr.wrapping_add(1);
        let start = self.rr % connected.len();
        for k in 0..connected.len() {
            let i = connected[(start + k) % connected.len()];
            if self.conduit_cost(i) <= min_cost * 2.0 + 1e3 {
                return Some(i);
            }
        }
        Some(connected[start])
    }

    /// Drain whatever control bytes the reactor has swept off every
    /// connected conduit, applying acks to the shared session. One
    /// [`WireDecoder`] per conduit parses both directions' wire format;
    /// a data frame arriving at the *sender* is a desynced peer, cured
    /// by reconnect.
    fn pump_all(&mut self) {
        for i in 0..self.conduits.len() {
            self.scratch.clear();
            let sweep = {
                let c = &self.conduits[i];
                match c.reg.as_ref() {
                    Some(reg) => reg.drain_into(&mut self.scratch),
                    None => continue, // down conduit: nothing to pump
                }
            };
            if !self.scratch.is_empty() {
                self.conduits[i].decoder.extend(&self.scratch);
            }
            // Parse even when the connection died: an ack that arrived
            // just before the EOF still trims the replay buffer.
            let mut desynced = false;
            loop {
                match self.conduits[i].decoder.next() {
                    Ok(Some(WireItem::Ctrl(kind, seq))) => self.session.apply_ctrl(kind, seq),
                    // Telemetry flows forward only; a record arriving at
                    // the sender is a confused peer, but a harmless one —
                    // skip it (forward compatibility) instead of
                    // resyncing.
                    Ok(Some(WireItem::Telemetry(_))) => {}
                    Ok(None) => break,
                    Ok(Some(WireItem::Frame(_))) | Err(_) => {
                        desynced = true;
                        break;
                    }
                }
            }
            if matches!(sweep, ReadSweep::Dead) || desynced {
                self.down(i);
            }
        }
    }

    /// Block until the replay buffer has room. A full buffer on a healthy
    /// boundary is ordinary backpressure — exactly like a full kernel
    /// send buffer blocking `write` in plain-TCP mode — so it is never an
    /// error and never times out. Two failure shapes are bounded: a full
    /// outage (no conduit connected) gets the reconnect budget per
    /// re-establish, and a dead stripe whose unacked tail has jammed the
    /// cumulative ACK stream for the whole reconnect budget is a hard
    /// error (its frames are the blocker and it isn't coming back).
    fn wait_for_room(&mut self) -> Result<()> {
        if self.session.has_room() {
            return Ok(());
        }
        let mut last_acked = self.session.acked();
        let mut stalled_since = Instant::now();
        loop {
            let seen = self.notify.epoch();
            self.pump_all();
            if self.session.has_room() {
                return Ok(());
            }
            if self.session.acked() != last_acked {
                last_acked = self.session.acked();
                stalled_since = Instant::now();
            }
            if !self.any_connected() {
                // The handshake's HELLO doubles as a cumulative ack.
                let deadline = Instant::now() + self.cfg.reconnect_timeout;
                self.establish_by(deadline)?;
                continue;
            }
            self.revive_due();
            if stalled_since.elapsed() > self.cfg.reconnect_timeout {
                if let Some(i) = (0..self.conduits.len())
                    .find(|&i| !self.conduits[i].is_connected())
                {
                    let down_for = self.conduits[i]
                        .down_since
                        .map(|t| t.elapsed())
                        .unwrap_or_default();
                    anyhow::bail!(
                        "link to {} down: stripe {i} unreachable for {down_for:?} with the \
                         replay buffer full and no ack progress for {:?} ({} frames unacked)",
                        self.peer,
                        self.cfg.reconnect_timeout,
                        self.session.unacked()
                    );
                }
            }
            // Park until the reactor sweeps more ack bytes in (bounded,
            // so revival schedules and the stall clock keep ticking).
            self.notify.wait_past(seen, Duration::from_millis(2));
        }
    }

    /// Full-outage re-establish: dial one conduit blocking (backoff +
    /// jitter, bounded by `deadline`), handshake, replay what must be
    /// replayed. Returns whether that handshake replayed the unacked tail
    /// (the caller's pending frame is then already on the wire). On the
    /// very first establish of the session the remaining stripes are
    /// brought up too (the peer is reachable, so quick dials land
    /// immediately); any that fail go on the revival schedule.
    fn establish_by(&mut self, deadline: Instant) -> Result<bool> {
        let first_session = !self.ever_connected;
        let target = (0..self.conduits.len())
            .find(|&i| !self.conduits[i].is_connected())
            .unwrap_or(0);
        let t0 = Instant::now();
        let mut backoff = Backoff::new(
            self.cfg.backoff_base,
            self.cfg.backoff_max,
            self.cfg.jitter,
            self.cfg.seed ^ self.conduits[target].dials ^ self.conduits[target].nonce,
        );
        let covered = loop {
            let peer = self.peer.clone();
            let stream = self.conduits[target]
                .dial_blocking(&peer, deadline, &mut backoff)
                .map_err(|e| {
                    anyhow::anyhow!(
                        "link to {} down: {e} ({} frames awaiting replay)",
                        self.peer,
                        self.session.unacked()
                    )
                })?;
            let was = self.conduits[target].ever_connected;
            match self.handshake(target, stream, deadline) {
                Ok(replayed) => {
                    if was {
                        self.note_reconnect(target, t0.elapsed());
                    }
                    self.ever_connected = true;
                    break replayed;
                }
                Err(e) => {
                    // Handshake failures are transient (half-dead peer,
                    // stale backlog entry) — retry until the deadline,
                    // then surface the real reason.
                    if Instant::now() >= deadline {
                        return Err(e.context(format!(
                            "link to {} down: handshake kept failing",
                            self.peer
                        )));
                    }
                    std::thread::sleep(backoff.next_delay());
                }
            }
        };
        if first_session {
            for i in 0..self.conduits.len() {
                if self.conduits[i].is_connected() {
                    continue;
                }
                self.try_revive(i);
            }
        }
        Ok(covered)
    }

    /// Attempt one bounded revival dial for every down conduit whose
    /// backoff schedule says it's due. Never blocks beyond the quick-dial
    /// budget — the surviving stripes keep the boundary moving, and the
    /// attempt's cost returns from `send` as the partial-collapse stall.
    fn revive_due(&mut self) {
        for i in 0..self.conduits.len() {
            if self.conduits[i].revival_due() {
                self.try_revive(i);
            }
        }
    }

    fn try_revive(&mut self, i: usize) {
        let t0 = Instant::now();
        let peer = self.peer.clone();
        let was = self.conduits[i].ever_connected;
        let budget = REVIVAL_DIAL_BUDGET
            .min(self.cfg.backoff_max)
            .max(Duration::from_millis(10));
        let dialed = self.conduits[i].dial_quick(&peer, budget);
        let result = match dialed {
            Ok(stream) => self.handshake(i, stream, Instant::now() + self.cfg.hello_timeout),
            Err(e) => Err(e.into()),
        };
        match result {
            Ok(_) => {
                if was {
                    self.note_reconnect(i, t0.elapsed());
                }
                self.ever_connected = true;
            }
            Err(_) => {
                self.conduits[i].retry_failed(self.cfg.backoff_max);
                if was {
                    // The failed attempt is real stall the controller
                    // should see as (partially) collapsed bandwidth.
                    let us = t0.elapsed().as_micros() as u64;
                    self.stats.stall_us.fetch_add(us, std::sync::atomic::Ordering::Relaxed);
                    self.stripe_stats[i]
                        .stall_us
                        .fetch_add(us, std::sync::atomic::Ordering::Relaxed);
                }
            }
        }
    }

    fn note_reconnect(&self, i: usize, stall: Duration) {
        use std::sync::atomic::Ordering::Relaxed;
        let us = stall.as_micros() as u64;
        self.stats.reconnects.fetch_add(1, Relaxed);
        self.stats.stall_us.fetch_add(us, Relaxed);
        self.stripe_stats[i].reconnects.fetch_add(1, Relaxed);
        self.stripe_stats[i].stall_us.fetch_add(us, Relaxed);
    }

    /// On a fresh connection: read the receiver's `HELLO`, resync the
    /// shared session to its cumulative position, and — when the session
    /// may have lost frames (`dirty`, or this conduit itself reconnected)
    /// — replay the unacked tail on this conduit (the receiver dedups
    /// whatever other stripes already delivered). A clean session on a
    /// fresh conduit replays nothing: bringing up extra stripes at
    /// startup must not echo frames the first stripe carried. Returns
    /// whether the tail was replayed.
    fn handshake(&mut self, i: usize, mut stream: TcpStream, deadline: Instant) -> Result<bool> {
        stream.set_nodelay(true).ok();
        let budget = self
            .cfg
            .hello_timeout
            .min(deadline.saturating_duration_since(Instant::now()))
            .max(Duration::from_millis(1));
        let rec = read_ctrl_timeout(&mut stream, budget)?;
        anyhow::ensure!(
            // lint: allow(unwrap): rec is a fixed CTRL_LEN array, so the
            // 4-byte slice conversion is infallible.
            u32::from_le_bytes(rec[0..4].try_into().unwrap()) == CTRL_MARKER,
            "peer is not speaking the resilient protocol (bad HELLO marker)"
        );
        let (kind, next_expected) = parse_ctrl(&rec);
        anyhow::ensure!(kind == K_HELLO, "expected HELLO, got control kind {kind}");
        self.session.on_hello(next_expected)?;
        // Selective acks: the receiver batches a HAVE record for every
        // seq parked in its reorder window right behind the HELLO, in
        // the same write. Sweep whatever of that has arrived (best
        // effort — the stream is not yet reactor-registered, so this is
        // a direct nonblocking read) and apply it before replaying; any
        // HAVE that hasn't landed yet simply costs a replayed frame the
        // receiver dedups. The decoder is kept and moved into the
        // conduit below so a partial trailing record is never lost.
        let mut decoder = WireDecoder::new();
        self.scratch.clear();
        if matches!(read_available(&mut stream, &mut self.scratch), ReadSweep::Dead) {
            anyhow::bail!("peer vanished right after its HELLO");
        }
        decoder.extend(&self.scratch);
        loop {
            match decoder.next() {
                Ok(Some(WireItem::Ctrl(kind, seq))) => self.session.apply_ctrl(kind, seq),
                Ok(Some(WireItem::Telemetry(_))) => {}
                Ok(None) => break,
                Ok(Some(WireItem::Frame(_))) | Err(_) => {
                    anyhow::bail!("peer desynced during the handshake")
                }
            }
        }
        let replay_owed = self.dirty || self.conduits[i].ever_connected;
        let mut replayed = 0u64;
        let mut replayed_bytes = 0u64;
        if replay_owed {
            for bytes in self.session.replay_tail() {
                write_frame_bytes(&mut stream, bytes)
                    .map_err(|e| anyhow::anyhow!("replay write failed: {e}"))?;
                replayed += 1;
                replayed_bytes += bytes.len() as u64;
            }
        }
        if self.conduits[i].ever_connected && replayed > 0 {
            self.stats
                .replayed
                .fetch_add(replayed, std::sync::atomic::Ordering::Relaxed);
        }
        if replayed > 0 {
            // Replays are wire traffic this stripe carried.
            use std::sync::atomic::Ordering::Relaxed;
            self.stripe_stats[i].frames.fetch_add(replayed, Relaxed);
            self.stripe_stats[i].bytes.fetch_add(replayed_bytes, Relaxed);
        }
        // Hand the fresh connection to the reactor. The handshake sweep's
        // decoder moves into the conduit so partial bytes carry over.
        self.conduits[i].decoder = decoder;
        self.conduits[i]
            .install(stream, &self.notify)
            .map_err(|e| anyhow::anyhow!("reactor registration failed: {e}"))?;
        if replay_owed {
            // Everything unacked is back on the wire via this conduit;
            // nothing is lost anymore until the next death-with-unacked.
            self.dirty = false;
        }
        Ok(replay_owed)
    }
}

impl FrameTx for StripedTx {
    fn send(&mut self, frame: Frame) -> Result<f64> {
        StripedTx::send(self, frame)
    }

    fn send_prepared(&mut self, prepared: PreparedFrame) -> Result<f64> {
        // Zero-copy: the codec thread's serialization buffer moves into
        // the replay buffer and the socket write borrows it from there.
        self.send_bytes(prepared.seq, prepared.wire)
    }

    fn reclaim_wire(&mut self) -> Option<Vec<u8>> {
        self.session.take_spare()
    }

    fn kind(&self) -> &'static str {
        "tcp+striped"
    }

    fn finish(&mut self) -> Result<()> {
        StripedTx::finish(self)
    }

    fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        Some(self.stats.clone())
    }

    fn stripes(&self) -> Option<Vec<Arc<StripeStats>>> {
        Some(self.stripe_stats.clone())
    }

    fn send_telemetry(&mut self, payload: &[u8]) -> Result<()> {
        StripedTx::send_telemetry(self, payload)
    }
}

// ---------------------------------------------------------------------------
// Receiver: StripedRx
// ---------------------------------------------------------------------------

/// Striped receiver half: one [`SessionRx`] fed by every conduit the kept
/// listener accepts. Conduits are polled (a blocking read on one would
/// starve the others); frames reorder through the session's shared
/// sequence space, so in-order delivery holds no matter how the stripes
/// interleave.
pub struct StripedRx {
    listener: Arc<TcpListener>,
    cfg: ResilienceConfig,
    stats: Arc<ResilienceStats>,
    session: SessionRx,
    conduits: Vec<AcceptedConduit>,
    /// Conduit deaths not yet replaced by an accept — the next accepts
    /// count as re-accepts (a clean striped startup accepts N conduits
    /// without a single death, so none of those count).
    deaths: u64,
    ever_connected: bool,
    done: bool,
    scratch: Vec<u8>,
    /// Telemetry payloads decoded off the data stream, awaiting
    /// [`StripedRx::poll_telemetry`] (arrival order).
    tele_inbox: Vec<Vec<u8>>,
    /// Fired by the reactor whenever inbound bytes land on any of this
    /// boundary's conduits — idle `recv` parks on it instead of a
    /// per-conduit blocking read or a poll sleep.
    notify: Arc<Notify>,
}

impl StripedRx {
    /// Striped receiver on `listener`: accepts however many stripes dial
    /// in and reorders across them (window bounded by `replay_capacity`).
    pub fn accept_on(
        listener: Arc<TcpListener>,
        cfg: ResilienceConfig,
        stats: Arc<ResilienceStats>,
    ) -> Self {
        let reorder = cfg.replay_capacity.max(1);
        Self::with_reorder_window(listener, cfg, stats, reorder)
    }

    /// Strict single-conduit receiver (the classic resilient link): any
    /// sequence gap is a protocol error, never parked.
    pub fn accept_on_ordered(
        listener: Arc<TcpListener>,
        cfg: ResilienceConfig,
        stats: Arc<ResilienceStats>,
    ) -> Self {
        Self::with_reorder_window(listener, cfg, stats, 0)
    }

    fn with_reorder_window(
        listener: Arc<TcpListener>,
        cfg: ResilienceConfig,
        stats: Arc<ResilienceStats>,
        reorder: usize,
    ) -> Self {
        StripedRx {
            listener,
            session: SessionRx::new(cfg.replay_capacity, reorder),
            cfg,
            stats,
            conduits: Vec::new(),
            deaths: 0,
            ever_connected: false,
            done: false,
            scratch: Vec::new(),
            tele_inbox: Vec::new(),
            notify: Arc::new(Notify::new()),
        }
    }

    /// Shared resilience counters (one block per boundary).
    pub fn stats(&self) -> Arc<ResilienceStats> {
        self.stats.clone()
    }

    /// Take the telemetry payloads that arrived interleaved with the data
    /// stream since the last poll (see
    /// [`crate::net::transport::FrameRx::poll_telemetry`]).
    pub fn poll_telemetry(&mut self) -> Vec<Vec<u8>> {
        std::mem::take(&mut self.tele_inbox)
    }

    /// Next in-order frame; `Ok(None)` only after the peer's `FIN` (clean
    /// drain). Conduit failures trigger re-accept + resync internally and
    /// only surface as `Err` once every conduit is gone and the
    /// reconnect budget is exhausted.
    pub fn recv(&mut self) -> Result<Option<Frame>> {
        loop {
            if let Some(f) = self.session.pop_ready() {
                self.try_ack(false);
                return Ok(Some(f));
            }
            if self.done {
                return Ok(None);
            }
            // Epoch snapshot BEFORE the poll: bytes the reactor sweeps
            // in while we're polling bump the epoch past `seen`, so the
            // idle wait below returns immediately instead of losing the
            // wakeup.
            let seen = self.notify.epoch();
            self.accept_new();
            if self.conduits.is_empty() {
                self.await_peer()?;
                continue;
            }
            let progressed = self.poll_conduits()?;
            self.try_ack(false);
            self.try_fin_ack();
            if !progressed && !self.session.has_ready() && !self.done {
                // Park until the reactor sweeps more bytes in. Bounded:
                // a freshly dialing conduit sits in the listener backlog
                // without firing any notify, so the accept sweep must
                // still come around on its own.
                self.notify.wait_past(seen, Duration::from_millis(5));
            }
        }
    }

    /// Greet every connection waiting on the listener (non-blocking).
    fn accept_new(&mut self) {
        for stream in accept_pending(&self.listener) {
            self.adopt(stream);
        }
    }

    fn adopt(&mut self, mut stream: TcpStream) {
        stream.set_nodelay(true).ok();
        // Greet with the cumulative position, followed by one advisory
        // HAVE per seq already parked in the reorder window — all in a
        // single write, so the dialer's post-HELLO sweep sees the whole
        // batch before it starts replaying and can skip frames other
        // stripes already delivered.
        let mut greeting = self.session.hello_record().to_vec();
        for seq in self.session.parked_seqs() {
            greeting.extend_from_slice(&ctrl_record(K_HAVE, seq));
        }
        if write_raw(&mut stream, &greeting).is_err() {
            return; // stale backlog entry; the dialer will retry
        }
        // The HELLO just written is a cumulative ack.
        let pos = self.session.next_expected();
        self.session.mark_acked(pos);
        let conduit = match AcceptedConduit::new(stream, &self.notify) {
            Ok(c) => c,
            // Reactor registration failed: the conduit never joins; the
            // dialer sees EOF and redials, same as a failed greeting.
            Err(_) => return,
        };
        if self.ever_connected && self.deaths > 0 {
            // Re-accepts count separately from the dialer's reconnects:
            // a loopback link shares one stats block between both ends,
            // and one outage must not read as two. Stall is charged on
            // the dialing side only (the two waits overlap).
            self.stats
                .reaccepts
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.deaths -= 1;
        }
        self.ever_connected = true;
        self.conduits.push(conduit);
    }

    /// Block (bounded) until at least one conduit connects — the
    /// zero-conduit state is the striped analogue of the single link
    /// being down.
    fn await_peer(&mut self) -> Result<()> {
        let was_connected = self.ever_connected;
        // First accept of the session = startup (peers may launch in any
        // order, as generous as the plain connect retry); later ones are
        // outage recovery.
        let budget = if was_connected {
            self.cfg.reconnect_timeout
        } else {
            self.cfg.initial_timeout.max(self.cfg.reconnect_timeout)
        };
        let deadline = Instant::now() + budget;
        while self.conduits.is_empty() {
            self.accept_new();
            if !self.conduits.is_empty() {
                return Ok(());
            }
            if Instant::now() >= deadline {
                let what = if was_connected {
                    "peer did not reconnect"
                } else {
                    "no peer connected"
                };
                anyhow::bail!(
                    "{what} within {budget:?} (listening on {})",
                    self.listener
                        .local_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "?".into())
                );
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        Ok(())
    }

    /// Sweep every conduit for available bytes and feed the session.
    /// Returns whether anything moved. Dead conduits are dropped (their
    /// unacked frames replay on the next accept); protocol violations
    /// (an uncoverable gap, a mismatched FIN) are hard errors.
    fn poll_conduits(&mut self) -> Result<bool> {
        let mut progressed = false;
        let mut force_ack = false;
        let mut i = 0;
        while i < self.conduits.len() {
            self.scratch.clear();
            let sweep = self.conduits[i].reg.drain_into(&mut self.scratch);
            if !self.scratch.is_empty() {
                self.conduits[i].decoder.extend(&self.scratch);
            }
            let mut dead = matches!(sweep, ReadSweep::Dead);
            // Decode whatever arrived — even off a dead conduit, bytes
            // that landed before the EOF still count.
            loop {
                let item = match self.conduits[i].decoder.next() {
                    Ok(Some(item)) => item,
                    Ok(None) => break,
                    Err(_) => {
                        // Desynced or corrupt stream: drop the conduit;
                        // replay makes skipping nothing safe.
                        dead = true;
                        break;
                    }
                };
                match item {
                    WireItem::Frame(f) => match self.session.on_frame(f)? {
                        RxStep::Delivered | RxStep::Buffered => progressed = true,
                        RxStep::Duplicate => {
                            // Replayed frame we already have: drop it and
                            // re-ack immediately so the sender resyncs.
                            self.stats
                                .deduped
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            force_ack = true;
                        }
                    },
                    WireItem::Ctrl(K_FIN, end) => {
                        self.session.on_fin(end)?;
                        progressed = true;
                    }
                    WireItem::Telemetry(p) => {
                        self.tele_inbox.push(p);
                        progressed = true;
                    }
                    WireItem::Ctrl(_, _) => {} // not meaningful inbound; skip
                }
            }
            if dead {
                self.conduits.remove(i);
                self.deaths += 1;
            } else {
                i += 1;
            }
        }
        if force_ack {
            self.try_ack(true);
        }
        Ok(progressed)
    }

    /// Write a cumulative `ACK` when one is due — on any live conduit; a
    /// failed write drops that conduit (the frame is already delivered,
    /// and the lost ack is recovered by the next connection's HELLO).
    fn try_ack(&mut self, force: bool) {
        let Some(pos) = self.session.ack_due(force) else {
            return;
        };
        if self.write_ctrl_any(K_ACK, pos) {
            self.session.mark_acked(pos);
        }
    }

    /// Send the FIN_ACK once every frame below the FIN boundary is in.
    /// On write failure stay acceptable instead of vanishing, so the
    /// sender's reconnect + re-FIN finds us and the drain completes
    /// (everything is received; only the acknowledgement is missing).
    fn try_fin_ack(&mut self) {
        let Some(end) = self.session.fin_due() else {
            return;
        };
        if self.write_ctrl_any(K_FIN_ACK, end) {
            self.session.mark_fin_acked();
            self.done = true;
        }
    }

    /// Write one control record on the first conduit that takes it,
    /// dropping the ones that fail. `false` = no conduit took it.
    fn write_ctrl_any(&mut self, kind: u8, seq: u64) -> bool {
        let mut i = 0;
        while i < self.conduits.len() {
            if write_ctrl(&mut self.conduits[i].stream, kind, seq).is_ok() {
                return true;
            }
            self.conduits.remove(i);
            self.deaths += 1;
        }
        false
    }
}

impl FrameRx for StripedRx {
    fn recv(&mut self) -> Result<Option<Frame>> {
        StripedRx::recv(self)
    }

    fn kind(&self) -> &'static str {
        "tcp+striped"
    }

    fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        Some(self.stats.clone())
    }

    fn poll_telemetry(&mut self) -> Vec<Vec<u8>> {
        StripedRx::poll_telemetry(self)
    }
}

/// A striped loopback boundary sharing one stats block: the Tx dials the
/// Rx's kept listener with `stripes` conduits. Endpoints connect lazily
/// on first use.
pub fn striped_loopback_pair(
    stripes: usize,
    cfg: &ResilienceConfig,
) -> Result<(StripedTx, StripedRx)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stats = Arc::new(ResilienceStats::default());
    let rx = StripedRx::accept_on(Arc::new(listener), cfg.clone(), stats.clone());
    let tx = StripedTx::connect_to(addr, stripes, cfg.clone(), stats);
    Ok((tx, rx))
}
