//! Edge network substrate.
//!
//! Replaces the paper's testbed networking (6 Jetsons + Linux `tc`
//! shaping) with a byte-accurate simulation plus a real-TCP option:
//!
//! * [`trace`] — piecewise-constant bandwidth traces (the experiment
//!   script's `tc` schedule); the controller is never told about changes,
//!   it must *measure* them, exactly as in the paper.
//! * [`link`] — a serialization-delay link model with propagation latency,
//!   jitter and loss injection.
//! * [`frame`] — the wire format for (possibly quantized) activations:
//!   self-describing header + CRC32-protected payload.
//! * [`transport`] — the `FrameTx`/`FrameRx` abstraction the pipeline
//!   drives: in-process channels (shaped by a [`link::SimLink`]) and real
//!   TCP sockets ([`tcp`]) behind one pair of traits, selected per stage
//!   boundary by [`transport::LinkSpec`]. On TCP the bandwidth signal is
//!   measured write-stall time, not simulation.
//! * [`resilient`] — the fault-tolerant link layer over [`tcp`]:
//!   reconnect with backoff+jitter, sequenced replay from a bounded
//!   buffer, receiver-side dedup, and an explicit FIN/FIN_ACK drain so a
//!   transient link failure stalls the pipeline (feeding the adaptive
//!   controller) instead of killing it.

pub mod frame;
pub mod link;
pub mod resilient;
pub mod tcp;
pub mod trace;
pub mod transport;

/// Bits per second. `f64::INFINITY` means unlimited (no shaping).
pub type Bps = f64;

/// Convenience: megabits/s → bits/s (the paper quotes Mbps throughout).
pub fn mbps(v: f64) -> Bps {
    v * 1e6
}
