//! Edge network substrate.
//!
//! Replaces the paper's testbed networking (6 Jetsons + Linux `tc`
//! shaping) with a byte-accurate simulation plus a real-TCP option:
//!
//! * [`trace`] — piecewise-constant bandwidth traces (the experiment
//!   script's `tc` schedule); the controller is never told about changes,
//!   it must *measure* them, exactly as in the paper.
//! * [`link`] — a serialization-delay link model with propagation latency,
//!   jitter and loss injection.
//! * [`frame`] — the wire format for (possibly quantized) activations:
//!   self-describing header + CRC32-protected payload.
//! * [`transport`] — the `FrameTx`/`FrameRx` abstraction the pipeline
//!   drives: in-process channels (shaped by a [`link::SimLink`]) and real
//!   TCP sockets ([`tcp`]) behind one pair of traits, selected per stage
//!   boundary by [`transport::LinkSpec`]. On TCP the bandwidth signal is
//!   measured write-stall time, not simulation.
//! * [`session`] — the reliability protocol itself (shared sequence
//!   space, bounded replay buffer, cumulative ACK trimming, HELLO resync,
//!   dedup/reorder window, FIN/FIN_ACK drain, plus the data-plane-neutral
//!   telemetry record) as a pure state machine with no socket types in
//!   scope — unit/property-testable offline. The normative wire spec is
//!   `docs/WIRE_PROTOCOL.md`.
//! * [`conduit`] — one physical connection of a session: dial/accept
//!   lifecycle, backoff bookkeeping, raw non-blocking byte I/O.
//! * [`reactor`] — the process-wide read reactor: one thread sweeps
//!   every registered conduit socket into per-registration inboxes and
//!   wakes the owning boundary, replacing per-conduit blocking reads.
//! * [`stripe`] — a stage boundary fanning one session over N conduits
//!   (connection striping for high-BDP/multi-path edge links): round-robin
//!   with a least-stalled bias on the sender, reordering through the
//!   shared sequence space on the receiver, aggregate busy time feeding
//!   the adaptive controller so a lost stripe reads as partial bandwidth
//!   collapse.
//! * [`resilient`] — the fault-tolerant link layer over [`tcp`]:
//!   reconnect with backoff+jitter, sequenced replay from a bounded
//!   buffer, receiver-side dedup, and an explicit FIN/FIN_ACK drain so a
//!   transient link failure stalls the pipeline (feeding the adaptive
//!   controller) instead of killing it. Implemented as the 1-conduit
//!   instantiation of [`stripe`].
//! * [`shaper`] — the chaos transport lab's root-free `tc netem`: a
//!   deterministic per-conduit byte shaper (trace-driven token bucket,
//!   delay+jitter, corruption, loss-as-conduit-kill, partition windows)
//!   applied on the sender threads at the striped write path.
//! * [`scenario`] — named, seeded impairment schedules (`cellular_fade`,
//!   `satellite_pass`, …) that instantiate per-stripe shapers from
//!   `transport.scenario` config / `--scenario` CLI.

pub mod conduit;
pub mod frame;
pub mod link;
pub mod reactor;
pub mod resilient;
pub mod scenario;
pub mod session;
pub mod shaper;
pub mod stripe;
pub mod tcp;
pub mod trace;
pub mod transport;

/// Bits per second. `f64::INFINITY` means unlimited (no shaping).
pub type Bps = f64;

/// Convenience: megabits/s → bits/s (the paper quotes Mbps throughout).
pub fn mbps(v: f64) -> Bps {
    v * 1e6
}
