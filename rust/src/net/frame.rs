//! Wire format for inter-stage activation frames.
//!
//! Self-describing so the receiver can dequantize without out-of-band
//! coordination — the sender may change bitwidth at any window boundary
//! (adaptive PDA) and the receiver just follows the header:
//!
//! ```text
//! magic  u32  "QPFR"
//! ver    u8
//! kind   u8    0 = raw f32, 1 = quantized, 2 = tiled
//! bits   u8    2/4/6/8/16 (or 32 for raw and tiled)
//! rank   u8
//! seq    u64   microbatch sequence number
//! stream u32   client stream / request ID (0 = single-stream)
//! scale  f32 | zp f32 | lo f32 | hi f32     (kind 1 only)
//! dims   u32 × rank
//! plen   u32   payload byte length
//! crc    u32   CRC32 (IEEE) of payload
//! payload …
//! ```
//!
//! Version 2 added the `stream` word for the multi-stream serving plane
//! (`pipeline::serve`): the coordinator tags each microbatch with the
//! client session it belongs to and demuxes returned logits by it.
//! Stream IDs are payload routing only — the session layer's sequence
//! space stays global per boundary, so reliability (replay, ACKs, HELLO
//! resync) is completely stream-oblivious.
//!
//! Kind 2 payloads are self-describing tiled payloads
//! (`quant::tile`): the per-tile param table, the outlier side-channel
//! and the packed streams all live inside the payload, so the header
//! carries no scale/zp/lo/hi and the `bits` byte stays 32 (per-tile
//! widths vary; see `Encoded::avg_wire_bits`).

use crate::quant::codec::Encoded;
use crate::quant::QuantParams;
use crate::Result;

/// Frame header magic ("QPFR").
pub const MAGIC: u32 = 0x5150_4652; // "QPFR"
/// Frame format version.
pub const VERSION: u8 = 2;

/// One activation frame: header + payload bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Microbatch sequence number.
    pub seq: u64,
    /// Client stream / request ID (0 = the single-stream default). Pure
    /// payload routing: the session layer never looks at it.
    pub stream: u32,
    /// Activation shape (outermost first).
    pub shape: Vec<usize>,
    /// Encoded payload + quantization parameters.
    pub enc: Encoded,
}

impl Frame {
    /// Assemble a single-stream frame (stream 0) from its parts.
    pub fn new(seq: u64, shape: Vec<usize>, enc: Encoded) -> Self {
        Frame { seq, stream: 0, shape, enc }
    }

    /// Assemble a frame tagged with a client stream ID (serving plane).
    pub fn for_stream(stream: u32, seq: u64, shape: Vec<usize>, enc: Encoded) -> Self {
        Frame { seq, stream, shape, enc }
    }

    /// Total bytes on the wire (header + payload).
    pub fn wire_len(&self) -> usize {
        self.header_len() + self.enc.payload.len()
    }

    fn header_len(&self) -> usize {
        4 + 1
            + 1
            + 1
            + 1
            + 8
            + 4
            + if self.enc.params.is_some() { 16 } else { 0 }
            + 4 * self.shape.len()
            + 4
            + 4
    }

    /// Serialize to a fresh buffer. Hot paths use [`Frame::write_into`]
    /// with a per-link wire buffer instead.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        self.write_into(&mut out);
        out
    }

    /// Serialize into a reusable buffer (cleared first). Senders keep one
    /// wire buffer per link (or draw from the session's recycled pool) so
    /// steady-state framing allocates nothing.
    pub fn write_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(self.wire_len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.push(VERSION);
        out.push(if self.enc.tiled {
            2
        } else if self.enc.params.is_some() {
            1
        } else {
            0
        });
        out.push(self.enc.bits());
        out.push(self.shape.len() as u8);
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.stream.to_le_bytes());
        if let Some(p) = self.enc.params {
            out.extend_from_slice(&p.scale.to_le_bytes());
            out.extend_from_slice(&p.zero_point.to_le_bytes());
            out.extend_from_slice(&p.lo.to_le_bytes());
            out.extend_from_slice(&p.hi.to_le_bytes());
        }
        for &d in &self.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        out.extend_from_slice(&(self.enc.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&self.enc.payload).to_le_bytes());
        out.extend_from_slice(&self.enc.payload);
    }

    /// Parse from bytes (validates magic, version, CRC).
    pub fn from_bytes(buf: &[u8]) -> Result<Frame> {
        let mut r = Reader { buf, pos: 0 };
        anyhow::ensure!(r.u32()? == MAGIC, "bad frame magic");
        anyhow::ensure!(r.u8()? == VERSION, "unsupported frame version");
        let kind = r.u8()?;
        anyhow::ensure!(kind <= 2, "unknown frame kind {kind}");
        let bits = r.u8()?;
        let rank = r.u8()? as usize;
        let seq = r.u64()?;
        let stream = r.u32()?;
        let params = if kind == 1 {
            Some(QuantParams {
                scale: r.f32()?,
                zero_point: r.f32()?,
                lo: r.f32()?,
                hi: r.f32()?,
                bits,
            })
        } else {
            None
        };
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u32()? as usize);
        }
        let plen = r.u32()? as usize;
        let crc = r.u32()?;
        anyhow::ensure!(r.buf.len() - r.pos >= plen, "frame payload truncated");
        let payload = r.buf[r.pos..r.pos + plen].to_vec();
        anyhow::ensure!(crc32(&payload) == crc, "frame CRC mismatch");
        let elems: usize = shape.iter().product();
        Ok(Frame {
            seq,
            stream,
            shape,
            enc: Encoded { params, elems, payload, tiled: kind == 2 },
        })
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.pos + n <= self.buf.len(), "frame header truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        // lint: allow(unwrap): take(4) guarantees a 4-byte slice, conversion is infallible
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        // lint: allow(unwrap): take(8) guarantees an 8-byte slice, conversion is infallible
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        // lint: allow(unwrap): take(4) guarantees a 4-byte slice, conversion is infallible
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// CRC32 (IEEE 802.3), table-driven.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xff) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codec::Codec;
    use crate::quant::Method;

    fn sample_frame(bits: u8) -> Frame {
        let x: Vec<f32> = (0..256).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let mut c = Codec::default();
        let enc = c.encode(&x, Method::Pda, bits).unwrap();
        Frame::new(7, vec![2, 8, 16], enc)
    }

    #[test]
    fn crc32_known_vector() {
        // Standard test vector: crc32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_all_bitwidths() {
        for bits in [2u8, 4, 6, 8, 16, 32] {
            let f = sample_frame(bits);
            let bytes = f.to_bytes();
            assert_eq!(bytes.len(), f.wire_len());
            let back = Frame::from_bytes(&bytes).unwrap();
            assert_eq!(back, f, "bits={bits}");
        }
    }

    #[test]
    fn decode_roundtrip_through_frame() {
        let x: Vec<f32> = (0..256).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut c = Codec::default();
        let enc = c.encode(&x, Method::Aciq, 8).unwrap();
        let f = Frame::new(0, vec![256], enc);
        let back = Frame::from_bytes(&f.to_bytes()).unwrap();
        let mut out = Vec::new();
        c.decode(&back.enc, &mut out).unwrap();
        let p = back.enc.params.unwrap();
        for (a, b) in x.iter().zip(&out) {
            assert!((a - b).abs() <= p.scale / 2.0 + 1e-6);
        }
    }

    #[test]
    fn tiled_frame_roundtrips_as_kind_2() {
        use crate::quant::tile::{TileCodec, TileConfig};
        let x: Vec<f32> = (0..1024).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let mut c = Codec::default();
        let cfg = TileConfig { tile_elems: 256, outlier_frac: 0.01 };
        c.set_tiling(Some(TileCodec::new(cfg, Method::Pda)));
        let enc = c.encode_tiled(&x, 4, None).unwrap();
        let f = Frame::new(3, vec![4, 256], enc);
        let bytes = f.to_bytes();
        assert_eq!(bytes[5], 2, "tiled frames use kind 2");
        let back = Frame::from_bytes(&bytes).unwrap();
        assert_eq!(back, f);
        assert!(back.enc.tiled);
        let mut out = Vec::new();
        c.decode(&back.enc, &mut out).unwrap();
        assert_eq!(out.len(), 1024);
        // An unknown kind is a parse error, not a silent misread.
        let mut bad = bytes.clone();
        bad[5] = 3;
        assert!(Frame::from_bytes(&bad).unwrap_err().to_string().contains("kind"));
    }

    #[test]
    fn corrupt_payload_detected() {
        let f = sample_frame(8);
        let mut bytes = f.to_bytes();
        let n = bytes.len();
        bytes[n - 1] ^= 0xff;
        assert!(Frame::from_bytes(&bytes).unwrap_err().to_string().contains("CRC"));
    }

    #[test]
    fn corrupt_magic_detected() {
        let f = sample_frame(4);
        let mut bytes = f.to_bytes();
        bytes[0] ^= 0xff;
        assert!(Frame::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let f = sample_frame(16);
        let bytes = f.to_bytes();
        for cut in [3usize, 10, bytes.len() - 1] {
            assert!(Frame::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn write_into_matches_to_bytes_and_reuses_the_buffer() {
        // Descending frame sizes: after the 32-bit frame grows the buffer
        // once, every later (smaller or equal) frame must reuse it.
        let mut wire = Vec::new();
        let mut ptr = std::ptr::null();
        for (i, bits) in [32u8, 8, 8, 2].into_iter().enumerate() {
            let f = sample_frame(bits);
            f.write_into(&mut wire);
            assert_eq!(wire, f.to_bytes(), "bits={bits}");
            assert_eq!(Frame::from_bytes(&wire).unwrap(), f);
            if i > 0 {
                assert_eq!(wire.as_ptr(), ptr, "bits={bits}: buffer must be reused");
            }
            ptr = wire.as_ptr();
        }
    }

    #[test]
    fn stream_id_roundtrips_and_defaults_to_zero() {
        let f = sample_frame(8);
        assert_eq!(f.stream, 0, "Frame::new is the single-stream constructor");
        let tagged = Frame::for_stream(42, f.seq, f.shape.clone(), f.enc.clone());
        let back = Frame::from_bytes(&tagged.to_bytes()).unwrap();
        assert_eq!(back.stream, 42);
        assert_eq!(back, tagged);
        // A v1 (pre-stream) header is rejected loudly, not misparsed.
        let mut old = tagged.to_bytes();
        old[4] = 1;
        assert!(Frame::from_bytes(&old).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn header_overhead_is_small() {
        let f = sample_frame(2);
        let overhead = f.wire_len() - f.enc.payload.len();
        assert!(overhead <= 64, "header overhead {overhead}");
    }
}
