//! Fault-tolerant TCP link layer: reconnect, sequenced replay, dedup and
//! a clean drain protocol over the existing frame wire format.
//!
//! QuantPipe targets *dynamic edge environments*, where links don't just
//! slow down — they drop and come back. The plain TCP endpoints report a
//! failure and the run dies; these wrappers make a stage boundary survive
//! it:
//!
//! * **Replay buffer** — [`ReconnectingTx`] keeps every sent frame until
//!   the peer acknowledges it (bounded by `replay_capacity`; a full buffer
//!   blocks the sender, which is just backpressure by another name).
//! * **Reconnect** — on any I/O error the connecting side redials with
//!   exponential backoff + jitter ([`super::tcp::Backoff`]) and the
//!   accepting side re-accepts on its kept listener, both bounded by
//!   `reconnect_timeout`.
//! * **Resync handshake** — on every (re)connect the receiver speaks
//!   first: `HELLO{next_expected_seq}`. The sender trims its replay buffer
//!   below that point and replays exactly the lost tail; the receiver
//!   discards anything it has already delivered (dedup). No frame is lost
//!   or duplicated as long as the outage is shorter than the budget.
//! * **Drain protocol** — shutdown is explicit: the sender ends with
//!   `FIN{end_seq}` and waits for `FIN_ACK`. A bare EOF therefore always
//!   means *failure* (reconnect), never "peer finished".
//!
//! Since the boundary-session refactor this module is the **1-conduit
//! instantiation** of the layered stack:
//!
//! * [`super::session`] — every protocol decision (replay buffer,
//!   cumulative ACK trimming, HELLO resync, dedup, FIN/FIN_ACK), with no
//!   socket types in scope;
//! * [`super::conduit`] — per-connection dial/accept/backoff and raw
//!   byte I/O;
//! * [`super::stripe`] — the boundary glue fanning one session over N
//!   conduits. [`ReconnectingTx`]/[`ReconnectingRx`] are `StripedTx`/
//!   `StripedRx` with N = 1 and a strict (reorder-free) receiver, so the
//!   single-link and striped paths can never drift apart.
//!
//! The adaptive loop needs no special case: `send` returns the seconds it
//! was busy, reconnect stalls included, so the `WindowMonitor` sees an
//! outage as collapsed measured bandwidth and the `AdaptivePda` sheds
//! bits instead of the run aborting.
//!
//! Wire format (see [`super::session`] for the byte layout): data frames
//! are length-prefixed exactly as in [`super::tcp`]; control records use
//! the impossible length prefix `u32::MAX` as a marker, followed by one
//! kind byte and a `u64` sequence number — 13 bytes total. Both
//! directions of one socket are used: data + FIN flow forward,
//! HELLO/ACK/FIN_ACK flow backward. Roles are fixed by who dials:
//! [`ReconnectingTx`] connects (and redials), [`ReconnectingRx`] accepts
//! (and re-accepts). Both ends must run the resilient layer — mixing a
//! resilient endpoint with a plain one on the same socket desyncs on the
//! first control record.

use super::frame::Frame;
use super::stripe::{StripedRx, StripedTx};
use super::transport::{FrameRx, FrameTx, PreparedFrame};
use crate::metrics::ResilienceStats;
use crate::Result;
use std::net::TcpListener;
use std::sync::Arc;

pub use super::conduit::LinkKillSwitch;
pub use super::session::ResilienceConfig;

/// Fault-tolerant sender half. Dials `peer` lazily on first send, keeps a
/// replay buffer of unacked frames, redials with backoff on failure, and
/// ends with the FIN/FIN_ACK drain in [`ReconnectingTx::finish`]. One
/// conduit of the striped boundary ([`super::stripe::StripedTx`]).
pub struct ReconnectingTx(StripedTx);

impl ReconnectingTx {
    /// Lazily-connecting sender toward `peer` (e.g. `"10.0.0.2:9000"`).
    pub fn connect_to(
        peer: impl Into<String>,
        cfg: ResilienceConfig,
        stats: Arc<ResilienceStats>,
    ) -> Self {
        ReconnectingTx(StripedTx::connect_to(peer, 1, cfg, stats))
    }

    /// Shared resilience counters for this link.
    pub fn stats(&self) -> Arc<ResilienceStats> {
        self.0.stats()
    }

    /// Handle that can kill the active socket (fault injection).
    pub fn kill_switch(&self) -> LinkKillSwitch {
        self.0.kill_switch_for(0)
    }

    /// Frames sent but not yet acknowledged by the peer.
    pub fn unacked(&self) -> usize {
        self.0.unacked()
    }

    /// Attach a chaos shaper (`net::shaper`) to this link's single
    /// conduit. `None` restores the unshaped write path.
    pub fn set_shaper(&mut self, shaper: Option<Arc<super::shaper::LinkShaper>>) {
        self.0.set_shaper(0, shaper)
    }

    /// Drain any acks the peer has pushed without blocking. `send` does
    /// this itself on a schedule.
    pub fn pump(&mut self) {
        self.0.pump()
    }

    /// Ship one frame. Blocks through replay-buffer backpressure and any
    /// reconnect + replay cycle; returns the seconds spent, which is the
    /// busy time the `WindowMonitor` turns into measured bandwidth — an
    /// outage therefore *is* the bandwidth signal.
    pub fn send(&mut self, frame: Frame) -> Result<f64> {
        self.0.send(frame)
    }

    /// Drain protocol: make sure every frame is delivered, send
    /// `FIN{next_seq}` and wait for `FIN_ACK`. After this the peer's
    /// `recv` has returned `Ok(None)` — a clean shutdown, observably
    /// different from a failure on both ends.
    pub fn finish(&mut self) -> Result<()> {
        self.0.finish()
    }
}

impl FrameTx for ReconnectingTx {
    fn send(&mut self, frame: Frame) -> Result<f64> {
        self.0.send(frame)
    }

    // Forward explicitly: the newtype must not fall back to the trait's
    // re-parsing default, or the copy-free path would silently copy.
    fn send_prepared(&mut self, prepared: PreparedFrame) -> Result<f64> {
        self.0.send_prepared(prepared)
    }

    fn reclaim_wire(&mut self) -> Option<Vec<u8>> {
        self.0.reclaim_wire()
    }

    fn kind(&self) -> &'static str {
        "tcp+resilient"
    }

    fn finish(&mut self) -> Result<()> {
        self.0.finish()
    }

    fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        Some(self.0.stats())
    }
    // stripes() stays None: a single-conduit link reports through the
    // resilience counters only, keeping pre-striping reports unchanged.

    fn send_telemetry(&mut self, payload: &[u8]) -> Result<()> {
        self.0.send_telemetry(payload)
    }
}

/// Fault-tolerant receiver half. Keeps its listener so a failed peer can
/// come back; speaks `HELLO{next_expected}` on every (re)accept, acks
/// cumulatively, dedups replayed frames, and turns `FIN` into the clean
/// `Ok(None)` end-of-stream. One conduit of the striped boundary, with
/// the strict in-order receiver (a single ordered connection can never
/// legitimately skip ahead, so a sequence gap is a protocol error).
pub struct ReconnectingRx(StripedRx);

impl ReconnectingRx {
    /// Receiver that (re-)accepts peers on `listener`.
    pub fn accept_on(
        listener: Arc<TcpListener>,
        cfg: ResilienceConfig,
        stats: Arc<ResilienceStats>,
    ) -> Self {
        ReconnectingRx(StripedRx::accept_on_ordered(listener, cfg, stats))
    }

    /// Shared resilience counters for this link.
    pub fn stats(&self) -> Arc<ResilienceStats> {
        self.0.stats()
    }

    /// Next in-order frame; `Ok(None)` only after the peer's `FIN` (clean
    /// drain). Link failures trigger re-accept + resync internally and
    /// only surface as `Err` once `reconnect_timeout` is exhausted.
    pub fn recv(&mut self) -> Result<Option<Frame>> {
        self.0.recv()
    }
}

impl FrameRx for ReconnectingRx {
    fn recv(&mut self) -> Result<Option<Frame>> {
        self.0.recv()
    }

    fn kind(&self) -> &'static str {
        "tcp+resilient"
    }

    fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        Some(self.0.stats())
    }

    fn poll_telemetry(&mut self) -> Vec<Vec<u8>> {
        self.0.poll_telemetry()
    }
}

/// A resilient loopback boundary sharing one stats block: the Tx dials
/// the Rx's kept listener. Endpoints connect lazily on first use.
pub fn resilient_loopback_pair(
    cfg: &ResilienceConfig,
) -> Result<(ReconnectingTx, ReconnectingRx)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stats = Arc::new(ResilienceStats::default());
    let rx = ReconnectingRx::accept_on(Arc::new(listener), cfg.clone(), stats.clone());
    let tx = ReconnectingTx::connect_to(addr, cfg.clone(), stats);
    Ok((tx, rx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::conduit::{write_ctrl, write_frame_bytes};
    use crate::net::session::{parse_ctrl, CTRL_LEN, CTRL_MARKER, K_ACK, K_FIN, K_FIN_ACK, K_HELLO};
    use crate::quant::codec::Codec;
    use crate::quant::Method;
    use std::io::Read;
    use std::net::TcpStream;
    use std::time::{Duration, Instant};

    fn fast_cfg() -> ResilienceConfig {
        ResilienceConfig {
            replay_capacity: 16,
            reconnect_timeout: Duration::from_secs(5),
            initial_timeout: Duration::from_secs(5),
            backoff_base: Duration::from_millis(2),
            backoff_max: Duration::from_millis(20),
            jitter: 0.5,
            hello_timeout: Duration::from_millis(500),
            drain_timeout: Duration::from_secs(5),
            seed: 1,
        }
    }

    fn frame(seq: u64, n: usize) -> Frame {
        let x: Vec<f32> = (0..n).map(|i| ((i + seq as usize) as f32).sin()).collect();
        let mut c = Codec::default();
        Frame::new(seq, vec![n], c.encode(&x, Method::Pda, 8).unwrap())
    }

    /// Read one length-prefixed frame off a raw socket (scripted peers).
    fn raw_read_frame(s: &mut TcpStream) -> Frame {
        let mut pre = [0u8; 4];
        s.read_exact(&mut pre).unwrap();
        let len = u32::from_le_bytes(pre) as usize;
        assert_ne!(len, CTRL_MARKER as usize, "expected a data frame, got a control record");
        let mut buf = vec![0u8; len];
        s.read_exact(&mut buf).unwrap();
        Frame::from_bytes(&buf).unwrap()
    }

    /// Read one control record off a raw socket.
    fn raw_read_ctrl(s: &mut TcpStream) -> (u8, u64) {
        let mut rec = [0u8; CTRL_LEN];
        s.read_exact(&mut rec).unwrap();
        assert_eq!(u32::from_le_bytes(rec[0..4].try_into().unwrap()), CTRL_MARKER);
        parse_ctrl(&rec)
    }

    #[test]
    fn replay_buffer_trims_on_ack() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let peer = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            write_ctrl(&mut s, K_HELLO, 0).unwrap();
            for want in 0..4u64 {
                assert_eq!(raw_read_frame(&mut s).seq, want);
            }
            // Cumulative ack: frames 0 and 1 are delivered.
            write_ctrl(&mut s, K_ACK, 2).unwrap();
            s // keep the socket open until the test is done
        });
        let mut tx = ReconnectingTx::connect_to(addr, fast_cfg(), Default::default());
        for seq in 0..4 {
            tx.send(frame(seq, 32)).unwrap();
        }
        assert_eq!(tx.unacked(), 4);
        let deadline = Instant::now() + Duration::from_secs(5);
        while tx.unacked() != 2 && Instant::now() < deadline {
            tx.pump();
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(tx.unacked(), 2, "ACK{{2}} must trim exactly seqs 0 and 1");
        let _s = peer.join().unwrap();
    }

    #[test]
    fn receiver_dedups_replayed_frames_and_acks_cumulatively() {
        let listener = Arc::new(TcpListener::bind("127.0.0.1:0").unwrap());
        let addr = listener.local_addr().unwrap().to_string();
        let stats = Arc::new(ResilienceStats::default());
        let mut rx = ReconnectingRx::accept_on(listener, fast_cfg(), stats.clone());
        let peer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            assert_eq!(raw_read_ctrl(&mut s), (K_HELLO, 0));
            write_frame_bytes(&mut s, &frame(0, 32).to_bytes()).unwrap();
            write_frame_bytes(&mut s, &frame(0, 32).to_bytes()).unwrap(); // duplicate
            write_frame_bytes(&mut s, &frame(1, 32).to_bytes()).unwrap();
            write_ctrl(&mut s, K_FIN, 2).unwrap();
            // Drain acks until FIN_ACK confirms the clean shutdown.
            loop {
                let (kind, seq) = raw_read_ctrl(&mut s);
                if kind == K_FIN_ACK {
                    assert_eq!(seq, 2);
                    break;
                }
                assert_eq!(kind, K_ACK);
            }
        });
        assert_eq!(rx.recv().unwrap().unwrap().seq, 0);
        assert_eq!(rx.recv().unwrap().unwrap().seq, 1);
        assert!(rx.recv().unwrap().is_none(), "FIN must be a clean end of stream");
        assert!(rx.recv().unwrap().is_none(), "recv after FIN stays clean");
        assert_eq!(stats.deduped.load(std::sync::atomic::Ordering::Relaxed), 1);
        peer.join().unwrap();
    }

    #[test]
    fn reconnect_replays_exactly_the_unacked_tail() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let peer = std::thread::spawn(move || {
            // Connection 1: greet, read 2 frames, ack only the first, die.
            let (mut s, _) = listener.accept().unwrap();
            write_ctrl(&mut s, K_HELLO, 0).unwrap();
            assert_eq!(raw_read_frame(&mut s).seq, 0);
            assert_eq!(raw_read_frame(&mut s).seq, 1);
            write_ctrl(&mut s, K_ACK, 1).unwrap();
            std::thread::sleep(Duration::from_millis(30)); // let the ack land
            drop(s);
            // Connection 2: resume from seq 1; the sender must replay 1, 2.
            let (mut s, _) = listener.accept().unwrap();
            write_ctrl(&mut s, K_HELLO, 1).unwrap();
            assert_eq!(raw_read_frame(&mut s).seq, 1);
            assert_eq!(raw_read_frame(&mut s).seq, 2);
            write_ctrl(&mut s, K_ACK, 3).unwrap();
            let (kind, seq) = raw_read_ctrl(&mut s);
            assert_eq!((kind, seq), (K_FIN, 3));
            write_ctrl(&mut s, K_FIN_ACK, 3).unwrap();
        });
        let stats = Arc::new(ResilienceStats::default());
        let mut tx = ReconnectingTx::connect_to(addr, fast_cfg(), stats.clone());
        tx.send(frame(0, 32)).unwrap();
        tx.send(frame(1, 32)).unwrap();
        std::thread::sleep(Duration::from_millis(60)); // peer drops the link
        // This send hits the dead socket (possibly absorbing one buffered
        // write first), reconnects, and replays per the new HELLO.
        tx.send(frame(2, 32)).unwrap();
        tx.finish().unwrap();
        assert_eq!(stats.reconnects.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert!(
            stats.replayed.load(std::sync::atomic::Ordering::Relaxed) >= 1,
            "the unacked tail must be replayed"
        );
        assert_eq!(tx.unacked(), 0);
        peer.join().unwrap();
    }

    #[test]
    fn clean_fin_drain_end_to_end() {
        let (mut tx, mut rx) = resilient_loopback_pair(&fast_cfg()).unwrap();
        let stats = tx.stats();
        let sender = std::thread::spawn(move || {
            for seq in 0..5 {
                tx.send(frame(seq, 64)).unwrap();
            }
            tx.finish().unwrap();
        });
        for want in 0..5u64 {
            assert_eq!(rx.recv().unwrap().unwrap().seq, want);
        }
        assert!(rx.recv().unwrap().is_none());
        sender.join().unwrap();
        assert_eq!(stats.reconnects.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(stats.deduped.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn kill_mid_stream_recovers_without_loss_or_duplication() {
        let (mut tx, mut rx) = resilient_loopback_pair(&fast_cfg()).unwrap();
        let stats = tx.stats();
        let kill = tx.kill_switch();
        let total = 50u64;
        let sender = std::thread::spawn(move || {
            for seq in 0..total {
                tx.send(frame(seq, 256)).unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
            tx.finish().unwrap();
        });
        let mut got = Vec::new();
        for i in 0..total {
            if i == 10 {
                assert!(kill.kill(), "link must be active by frame 10");
            }
            got.push(rx.recv().unwrap().unwrap().seq);
        }
        assert!(rx.recv().unwrap().is_none());
        sender.join().unwrap();
        assert_eq!(got, (0..total).collect::<Vec<_>>(), "loss or duplication after reconnect");
        assert!(
            stats.reconnects.load(std::sync::atomic::Ordering::Relaxed) >= 1,
            "the kill must have forced a reconnect"
        );
    }

    #[test]
    fn sender_errors_once_reconnect_budget_exhausted() {
        // Nothing ever listens on this freshly-bound-then-dropped port.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut cfg = fast_cfg();
        cfg.reconnect_timeout = Duration::from_millis(80);
        cfg.initial_timeout = Duration::from_millis(80);
        let mut tx = ReconnectingTx::connect_to(addr, cfg, Default::default());
        let err = tx.send(frame(0, 16)).unwrap_err();
        assert!(err.to_string().contains("down"), "{err:#}");
    }

    #[test]
    fn receiver_errors_once_reconnect_budget_exhausted() {
        let listener = Arc::new(TcpListener::bind("127.0.0.1:0").unwrap());
        let mut cfg = fast_cfg();
        cfg.reconnect_timeout = Duration::from_millis(80);
        cfg.initial_timeout = Duration::from_millis(80);
        let mut rx = ReconnectingRx::accept_on(listener, cfg, Default::default());
        let err = rx.recv().unwrap_err();
        assert!(err.to_string().contains("no peer connected"), "{err:#}");
    }
}
