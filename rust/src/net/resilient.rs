//! Fault-tolerant TCP link layer: reconnect, sequenced replay, dedup and
//! a clean drain protocol over the existing frame wire format.
//!
//! QuantPipe targets *dynamic edge environments*, where links don't just
//! slow down — they drop and come back. The plain TCP endpoints report a
//! failure and the run dies; these wrappers make a stage boundary survive
//! it:
//!
//! * **Replay buffer** — [`ReconnectingTx`] keeps every sent frame until
//!   the peer acknowledges it (bounded by `replay_capacity`; a full buffer
//!   blocks the sender, which is just backpressure by another name).
//! * **Reconnect** — on any I/O error the connecting side redials with
//!   exponential backoff + jitter ([`super::tcp::Backoff`]) and the
//!   accepting side re-accepts on its kept listener, both bounded by
//!   `reconnect_timeout`.
//! * **Resync handshake** — on every (re)connect the receiver speaks
//!   first: `HELLO{next_expected_seq}`. The sender trims its replay buffer
//!   below that point and replays exactly the lost tail; the receiver
//!   discards anything it has already delivered (dedup). No frame is lost
//!   or duplicated as long as the outage is shorter than the budget.
//! * **Drain protocol** — shutdown is explicit: the sender ends with
//!   `FIN{end_seq}` and waits for `FIN_ACK`. A bare EOF therefore always
//!   means *failure* (reconnect), never "peer finished" — the ambiguity
//!   that makes half-open TCP shutdowns indistinguishable from crashes is
//!   gone from both ends.
//!
//! The adaptive loop needs no special case: `send` returns the seconds it
//! was busy, reconnect stalls included, so the `WindowMonitor` sees an
//! outage as collapsed measured bandwidth and the `AdaptivePda` sheds
//! bits instead of the run aborting.
//!
//! Wire format: data frames are length-prefixed exactly as in
//! [`super::tcp`]; control records use the impossible length prefix
//! `u32::MAX` (> [`MAX_FRAME_BYTES`]) as a marker, followed by one kind
//! byte and a `u64` sequence number — 13 bytes total:
//!
//! ```text
//! marker u32 = 0xFFFF_FFFF | kind u8 | seq u64 LE
//! kind: 1 HELLO{next_expected}  receiver → sender, on every (re)connect
//!       2 ACK{next_expected}    receiver → sender, cumulative
//!       3 FIN{end_seq}          sender → receiver, after the last frame
//!       4 FIN_ACK{end_seq}      receiver → sender, everything delivered
//! ```
//!
//! Both directions of one socket are used: data + FIN flow forward,
//! HELLO/ACK/FIN_ACK flow backward. Roles are fixed by who dials:
//! [`ReconnectingTx`] connects (and redials), [`ReconnectingRx`] accepts
//! (and re-accepts). Both ends must run the resilient layer — mixing a
//! resilient endpoint with a plain one on the same socket desyncs on the
//! first control record.

use super::frame::Frame;
use super::tcp::{connect_until, Backoff, MAX_FRAME_BYTES};
use super::transport::{FrameRx, FrameTx};
use crate::metrics::ResilienceStats;
use crate::util::sync::lock;
use crate::Result;
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Length-prefix value marking a control record (can never be a frame
/// length: it exceeds [`MAX_FRAME_BYTES`]).
const CTRL_MARKER: u32 = u32::MAX;
const CTRL_LEN: usize = 13; // marker u32 + kind u8 + seq u64

const K_HELLO: u8 = 1;
const K_ACK: u8 = 2;
const K_FIN: u8 = 3;
const K_FIN_ACK: u8 = 4;

/// Tuning for the resilient layer. Defaults suit LAN/edge deployments;
/// tests shrink every duration.
#[derive(Debug, Clone)]
pub struct ResilienceConfig {
    /// Sent-but-unacked frames kept for replay. A full buffer blocks the
    /// sender until the receiver acks (backpressure), so no unacked frame
    /// is ever evicted — the no-loss guarantee depends on that. Both ends
    /// of a link should share this value: the receiver batches its
    /// cumulative acks once per `replay_capacity / 4` frames.
    pub replay_capacity: usize,
    /// Total budget to get a link back after a failure; exhausted ⇒ the
    /// outage is reported as a hard error.
    pub reconnect_timeout: Duration,
    /// Budget for the FIRST connection of the session. Multi-process
    /// startup is order-independent, so the initial peer wait must be as
    /// generous as the plain-TCP connect retry — not the (typically
    /// tighter) mid-run reconnect budget.
    pub initial_timeout: Duration,
    /// First redial delay (doubles per attempt).
    pub backoff_base: Duration,
    /// Redial delay cap.
    pub backoff_max: Duration,
    /// Jitter fraction in `[0, 1]`: each delay is scaled by a factor from
    /// `[1 - jitter, 1]`.
    pub jitter: f64,
    /// How long the dialer waits for the peer's `HELLO` on a fresh
    /// connection before treating the attempt as failed.
    pub hello_timeout: Duration,
    /// Budget for the FIN/FIN_ACK drain at shutdown (includes any final
    /// reconnect + replay needed to deliver the tail).
    pub drain_timeout: Duration,
    /// Seed for the jitter RNG (deterministic schedules in tests).
    pub seed: u64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            replay_capacity: 128,
            reconnect_timeout: Duration::from_secs(10),
            initial_timeout: Duration::from_secs(30),
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_secs(1),
            jitter: 0.5,
            hello_timeout: Duration::from_secs(2),
            drain_timeout: Duration::from_secs(10),
            seed: 0x5150_1ead,
        }
    }
}

/// Test/ops lever: force-kill the link's active socket to simulate a
/// transient failure (both ends observe it and run their reconnect
/// paths). Cloned handles share the same slot.
#[derive(Clone, Default)]
pub struct LinkKillSwitch(Arc<Mutex<Option<TcpStream>>>);

impl LinkKillSwitch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Shut down the currently registered connection. Returns `false` if
    /// the link has never connected.
    pub fn kill(&self) -> bool {
        match &*lock(&self.0) {
            Some(s) => {
                let _ = s.shutdown(Shutdown::Both);
                true
            }
            None => false,
        }
    }

    fn register(&self, stream: &TcpStream) {
        *lock(&self.0) = stream.try_clone().ok();
    }
}

// ---------------------------------------------------------------------------
// Shared wire helpers
// ---------------------------------------------------------------------------

fn write_frame_bytes(s: &mut TcpStream, bytes: &[u8]) -> std::io::Result<()> {
    s.write_all(&(bytes.len() as u32).to_le_bytes())?;
    s.write_all(bytes)?;
    s.flush()
}

fn write_ctrl(s: &mut TcpStream, kind: u8, seq: u64) -> std::io::Result<()> {
    let mut rec = [0u8; CTRL_LEN];
    rec[0..4].copy_from_slice(&CTRL_MARKER.to_le_bytes());
    rec[4] = kind;
    rec[5..13].copy_from_slice(&seq.to_le_bytes());
    s.write_all(&rec)?;
    s.flush()
}

/// Parse the record at `rec` (13 bytes, marker already implied checked by
/// the caller): `(kind, seq)`.
fn parse_ctrl(rec: &[u8]) -> (u8, u64) {
    (rec[4], u64::from_le_bytes(rec[5..13].try_into().unwrap()))
}

// ---------------------------------------------------------------------------
// Sender: ReconnectingTx
// ---------------------------------------------------------------------------

/// Fault-tolerant sender half. Dials `peer` lazily on first send, keeps a
/// replay buffer of unacked frames, redials with backoff on failure, and
/// ends with the FIN/FIN_ACK drain in [`ReconnectingTx::finish`].
pub struct ReconnectingTx {
    peer: String,
    cfg: ResilienceConfig,
    stats: Arc<ResilienceStats>,
    conn: Option<TcpStream>,
    /// Unparsed inbound control bytes from the current connection.
    rdbuf: Vec<u8>,
    /// `(seq, serialized frame)` for every sent-but-unacked frame,
    /// ascending and contiguous.
    replay: VecDeque<(u64, Vec<u8>)>,
    /// Receiver's cumulative ack: everything below this is delivered.
    acked: u64,
    /// One past the highest seq handed to `send` (the FIN boundary).
    next_seq: u64,
    fin_acked: bool,
    finished: bool,
    ever_connected: bool,
    dials: u64,
    sends_since_pump: u32,
    /// Decorrelates this endpoint's backoff jitter from its fleet-mates'.
    nonce: u64,
    kill: LinkKillSwitch,
}

/// Drain inbound acks at most every this many sends (sooner when the
/// replay buffer passes half capacity) — the drain costs syscalls and the
/// ACK scheme is cumulative, so per-send pumping buys nothing.
const PUMP_EVERY: u32 = 16;

/// Per-endpoint jitter-seed nonce: endpoints sharing one config (the
/// normal case — one config file per fleet) must still draw DIFFERENT
/// backoff jitter, or a fleet-wide outage retries in lockstep and the
/// jitter defends nothing. Process id decorrelates across processes, the
/// counter decorrelates endpoints within one.
fn endpoint_nonce() -> u64 {
    static COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    (std::process::id() as u64) << 32 | n
}

impl ReconnectingTx {
    /// Lazily-connecting sender toward `peer` (e.g. `"10.0.0.2:9000"`).
    pub fn connect_to(
        peer: impl Into<String>,
        cfg: ResilienceConfig,
        stats: Arc<ResilienceStats>,
    ) -> Self {
        ReconnectingTx {
            peer: peer.into(),
            cfg,
            stats,
            conn: None,
            rdbuf: Vec::new(),
            replay: VecDeque::new(),
            acked: 0,
            next_seq: 0,
            fin_acked: false,
            finished: false,
            ever_connected: false,
            dials: 0,
            sends_since_pump: 0,
            nonce: endpoint_nonce(),
            kill: LinkKillSwitch::new(),
        }
    }

    pub fn stats(&self) -> Arc<ResilienceStats> {
        self.stats.clone()
    }

    /// Handle that can kill the active socket (fault injection).
    pub fn kill_switch(&self) -> LinkKillSwitch {
        self.kill.clone()
    }

    /// Frames sent but not yet acknowledged by the peer.
    pub fn unacked(&self) -> usize {
        self.replay.len()
    }

    /// Drain any acks the peer has pushed without blocking. `send` does
    /// this itself on a schedule (every [`PUMP_EVERY`] sends, or sooner
    /// when the replay buffer passes half capacity).
    pub fn pump(&mut self) {
        self.pump_nonblocking();
    }

    /// Ship one frame. Blocks through replay-buffer backpressure and any
    /// reconnect + replay cycle; returns the seconds spent, which is the
    /// busy time the `WindowMonitor` turns into measured bandwidth — an
    /// outage therefore *is* the bandwidth signal.
    pub fn send(&mut self, frame: Frame) -> Result<f64> {
        anyhow::ensure!(!self.finished, "send on a finished resilient link");
        let t0 = Instant::now();
        let seq = frame.seq;
        let bytes = frame.to_bytes();
        self.sends_since_pump += 1;
        if self.sends_since_pump >= PUMP_EVERY
            || self.replay.len() + 1 >= self.cfg.replay_capacity / 2
        {
            self.pump_nonblocking();
            self.sends_since_pump = 0;
        }
        self.wait_for_room()?;
        self.replay.push_back((seq, bytes));
        if self.next_seq <= seq {
            self.next_seq = seq + 1;
        }
        loop {
            if self.conn.is_none() {
                // establish replays the whole unacked tail — including the
                // frame just queued — so there is nothing left to write.
                let deadline = Instant::now() + self.connect_budget();
                self.establish_by(deadline)?;
                break;
            }
            let stream = self.conn.as_mut().unwrap();
            let buf = &self.replay.back().unwrap().1;
            match write_frame_bytes(stream, buf) {
                Ok(()) => break,
                Err(_) => self.conn = None, // loop → reconnect + replay
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Drain protocol: make sure every frame is delivered, send
    /// `FIN{next_seq}` and wait for `FIN_ACK`. After this the peer's
    /// `recv` has returned `Ok(None)` — a clean shutdown, observably
    /// different from a failure on both ends.
    pub fn finish(&mut self) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        let deadline = Instant::now() + self.cfg.drain_timeout;
        self.fin_acked = false;
        loop {
            anyhow::ensure!(
                Instant::now() < deadline,
                "drain of link to {} timed out after {:?} ({} frames unacked)",
                self.peer,
                self.cfg.drain_timeout,
                self.replay.len()
            );
            if self.conn.is_none() {
                self.establish_by(deadline)?;
            }
            if write_ctrl(self.conn.as_mut().unwrap(), K_FIN, self.next_seq).is_err() {
                self.conn = None;
                continue;
            }
            while !self.fin_acked && self.conn.is_some() && Instant::now() < deadline {
                self.pump_blocking(Duration::from_millis(20));
            }
            if self.fin_acked {
                self.finished = true;
                if let Some(s) = &self.conn {
                    let _ = s.shutdown(Shutdown::Both);
                }
                self.conn = None;
                return Ok(());
            }
            // Connection died mid-drain (or FIN_ACK hasn't arrived):
            // reconnect, replay the tail, re-FIN.
        }
    }

    /// Budget for (re)establishing: the first connection of a session is
    /// startup (order-independent, generous); later ones are outages.
    fn connect_budget(&self) -> Duration {
        if self.ever_connected {
            self.cfg.reconnect_timeout
        } else {
            self.cfg.initial_timeout.max(self.cfg.reconnect_timeout)
        }
    }

    /// Redial + handshake + replay, bounded by `deadline`.
    fn establish_by(&mut self, deadline: Instant) -> Result<()> {
        let was_connected = self.ever_connected;
        let t0 = Instant::now();
        self.conn = None;
        self.rdbuf.clear();
        let mut backoff = Backoff::new(
            self.cfg.backoff_base,
            self.cfg.backoff_max,
            self.cfg.jitter,
            self.cfg.seed ^ self.dials ^ self.nonce,
        );
        loop {
            self.dials += 1;
            let stream = connect_until(&self.peer, deadline, &mut backoff).map_err(|e| {
                anyhow::anyhow!(
                    "link to {} down: {e} ({} frames awaiting replay)",
                    self.peer,
                    self.replay.len()
                )
            })?;
            match self.handshake(stream, deadline) {
                Ok(()) => {
                    if was_connected {
                        self.stats
                            .reconnects
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        self.stats.stall_us.fetch_add(
                            t0.elapsed().as_micros() as u64,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                    }
                    self.ever_connected = true;
                    return Ok(());
                }
                Err(e) => {
                    // Handshake failures are transient (half-dead peer,
                    // stale backlog entry) — retry until the deadline,
                    // then surface the real reason.
                    if Instant::now() >= deadline {
                        return Err(e.context(format!(
                            "link to {} down: handshake kept failing",
                            self.peer
                        )));
                    }
                    std::thread::sleep(backoff.next_delay());
                }
            }
        }
    }

    /// On a fresh connection: read the receiver's `HELLO`, trim the
    /// replay buffer to its cumulative position, replay the tail.
    fn handshake(&mut self, mut stream: TcpStream, deadline: Instant) -> Result<()> {
        stream.set_nodelay(true).ok();
        let budget = self
            .cfg
            .hello_timeout
            .min(deadline.saturating_duration_since(Instant::now()))
            .max(Duration::from_millis(1));
        stream.set_read_timeout(Some(budget)).ok();
        let mut rec = [0u8; CTRL_LEN];
        stream
            .read_exact(&mut rec)
            .map_err(|e| anyhow::anyhow!("no HELLO from peer: {e}"))?;
        anyhow::ensure!(
            u32::from_le_bytes(rec[0..4].try_into().unwrap()) == CTRL_MARKER,
            "peer is not speaking the resilient protocol (bad HELLO marker)"
        );
        let (kind, next_expected) = parse_ctrl(&rec);
        anyhow::ensure!(kind == K_HELLO, "expected HELLO, got control kind {kind}");
        anyhow::ensure!(
            next_expected <= self.next_seq,
            "peer expects seq {next_expected} but only {} were ever sent",
            self.next_seq
        );
        while self.replay.front().map_or(false, |(q, _)| *q < next_expected) {
            self.replay.pop_front();
        }
        if let Some((front, _)) = self.replay.front() {
            // Contiguity means the trimmed buffer starts exactly where the
            // receiver resumes; anything else is an unrecoverable gap
            // (e.g. a peer that lost acknowledged state).
            anyhow::ensure!(
                *front == next_expected,
                "replay buffer cannot cover the receiver's position: have seq {front}, peer needs {next_expected}"
            );
        }
        self.acked = self.acked.max(next_expected);
        let mut replayed = 0u64;
        for (_, bytes) in &self.replay {
            write_frame_bytes(&mut stream, bytes)
                .map_err(|e| anyhow::anyhow!("replay write failed: {e}"))?;
            replayed += 1;
        }
        if self.ever_connected && replayed > 0 {
            self.stats
                .replayed
                .fetch_add(replayed, std::sync::atomic::Ordering::Relaxed);
        }
        stream.set_read_timeout(None).ok();
        self.kill.register(&stream);
        self.conn = Some(stream);
        Ok(())
    }

    /// Block until the replay buffer has room. A full buffer on a
    /// HEALTHY link is ordinary backpressure — exactly like a full
    /// kernel send buffer blocking `write` in plain-TCP mode — so it is
    /// never an error and never times out. Only a DEAD link is bounded:
    /// each re-establish gets the reconnect budget, and exhausting that
    /// is the hard error.
    fn wait_for_room(&mut self) -> Result<()> {
        while self.replay.len() >= self.cfg.replay_capacity {
            if self.conn.is_none() {
                // The handshake's HELLO doubles as a cumulative ack.
                let deadline = Instant::now() + self.cfg.reconnect_timeout;
                self.establish_by(deadline)?;
                continue;
            }
            self.pump_blocking(Duration::from_millis(20));
        }
        Ok(())
    }

    /// Read whatever control bytes are available without blocking.
    fn pump_nonblocking(&mut self) {
        let Some(stream) = &self.conn else { return };
        if stream.set_nonblocking(true).is_err() {
            self.conn = None;
            return;
        }
        let mut alive = true;
        let mut tmp = [0u8; 256];
        loop {
            match self.conn.as_mut().unwrap().read(&mut tmp) {
                Ok(0) => {
                    alive = false;
                    break;
                }
                Ok(n) => self.rdbuf.extend_from_slice(&tmp[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    alive = false;
                    break;
                }
            }
        }
        if alive {
            if let Some(s) = &self.conn {
                alive = s.set_nonblocking(false).is_ok();
            }
        }
        // Parse even when the connection died: an ack that arrived just
        // before the EOF still trims the replay buffer.
        let parsed = self.parse_ctrl_buf();
        if !alive || !parsed {
            self.conn = None;
        }
    }

    /// One blocking read (bounded by `timeout`) for control traffic.
    fn pump_blocking(&mut self, timeout: Duration) {
        let Some(stream) = &self.conn else { return };
        stream
            .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
            .ok();
        let mut tmp = [0u8; 256];
        let alive = match self.conn.as_mut().unwrap().read(&mut tmp) {
            Ok(0) => false,
            Ok(n) => {
                self.rdbuf.extend_from_slice(&tmp[..n]);
                true
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => true,
            Err(e) if e.kind() == ErrorKind::Interrupted => true,
            Err(_) => false,
        };
        let parsed = self.parse_ctrl_buf();
        if !alive || !parsed {
            self.conn = None;
        }
    }

    /// Consume complete control records; `false` ⇒ stream desynced.
    fn parse_ctrl_buf(&mut self) -> bool {
        let mut consumed = 0;
        while self.rdbuf.len() - consumed >= CTRL_LEN {
            let rec = &self.rdbuf[consumed..consumed + CTRL_LEN];
            if u32::from_le_bytes(rec[0..4].try_into().unwrap()) != CTRL_MARKER {
                return false;
            }
            let (kind, seq) = parse_ctrl(rec);
            consumed += CTRL_LEN;
            match kind {
                // A mid-stream HELLO can't happen, but as a cumulative
                // position it is safe to treat like an ack.
                K_ACK | K_HELLO => {
                    while self.replay.front().map_or(false, |(q, _)| *q < seq) {
                        self.replay.pop_front();
                    }
                    self.acked = self.acked.max(seq);
                }
                K_FIN_ACK => self.fin_acked = true,
                _ => {} // unknown kinds: ignore (forward compatibility)
            }
        }
        self.rdbuf.drain(..consumed);
        true
    }
}

impl Drop for ReconnectingTx {
    fn drop(&mut self) {
        // Without an explicit finish() the peer sees EOF-without-FIN and
        // treats it as the failure it is. Never block in drop.
        if let Some(s) = &self.conn {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

impl FrameTx for ReconnectingTx {
    fn send(&mut self, frame: Frame) -> Result<f64> {
        ReconnectingTx::send(self, frame)
    }

    fn kind(&self) -> &'static str {
        "tcp+resilient"
    }

    fn finish(&mut self) -> Result<()> {
        ReconnectingTx::finish(self)
    }

    fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        Some(self.stats.clone())
    }
}

// ---------------------------------------------------------------------------
// Receiver: ReconnectingRx
// ---------------------------------------------------------------------------

enum WireItem {
    Frame(Frame),
    Fin(u64),
}

/// Fault-tolerant receiver half. Keeps its listener so a failed peer can
/// come back; speaks `HELLO{next_expected}` on every (re)accept, acks
/// cumulatively, dedups replayed frames, and turns `FIN` into the clean
/// `Ok(None)` end-of-stream.
pub struct ReconnectingRx {
    listener: Arc<TcpListener>,
    cfg: ResilienceConfig,
    stats: Arc<ResilienceStats>,
    conn: Option<TcpStream>,
    frame_buf: Vec<u8>,
    next_expected: u64,
    /// Cumulative position last written as an `ACK` (or `HELLO`).
    last_acked: u64,
    /// Ack once per this many delivered frames. Derived as a quarter of
    /// `replay_capacity`, so with both ends on one config the sender's
    /// buffer can never fill before the next ack boundary is crossed —
    /// per-frame ack packets would be pure overhead (the scheme is
    /// cumulative and `HELLO` re-syncs any lost tail).
    ack_every: u64,
    done: bool,
    ever_connected: bool,
}

impl ReconnectingRx {
    /// Receiver that (re-)accepts peers on `listener`.
    pub fn accept_on(
        listener: Arc<TcpListener>,
        cfg: ResilienceConfig,
        stats: Arc<ResilienceStats>,
    ) -> Self {
        let ack_every = (cfg.replay_capacity as u64 / 4).max(1);
        ReconnectingRx {
            listener,
            cfg,
            stats,
            conn: None,
            frame_buf: Vec::new(),
            next_expected: 0,
            last_acked: 0,
            ack_every,
            done: false,
            ever_connected: false,
        }
    }

    pub fn stats(&self) -> Arc<ResilienceStats> {
        self.stats.clone()
    }

    /// Next in-order frame; `Ok(None)` only after the peer's `FIN` (clean
    /// drain). Link failures trigger re-accept + resync internally and
    /// only surface as `Err` once `reconnect_timeout` is exhausted.
    pub fn recv(&mut self) -> Result<Option<Frame>> {
        if self.done {
            return Ok(None);
        }
        loop {
            if self.conn.is_none() {
                self.accept_peer()?;
            }
            match self.read_item() {
                Err(()) => self.conn = None, // failure → re-accept + HELLO
                Ok(WireItem::Fin(end)) => {
                    anyhow::ensure!(
                        end == self.next_expected,
                        "peer finished at seq {end} but only {} frames were delivered: frames lost",
                        self.next_expected
                    );
                    match self.conn.as_mut().map(|s| write_ctrl(s, K_FIN_ACK, end)) {
                        Some(Ok(())) => {
                            self.done = true;
                            return Ok(None);
                        }
                        _ => {
                            // FIN_ACK visibly didn't go out: stay
                            // acceptable instead of vanishing, so the
                            // sender's reconnect + re-FIN finds us and the
                            // drain completes (everything is delivered;
                            // only the acknowledgement is missing).
                            self.conn = None;
                        }
                    }
                }
                Ok(WireItem::Frame(f)) => {
                    if f.seq < self.next_expected {
                        // Replayed frame we already delivered: drop it and
                        // re-ack immediately so the sender resyncs.
                        self.stats
                            .deduped
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        self.ack(true);
                        continue;
                    }
                    anyhow::ensure!(
                        f.seq == self.next_expected,
                        "sequence gap: got frame {}, expected {} (peer could not replay the tail)",
                        f.seq,
                        self.next_expected
                    );
                    self.next_expected += 1;
                    self.ack(false);
                    return Ok(Some(f));
                }
            }
        }
    }

    /// Write a cumulative `ACK` — on every ack-batch boundary, or
    /// unconditionally when `force`d (dedup resync).
    fn ack(&mut self, force: bool) {
        if !force && self.next_expected.saturating_sub(self.last_acked) < self.ack_every {
            return;
        }
        if let Some(s) = self.conn.as_mut() {
            if write_ctrl(s, K_ACK, self.next_expected).is_ok() {
                self.last_acked = self.next_expected;
            } else {
                // Frame is already delivered; the lost ack is recovered by
                // the next connection's HELLO.
                self.conn = None;
            }
        }
    }

    /// Wait (bounded) for the peer to (re)connect, then greet it with our
    /// resume position.
    fn accept_peer(&mut self) -> Result<()> {
        let was_connected = self.ever_connected;
        let t0 = Instant::now();
        // First accept of the session = startup (peers may launch in any
        // order, as generous as the plain connect retry); later ones are
        // outage recovery.
        let budget = if was_connected {
            self.cfg.reconnect_timeout
        } else {
            self.cfg.initial_timeout.max(self.cfg.reconnect_timeout)
        };
        let deadline = t0 + budget;
        self.listener.set_nonblocking(true).ok();
        let result = loop {
            match self.listener.accept() {
                Ok((mut stream, _)) => {
                    stream.set_nodelay(true).ok();
                    if write_ctrl(&mut stream, K_HELLO, self.next_expected).is_err() {
                        continue; // stale backlog entry; try the next one
                    }
                    break Ok(stream);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        let what = if was_connected {
                            "peer did not reconnect"
                        } else {
                            "no peer connected"
                        };
                        break Err(anyhow::anyhow!(
                            "{what} within {budget:?} (listening on {})",
                            self.listener
                                .local_addr()
                                .map(|a| a.to_string())
                                .unwrap_or_else(|_| "?".into())
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => break Err(anyhow::anyhow!("listener failed: {e}")),
            }
        };
        self.listener.set_nonblocking(false).ok();
        let stream = result?;
        if was_connected {
            // Re-accepts count separately from the dialer's reconnects:
            // a loopback link shares one stats block between both ends,
            // and one outage must not read as two. Stall is charged on
            // the dialing side only (the two waits overlap).
            self.stats
                .reaccepts
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        // The HELLO just written is a cumulative ack.
        self.last_acked = self.next_expected;
        self.ever_connected = true;
        self.conn = Some(stream);
        Ok(())
    }

    /// Next wire item from the current connection. `Err(())` covers every
    /// link-level problem — I/O error, EOF (which without FIN is always a
    /// failure), desynced or corrupt stream — all cured by reconnecting:
    /// unacked frames replay, so skipping nothing is safe.
    fn read_item(&mut self) -> std::result::Result<WireItem, ()> {
        loop {
            let stream = self.conn.as_mut().ok_or(())?;
            let mut pre = [0u8; 4];
            stream.read_exact(&mut pre).map_err(|_| ())?;
            let len = u32::from_le_bytes(pre);
            if len == CTRL_MARKER {
                let mut rest = [0u8; CTRL_LEN - 4];
                stream.read_exact(&mut rest).map_err(|_| ())?;
                let kind = rest[0];
                let seq = u64::from_le_bytes(rest[1..9].try_into().unwrap());
                match kind {
                    K_FIN => return Ok(WireItem::Fin(seq)),
                    _ => continue, // not meaningful inbound; skip
                }
            }
            let len = len as usize;
            if len > MAX_FRAME_BYTES {
                return Err(()); // desynced stream; reconnect resyncs
            }
            self.frame_buf.resize(len, 0);
            let stream = self.conn.as_mut().ok_or(())?;
            stream.read_exact(&mut self.frame_buf).map_err(|_| ())?;
            return match Frame::from_bytes(&self.frame_buf) {
                Ok(f) => Ok(WireItem::Frame(f)),
                // Corrupt frame: unlike the plain receiver we must not
                // skip it (that would be loss) — reconnect and let the
                // sender replay it.
                Err(_) => Err(()),
            };
        }
    }
}

impl Drop for ReconnectingRx {
    fn drop(&mut self) {
        if let Some(s) = &self.conn {
            let _ = s.shutdown(Shutdown::Both);
        }
    }
}

impl FrameRx for ReconnectingRx {
    fn recv(&mut self) -> Result<Option<Frame>> {
        ReconnectingRx::recv(self)
    }

    fn kind(&self) -> &'static str {
        "tcp+resilient"
    }

    fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        Some(self.stats.clone())
    }
}

/// A resilient loopback boundary sharing one stats block: the Tx dials
/// the Rx's kept listener. Endpoints connect lazily on first use.
pub fn resilient_loopback_pair(
    cfg: &ResilienceConfig,
) -> Result<(ReconnectingTx, ReconnectingRx)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stats = Arc::new(ResilienceStats::default());
    let rx = ReconnectingRx::accept_on(Arc::new(listener), cfg.clone(), stats.clone());
    let tx = ReconnectingTx::connect_to(addr, cfg.clone(), stats);
    Ok((tx, rx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codec::Codec;
    use crate::quant::Method;

    fn fast_cfg() -> ResilienceConfig {
        ResilienceConfig {
            replay_capacity: 16,
            reconnect_timeout: Duration::from_secs(5),
            initial_timeout: Duration::from_secs(5),
            backoff_base: Duration::from_millis(2),
            backoff_max: Duration::from_millis(20),
            jitter: 0.5,
            hello_timeout: Duration::from_millis(500),
            drain_timeout: Duration::from_secs(5),
            seed: 1,
        }
    }

    fn frame(seq: u64, n: usize) -> Frame {
        let x: Vec<f32> = (0..n).map(|i| ((i + seq as usize) as f32).sin()).collect();
        let mut c = Codec::default();
        Frame::new(seq, vec![n], c.encode(&x, Method::Pda, 8).unwrap())
    }

    /// Read one length-prefixed frame off a raw socket (scripted peers).
    fn raw_read_frame(s: &mut TcpStream) -> Frame {
        let mut pre = [0u8; 4];
        s.read_exact(&mut pre).unwrap();
        let len = u32::from_le_bytes(pre) as usize;
        assert_ne!(len, CTRL_MARKER as usize, "expected a data frame, got a control record");
        let mut buf = vec![0u8; len];
        s.read_exact(&mut buf).unwrap();
        Frame::from_bytes(&buf).unwrap()
    }

    /// Read one control record off a raw socket.
    fn raw_read_ctrl(s: &mut TcpStream) -> (u8, u64) {
        let mut rec = [0u8; CTRL_LEN];
        s.read_exact(&mut rec).unwrap();
        assert_eq!(u32::from_le_bytes(rec[0..4].try_into().unwrap()), CTRL_MARKER);
        parse_ctrl(&rec)
    }

    #[test]
    fn replay_buffer_trims_on_ack() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let peer = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            write_ctrl(&mut s, K_HELLO, 0).unwrap();
            for want in 0..4u64 {
                assert_eq!(raw_read_frame(&mut s).seq, want);
            }
            // Cumulative ack: frames 0 and 1 are delivered.
            write_ctrl(&mut s, K_ACK, 2).unwrap();
            s // keep the socket open until the test is done
        });
        let mut tx = ReconnectingTx::connect_to(addr, fast_cfg(), Default::default());
        for seq in 0..4 {
            tx.send(frame(seq, 32)).unwrap();
        }
        assert_eq!(tx.unacked(), 4);
        let deadline = Instant::now() + Duration::from_secs(5);
        while tx.unacked() != 2 && Instant::now() < deadline {
            tx.pump();
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(tx.unacked(), 2, "ACK{{2}} must trim exactly seqs 0 and 1");
        let _s = peer.join().unwrap();
    }

    #[test]
    fn receiver_dedups_replayed_frames_and_acks_cumulatively() {
        let listener = Arc::new(TcpListener::bind("127.0.0.1:0").unwrap());
        let addr = listener.local_addr().unwrap().to_string();
        let stats = Arc::new(ResilienceStats::default());
        let mut rx = ReconnectingRx::accept_on(listener, fast_cfg(), stats.clone());
        let peer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            assert_eq!(raw_read_ctrl(&mut s), (K_HELLO, 0));
            write_frame_bytes(&mut s, &frame(0, 32).to_bytes()).unwrap();
            write_frame_bytes(&mut s, &frame(0, 32).to_bytes()).unwrap(); // duplicate
            write_frame_bytes(&mut s, &frame(1, 32).to_bytes()).unwrap();
            write_ctrl(&mut s, K_FIN, 2).unwrap();
            // Drain acks until FIN_ACK confirms the clean shutdown.
            loop {
                let (kind, seq) = raw_read_ctrl(&mut s);
                if kind == K_FIN_ACK {
                    assert_eq!(seq, 2);
                    break;
                }
                assert_eq!(kind, K_ACK);
            }
        });
        assert_eq!(rx.recv().unwrap().unwrap().seq, 0);
        assert_eq!(rx.recv().unwrap().unwrap().seq, 1);
        assert!(rx.recv().unwrap().is_none(), "FIN must be a clean end of stream");
        assert!(rx.recv().unwrap().is_none(), "recv after FIN stays clean");
        assert_eq!(stats.deduped.load(std::sync::atomic::Ordering::Relaxed), 1);
        peer.join().unwrap();
    }

    #[test]
    fn reconnect_replays_exactly_the_unacked_tail() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let peer = std::thread::spawn(move || {
            // Connection 1: greet, read 2 frames, ack only the first, die.
            let (mut s, _) = listener.accept().unwrap();
            write_ctrl(&mut s, K_HELLO, 0).unwrap();
            assert_eq!(raw_read_frame(&mut s).seq, 0);
            assert_eq!(raw_read_frame(&mut s).seq, 1);
            write_ctrl(&mut s, K_ACK, 1).unwrap();
            std::thread::sleep(Duration::from_millis(30)); // let the ack land
            drop(s);
            // Connection 2: resume from seq 1; the sender must replay 1, 2.
            let (mut s, _) = listener.accept().unwrap();
            write_ctrl(&mut s, K_HELLO, 1).unwrap();
            assert_eq!(raw_read_frame(&mut s).seq, 1);
            assert_eq!(raw_read_frame(&mut s).seq, 2);
            write_ctrl(&mut s, K_ACK, 3).unwrap();
            let (kind, seq) = raw_read_ctrl(&mut s);
            assert_eq!((kind, seq), (K_FIN, 3));
            write_ctrl(&mut s, K_FIN_ACK, 3).unwrap();
        });
        let stats = Arc::new(ResilienceStats::default());
        let mut tx = ReconnectingTx::connect_to(addr, fast_cfg(), stats.clone());
        tx.send(frame(0, 32)).unwrap();
        tx.send(frame(1, 32)).unwrap();
        std::thread::sleep(Duration::from_millis(60)); // peer drops the link
        // This send hits the dead socket (possibly absorbing one buffered
        // write first), reconnects, and replays per the new HELLO.
        tx.send(frame(2, 32)).unwrap();
        tx.finish().unwrap();
        assert_eq!(stats.reconnects.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert!(
            stats.replayed.load(std::sync::atomic::Ordering::Relaxed) >= 1,
            "the unacked tail must be replayed"
        );
        assert_eq!(tx.unacked(), 0);
        peer.join().unwrap();
    }

    #[test]
    fn clean_fin_drain_end_to_end() {
        let (mut tx, mut rx) = resilient_loopback_pair(&fast_cfg()).unwrap();
        let stats = tx.stats();
        let sender = std::thread::spawn(move || {
            for seq in 0..5 {
                tx.send(frame(seq, 64)).unwrap();
            }
            tx.finish().unwrap();
        });
        for want in 0..5u64 {
            assert_eq!(rx.recv().unwrap().unwrap().seq, want);
        }
        assert!(rx.recv().unwrap().is_none());
        sender.join().unwrap();
        assert_eq!(stats.reconnects.load(std::sync::atomic::Ordering::Relaxed), 0);
        assert_eq!(stats.deduped.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn kill_mid_stream_recovers_without_loss_or_duplication() {
        let (mut tx, mut rx) = resilient_loopback_pair(&fast_cfg()).unwrap();
        let stats = tx.stats();
        let kill = tx.kill_switch();
        let total = 50u64;
        let sender = std::thread::spawn(move || {
            for seq in 0..total {
                tx.send(frame(seq, 256)).unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
            tx.finish().unwrap();
        });
        let mut got = Vec::new();
        for i in 0..total {
            if i == 10 {
                assert!(kill.kill(), "link must be active by frame 10");
            }
            got.push(rx.recv().unwrap().unwrap().seq);
        }
        assert!(rx.recv().unwrap().is_none());
        sender.join().unwrap();
        assert_eq!(got, (0..total).collect::<Vec<_>>(), "loss or duplication after reconnect");
        assert!(
            stats.reconnects.load(std::sync::atomic::Ordering::Relaxed) >= 1,
            "the kill must have forced a reconnect"
        );
    }

    #[test]
    fn sender_errors_once_reconnect_budget_exhausted() {
        // Nothing ever listens on this freshly-bound-then-dropped port.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut cfg = fast_cfg();
        cfg.reconnect_timeout = Duration::from_millis(80);
        cfg.initial_timeout = Duration::from_millis(80);
        let mut tx = ReconnectingTx::connect_to(addr, cfg, Default::default());
        let err = tx.send(frame(0, 16)).unwrap_err();
        assert!(err.to_string().contains("down"), "{err:#}");
    }

    #[test]
    fn receiver_errors_once_reconnect_budget_exhausted() {
        let listener = Arc::new(TcpListener::bind("127.0.0.1:0").unwrap());
        let mut cfg = fast_cfg();
        cfg.reconnect_timeout = Duration::from_millis(80);
        cfg.initial_timeout = Duration::from_millis(80);
        let mut rx = ReconnectingRx::accept_on(listener, cfg, Default::default());
        let err = rx.recv().unwrap_err();
        assert!(err.to_string().contains("no peer connected"), "{err:#}");
    }
}
