//! Transports between pipeline stages.
//!
//! Stages are OS threads (PJRT is thread-pinned), so transports are
//! blocking. Two implementations share one pair of traits:
//!
//! * [`InProcSender`]/[`InProcReceiver`] — a bounded `sync_channel` of
//!   serialized frames behind a bandwidth-shaped [`SimLink`] (single host,
//!   the measurement substrate);
//! * [`super::tcp::TcpFrameSender`]/[`super::tcp::TcpFrameReceiver`] —
//!   real sockets (multi-process mode), where the bandwidth signal is the
//!   measured write-stall time under kernel backpressure.
//!
//! The [`FrameTx`]/[`FrameRx`] traits are what the pipeline driver, the
//! `WindowMonitor` feed and the worker endpoints program against, so the
//! adaptive control loop is identical over either substrate. Serializing
//! through bytes keeps semantics identical across both — including CRC
//! validation on receive.
//!
//! The bounded channel is the in-proc pipeline's in-flight cap
//! (GPipe-style microbatch backpressure): a full channel blocks the
//! upstream sender. In TCP mode the kernel socket buffers play that role.

use super::frame::Frame;
use super::link::SimLink;
use super::resilient::{resilient_loopback_pair, ReconnectingRx, ReconnectingTx, ResilienceConfig};
use super::stripe::{striped_loopback_pair, StripedRx, StripedTx};
use super::tcp::{TcpFrameReceiver, TcpFrameSender};
use crate::metrics::{ResilienceStats, StripeStats};
use crate::Result;
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Duration;

/// A frame already serialized to its wire bytes by the producing stage.
///
/// This is the unit of the copy-free handoff path: the stage loop
/// serializes **once** (via [`Frame::write_into`] into a recycled
/// buffer), and the same `Vec<u8>` then travels through the boundary
/// channel, the sender thread, the transport's replay buffer and the
/// socket write without being copied again. `seq` mirrors the sequence
/// number already encoded in `wire` so bookkeeping (in-flight counters,
/// replay keys) never needs to re-parse the bytes.
pub struct PreparedFrame {
    /// Data-plane sequence number, identical to the one inside `wire`.
    pub seq: u64,
    /// The complete serialized frame (header + payload + CRC).
    pub wire: Vec<u8>,
}

/// Blocking sender half of a stage-to-stage transport.
///
/// `send` returns the seconds the underlying link was busy shipping the
/// frame — serialization time on a shaped [`SimLink`], write-stall time on
/// a real socket, reconnect stall on a resilient link. That number feeds
/// the `WindowMonitor`, so "measured output bandwidth" means the same
/// thing on every transport.
pub trait FrameTx: Send {
    /// Ship one frame; returns seconds the link was busy (see trait docs).
    fn send(&mut self, frame: Frame) -> Result<f64>;
    /// Ship a frame the caller already serialized ([`PreparedFrame`]).
    /// Transports that keep frames as bytes internally (TCP, resilient,
    /// striped, in-proc) override this to move the buffer straight through
    /// with zero copies; the default re-parses and falls back to [`send`]
    /// so simple test transports keep working unchanged.
    ///
    /// [`send`]: FrameTx::send
    fn send_prepared(&mut self, prepared: PreparedFrame) -> Result<f64> {
        self.send(Frame::from_bytes(&prepared.wire)?)
    }
    /// Hand back a spare wire buffer the transport no longer needs (an
    /// acked replay-buffer entry, a written-out frame), so the producing
    /// stage can reuse it for the next [`PreparedFrame`] instead of
    /// allocating. `None` when nothing is available; the default (for
    /// transports without buffer pooling) is always `None`.
    fn reclaim_wire(&mut self) -> Option<Vec<u8>> {
        None
    }
    /// Transport name for logs/reports.
    fn kind(&self) -> &'static str;
    /// Negotiate a clean end of stream after the last frame. Resilient
    /// links run their FIN/FIN_ACK drain here so the peer can tell
    /// shutdown from failure; other transports close on drop.
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
    /// Live reconnect/replay counters, when the transport has them.
    fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        None
    }
    /// Live per-stripe counters, when the boundary is striped across
    /// multiple connections ([`super::stripe`]).
    fn stripes(&self) -> Option<Vec<Arc<StripeStats>>> {
        None
    }
    /// Ship one opaque telemetry record forward along the data path
    /// (see [`crate::metrics::telemetry`]). **Best effort**: telemetry
    /// never enters the replay buffer, never consumes a data-plane
    /// sequence number and never delays an ACK — a record on a dying
    /// connection may simply be lost, and transports without a telemetry
    /// channel (the in-process `SimLink` path) drop it silently; the
    /// snapshot format is built to tolerate both. `Err` is reserved for
    /// payloads that could never be sent (oversized).
    fn send_telemetry(&mut self, _payload: &[u8]) -> Result<()> {
        Ok(())
    }
}

/// Blocking receiver half of a stage-to-stage transport.
pub trait FrameRx: Send {
    /// Next frame, in order. `Ok(None)` = clean end of stream (the peer
    /// finished and closed); `Err` = transport failure (I/O error, stream
    /// truncated mid-frame, corrupt length prefix) that the driver should
    /// report rather than treat as a quiet shutdown.
    fn recv(&mut self) -> Result<Option<Frame>>;
    /// Transport name for logs/reports.
    fn kind(&self) -> &'static str;
    /// Live reconnect/dedup counters, when the transport has them.
    fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        None
    }
    /// Telemetry payloads that arrived interleaved with the data stream
    /// since the last poll (empty on transports without a telemetry
    /// channel). Drain this alongside `recv` — records accumulate as
    /// frames are read and are handed over in arrival order.
    fn poll_telemetry(&mut self) -> Vec<Vec<u8>> {
        Vec::new()
    }
}

/// One stage boundary of a [`crate::pipeline::PipelineSpec`]: how frames
/// travel from stage `i`'s sender thread to stage `i+1`'s input.
pub enum LinkSpec {
    /// Bandwidth-shaped in-process channel (simulation substrate).
    Sim(Arc<SimLink>),
    /// Pre-connected real TCP endpoints: the sender thread writes `tx`,
    /// the downstream stage reads `rx` (the accepted peer of `tx`).
    Tcp(TcpFrameSender, TcpFrameReceiver),
    /// Fault-tolerant TCP endpoints ([`super::resilient`]): same socket
    /// substrate, but the boundary survives transient link failures via
    /// reconnect + sequenced replay, and shuts down through an explicit
    /// FIN/FIN_ACK drain.
    ResilientTcp(ReconnectingTx, ReconnectingRx),
    /// Striped fault-tolerant boundary ([`super::stripe`]): one
    /// reliability session fanned over N TCP connections, the receiver
    /// reordering through the shared sequence space. For high-BDP or
    /// multi-path edge links where a single connection leaves bandwidth
    /// on the table; losing one stripe reads as partial bandwidth
    /// collapse, not an outage.
    Striped(StripedTx, StripedRx),
}

impl LinkSpec {
    /// Shaped in-process boundary.
    pub fn sim(link: Arc<SimLink>) -> Self {
        LinkSpec::Sim(link)
    }

    /// Unshaped in-process boundary.
    pub fn unlimited() -> Self {
        LinkSpec::Sim(Arc::new(SimLink::unlimited()))
    }

    /// Real-socket boundary over localhost (single-process deployments of
    /// the TCP path: tests, demos). Multi-process deployments build their
    /// endpoints from `tcp::connect`/`tcp::accept_one` instead.
    pub fn tcp_loopback() -> Result<Self> {
        let ((tx, _a_rx), (_b_tx, rx)) = super::tcp::loopback_pair()?;
        Ok(LinkSpec::Tcp(tx, rx))
    }

    /// Fault-tolerant real-socket boundary over localhost: the receiver
    /// keeps its listener, so the link survives mid-stream connection
    /// kills. Multi-process deployments build their endpoints from
    /// `ReconnectingTx::connect_to` / `ReconnectingRx::accept_on`.
    pub fn tcp_loopback_resilient(cfg: ResilienceConfig) -> Result<Self> {
        let (tx, rx) = resilient_loopback_pair(&cfg)?;
        Ok(LinkSpec::ResilientTcp(tx, rx))
    }

    /// Striped fault-tolerant boundary over localhost: `stripes`
    /// connections to one kept listener, one shared sequence space.
    /// Multi-process deployments build their endpoints from
    /// `StripedTx::connect_to` / `StripedRx::accept_on`.
    pub fn tcp_loopback_striped(stripes: usize, cfg: ResilienceConfig) -> Result<Self> {
        let (tx, rx) = striped_loopback_pair(stripes, &cfg)?;
        Ok(LinkSpec::Striped(tx, rx))
    }

    /// The link's resilience counters, when it has any (shared by both
    /// loopback endpoints; snapshot them after the run for the report).
    pub fn resilience(&self) -> Option<Arc<ResilienceStats>> {
        match self {
            LinkSpec::ResilientTcp(tx, _) => Some(tx.stats()),
            LinkSpec::Striped(tx, _) => Some(tx.stats()),
            _ => None,
        }
    }

    /// The link's live per-stripe counters, when it is striped.
    pub fn stripe_stats(&self) -> Option<Vec<Arc<StripeStats>>> {
        match self {
            LinkSpec::Striped(tx, _) => Some(tx.stripe_stats()),
            _ => None,
        }
    }

    /// Attach chaos-lab shapers to a striped boundary (one slot per
    /// stripe; see [`super::scenario::ScenarioKind::build`]). Returns
    /// whether the link could take them — only [`LinkSpec::Striped`]
    /// has a shaped write path; every other variant ignores the call
    /// and reports `false` so callers can be loud about it.
    pub fn set_stripe_shapers(
        &mut self,
        shapers: Vec<Option<Arc<super::shaper::LinkShaper>>>,
    ) -> bool {
        match self {
            LinkSpec::Striped(tx, _) => {
                tx.set_shapers(shapers);
                true
            }
            _ => false,
        }
    }

    /// Split into boxed trait endpoints. `depth` bounds in-flight frames
    /// for the in-proc channel (TCP relies on socket buffers).
    pub fn into_endpoints(self, depth: usize) -> (Box<dyn FrameTx>, Box<dyn FrameRx>) {
        match self {
            LinkSpec::Sim(link) => {
                let (tx, rx) = inproc_pair(link, depth);
                (Box::new(tx), Box::new(rx))
            }
            LinkSpec::Tcp(tx, rx) => (Box::new(tx), Box::new(rx)),
            LinkSpec::ResilientTcp(tx, rx) => (Box::new(tx), Box::new(rx)),
            LinkSpec::Striped(tx, rx) => (Box::new(tx), Box::new(rx)),
        }
    }
}

/// Sender half of an in-process shaped link.
pub struct InProcSender {
    link: Arc<SimLink>,
    tx: SyncSender<Vec<u8>>,
}

/// Receiver half.
pub struct InProcReceiver {
    rx: Receiver<Vec<u8>>,
}

/// Create a connected pair. `depth` bounds in-flight frames.
pub fn inproc_pair(link: Arc<SimLink>, depth: usize) -> (InProcSender, InProcReceiver) {
    let (tx, rx) = std::sync::mpsc::sync_channel(depth.max(1));
    (InProcSender { link, tx }, InProcReceiver { rx })
}

impl InProcSender {
    /// Ship one frame: blocks for the shaped serialization time, then for
    /// channel space. Returns seconds the link was occupied.
    pub fn send(&self, frame: Frame) -> Result<f64> {
        let bytes = frame.to_bytes();
        let occupied = self.link.send(bytes.len());
        self.tx
            .send(bytes)
            .map_err(|_| anyhow::anyhow!("receiver dropped"))?;
        Ok(occupied.as_secs_f64())
    }
}

impl FrameTx for InProcSender {
    fn send(&mut self, frame: Frame) -> Result<f64> {
        InProcSender::send(self, frame)
    }

    fn send_prepared(&mut self, prepared: PreparedFrame) -> Result<f64> {
        // Already serialized: charge the shaped link for the bytes and move
        // the buffer into the channel without re-encoding.
        let occupied = self.link.send(prepared.wire.len());
        self.tx
            .send(prepared.wire)
            .map_err(|_| anyhow::anyhow!("receiver dropped"))?;
        Ok(occupied.as_secs_f64())
    }

    fn kind(&self) -> &'static str {
        "inproc"
    }
}

impl InProcReceiver {
    /// Next frame, in order. `None` = channel closed. Frames failing CRC
    /// are skipped (loss injection models retransmission delay upstream;
    /// CRC failures here are test-injected corruption).
    pub fn recv(&mut self) -> Option<Frame> {
        loop {
            let bytes = self.rx.recv().ok()?;
            match Frame::from_bytes(&bytes) {
                Ok(f) => return Some(f),
                Err(_) => continue,
            }
        }
    }

    /// Receive with a timeout (used by shutdown paths).
    pub fn recv_timeout(&mut self, d: Duration) -> std::result::Result<Option<Frame>, ()> {
        loop {
            match self.rx.recv_timeout(d) {
                Ok(bytes) => match Frame::from_bytes(&bytes) {
                    Ok(f) => return Ok(Some(f)),
                    Err(_) => continue,
                },
                Err(RecvTimeoutError::Timeout) => return Err(()),
                Err(RecvTimeoutError::Disconnected) => return Ok(None),
            }
        }
    }
}

impl FrameRx for InProcReceiver {
    fn recv(&mut self) -> Result<Option<Frame>> {
        // A closed channel is always a clean shutdown in-process; transport
        // failures don't exist on a sync_channel.
        Ok(InProcReceiver::recv(self))
    }

    fn kind(&self) -> &'static str {
        "inproc"
    }
}

/// Expose try-send saturation for tests.
pub fn try_send_raw(tx: &SyncSender<Vec<u8>>, bytes: Vec<u8>) -> std::result::Result<(), TrySendError<Vec<u8>>> {
    tx.try_send(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mbps;
    use crate::net::trace::BandwidthTrace;
    use crate::quant::codec::Codec;
    use crate::quant::Method;

    fn frame(seq: u64) -> Frame {
        let x: Vec<f32> = (0..128).map(|i| (i as f32 + seq as f32).sin()).collect();
        let mut c = Codec::default();
        Frame::new(seq, vec![128], c.encode(&x, Method::Aciq, 8).unwrap())
    }

    #[test]
    fn frames_arrive_in_order() {
        let link = Arc::new(SimLink::unlimited());
        let (tx, mut rx) = inproc_pair(link, 4);
        let sender = std::thread::spawn(move || {
            for seq in 0..8u64 {
                tx.send(frame(seq)).unwrap();
            }
        });
        for seq in 0..8u64 {
            assert_eq!(rx.recv().unwrap().seq, seq);
        }
        sender.join().unwrap();
        assert!(rx.recv().is_none());
    }

    #[test]
    fn shaped_send_takes_time() {
        // ~616-byte frame over 0.1 Mbps ≈ 49 ms. Only lower bounds are
        // tight here: on a loaded machine the elapsed time and the
        // occupancy measurement can only inflate, so the upper tolerance
        // is deliberately loose (this test used to flake under load).
        let link = Arc::new(SimLink::new(BandwidthTrace::constant(mbps(0.1))));
        let (tx, rx) = inproc_pair(link, 4);
        let f = frame(0);
        let bytes = f.wire_len();
        let t0 = std::time::Instant::now();
        let r = std::thread::spawn(move || {
            let mut rx = rx;
            rx.recv()
        });
        let occ = tx.send(f).unwrap();
        assert!(r.join().unwrap().is_some());
        let expect = bytes as f64 * 8.0 / 0.1e6;
        assert!(occ >= expect * 0.6, "occ={occ} expect={expect}");
        assert!(occ <= expect * 10.0, "occ={occ} expect={expect}");
        assert!(t0.elapsed().as_secs_f64() >= expect * 0.6);
    }

    #[test]
    fn bounded_channel_backpressures() {
        let link = Arc::new(SimLink::unlimited());
        let (tx, mut rx) = inproc_pair(link, 2);
        tx.send(frame(0)).unwrap();
        tx.send(frame(1)).unwrap();
        // 3rd raw try_send must fail (channel full).
        assert!(try_send_raw(&tx.tx, frame(2).to_bytes()).is_err());
        rx.recv().unwrap();
        assert!(try_send_raw(&tx.tx, frame(2).to_bytes()).is_ok());
    }

    #[test]
    fn closed_receiver_errors() {
        let link = Arc::new(SimLink::unlimited());
        let (tx, rx) = inproc_pair(link, 1);
        drop(rx);
        assert!(tx.send(frame(0)).is_err());
    }

    #[test]
    fn corrupt_frames_skipped() {
        let link = Arc::new(SimLink::unlimited());
        let (tx, mut rx) = inproc_pair(link, 4);
        let mut bad = frame(0).to_bytes();
        let n = bad.len();
        bad[n - 1] ^= 0xff;
        try_send_raw(&tx.tx, bad).unwrap();
        tx.send(frame(1)).unwrap();
        // The corrupt frame is skipped; the next valid one arrives.
        assert_eq!(rx.recv().unwrap().seq, 1);
    }

    #[test]
    fn recv_timeout_paths() {
        let link = Arc::new(SimLink::unlimited());
        let (tx, mut rx) = inproc_pair(link, 1);
        assert!(rx.recv_timeout(Duration::from_millis(10)).is_err()); // timeout
        tx.send(frame(5)).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)).unwrap().unwrap().seq, 5);
        drop(tx);
        assert!(rx.recv_timeout(Duration::from_millis(10)).unwrap().is_none()); // closed
    }

    #[test]
    fn trait_objects_cover_all_transports() {
        // The same driver-side code must run over any substrate.
        fn ship(mut tx: Box<dyn FrameTx>, mut rx: Box<dyn FrameRx>, n: u64) {
            let sender = std::thread::spawn(move || {
                for seq in 0..n {
                    tx.send(frame(seq)).unwrap();
                }
                tx.finish().unwrap(); // no-op except on resilient links
            });
            for seq in 0..n {
                assert_eq!(rx.recv().unwrap().unwrap().seq, seq);
            }
            // Read the end-of-stream FIRST: on a resilient link this is
            // what acks the sender's FIN and lets its drain return.
            assert!(rx.recv().unwrap().is_none());
            sender.join().unwrap();
        }
        let (tx, rx) = LinkSpec::unlimited().into_endpoints(4);
        assert_eq!(tx.kind(), "inproc");
        assert!(tx.resilience().is_none());
        ship(tx, rx, 6);
        let (tx, rx) = LinkSpec::tcp_loopback().unwrap().into_endpoints(4);
        assert_eq!(tx.kind(), "tcp");
        ship(tx, rx, 6);
        let spec = LinkSpec::tcp_loopback_resilient(ResilienceConfig::default()).unwrap();
        let stats = spec.resilience().expect("resilient link exposes stats");
        assert!(spec.stripe_stats().is_none(), "single-conduit link is not striped");
        let (tx, rx) = spec.into_endpoints(4);
        assert_eq!(tx.kind(), "tcp+resilient");
        assert!(tx.resilience().is_some());
        assert!(tx.stripes().is_none());
        ship(tx, rx, 6);
        assert_eq!(stats.snapshot().reconnects, 0, "clean run must not reconnect");
        let spec = LinkSpec::tcp_loopback_striped(3, ResilienceConfig::default()).unwrap();
        let stats = spec.resilience().expect("striped link exposes stats");
        let per_stripe = spec.stripe_stats().expect("striped link exposes stripe counters");
        assert_eq!(per_stripe.len(), 3);
        let (tx, rx) = spec.into_endpoints(4);
        assert_eq!(tx.kind(), "tcp+striped");
        assert!(tx.stripes().is_some());
        ship(tx, rx, 6);
        assert_eq!(stats.snapshot().reconnects, 0, "clean striped run must not reconnect");
        // >= rather than ==: a transient reconnect would add replays,
        // which also count as carried wire traffic.
        let carried: u64 = per_stripe
            .iter()
            .map(|s| s.frames.load(std::sync::atomic::Ordering::Relaxed))
            .sum();
        assert!(carried >= 6, "every frame must be carried by some stripe: {carried}");
    }
}
