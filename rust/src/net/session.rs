//! The reliability **session layer**: every protocol decision of the
//! fault-tolerant link, with *no socket types in scope*.
//!
//! [`SessionTx`]/[`SessionRx`] own the shared sequence space of one stage
//! boundary — the bounded replay buffer, cumulative-ACK trimming, the
//! `HELLO{next_expected}` resync contract, the receive-side dedup/reorder
//! window and the FIN/FIN_ACK drain handshake. They operate purely on
//! frames and 13-byte control records; the **conduit layer**
//! ([`super::conduit`]) moves those bytes over real connections, and the
//! boundary glue ([`super::stripe`], [`super::resilient`]) decides *which*
//! connection carries *which* record.
//!
//! Because a session is independent of its conduits, one session can span
//! N of them (connection striping): every conduit that (re)appears is
//! greeted with the same cumulative `HELLO`, replays from the same
//! buffer, and feeds the same reorder window — losing a conduit is a
//! resync, never a new sequence space.
//!
//! Wire format (unchanged from the pre-split resilient layer): data
//! frames are length-prefixed (`u32 LE || frame bytes`); control records
//! use the impossible length prefix `u32::MAX` as a marker:
//!
//! ```text
//! marker u32 = 0xFFFF_FFFF | kind u8 | seq u64 LE      (13 bytes)
//! kind: 1 HELLO{next_expected}  receiver → sender, on every (re)connect
//!       2 ACK{next_expected}    receiver → sender, cumulative
//!       3 FIN{end_seq}          sender → receiver, after the last frame
//!       4 FIN_ACK{end_seq}      receiver → sender, everything received
//!       5 TELEMETRY{len}        sender → receiver, `len` payload bytes
//!                               follow the 13-byte header
//!       6 HAVE{seq}             receiver → sender, advisory selective
//!                               ack: `seq` is already parked in the
//!                               reorder window, skip it on replay
//! ```
//!
//! TELEMETRY is the one variable-length record: its `seq` field carries
//! the payload length (bounded by [`MAX_TELEMETRY_BYTES`]), and the
//! payload — an opaque [`crate::metrics::telemetry::StageSnapshot`] — is
//! deliberately **outside the reliability session**: it consumes no
//! data-plane sequence number, never enters the replay buffer, and never
//! changes when an ACK is due, so observability can never reorder or
//! delay the data plane (best-effort delivery is the price, and the
//! snapshot format is built to tolerate it).

use super::frame::Frame;
use crate::Result;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::time::Duration;

/// Length-prefix value marking a control record (can never be a frame
/// length: it exceeds [`MAX_FRAME_BYTES`]).
pub const CTRL_MARKER: u32 = u32::MAX;
/// Control record size: marker u32 + kind u8 + seq u64.
pub const CTRL_LEN: usize = 13;

/// Upper bound on an incoming frame's length prefix; anything larger is a
/// corrupt or hostile stream, not a real activation frame.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Control kind: receiver's greeting / resync position.
pub const K_HELLO: u8 = 1;
/// Control kind: cumulative acknowledgement.
pub const K_ACK: u8 = 2;
/// Control kind: sender finished at `seq`.
pub const K_FIN: u8 = 3;
/// Control kind: receiver confirms the drain.
pub const K_FIN_ACK: u8 = 4;
/// Control kind: telemetry record; the `seq` field is the byte length of
/// the opaque payload that follows the 13-byte header.
pub const K_TELEMETRY: u8 = 5;
/// Control kind: advisory selective ack — the receiver already holds
/// `seq` in its reorder window, so a resyncing sender may skip it when
/// replaying the unacked tail. Best-effort: a lost or unsupported HAVE
/// merely degrades to full-tail replay plus receiver-side dedup (peers
/// predating this kind ignore it via the unknown-kind arm).
pub const K_HAVE: u8 = 6;

/// Upper bound on a telemetry record's payload. Far above any real
/// snapshot (a few KB); anything larger is a corrupt or hostile stream.
pub const MAX_TELEMETRY_BYTES: usize = 1 << 20;

/// Serialize one control record.
pub fn ctrl_record(kind: u8, seq: u64) -> [u8; CTRL_LEN] {
    let mut rec = [0u8; CTRL_LEN];
    rec[0..4].copy_from_slice(&CTRL_MARKER.to_le_bytes());
    rec[4] = kind;
    rec[5..13].copy_from_slice(&seq.to_le_bytes());
    rec
}

/// Parse the record at `rec` (13 bytes, marker already checked by the
/// caller): `(kind, seq)`.
pub fn parse_ctrl(rec: &[u8]) -> (u8, u64) {
    // lint: allow(unwrap): 8-byte slice of a CTRL_LEN record, length fixed by construction
    (rec[4], u64::from_le_bytes(rec[5..13].try_into().unwrap()))
}

/// Serialize a complete telemetry record — 13-byte header (the `seq`
/// field carries the payload length) followed by the payload — appending
/// to `out`. Oversized payloads are refused rather than truncated: a
/// record the decoder would reject must never reach the wire.
pub fn append_telemetry_record(out: &mut Vec<u8>, payload: &[u8]) -> Result<()> {
    anyhow::ensure!(
        payload.len() <= MAX_TELEMETRY_BYTES,
        "telemetry payload of {} bytes exceeds {MAX_TELEMETRY_BYTES}",
        payload.len()
    );
    out.extend_from_slice(&ctrl_record(K_TELEMETRY, payload.len() as u64));
    out.extend_from_slice(payload);
    Ok(())
}

/// Tuning for the reliability session and its conduits. Defaults suit
/// LAN/edge deployments; tests shrink every duration.
#[derive(Debug, Clone)]
pub struct ResilienceConfig {
    /// Sent-but-unacked frames kept for replay. A full buffer blocks the
    /// sender until the receiver acks (backpressure), so no unacked frame
    /// is ever evicted — the no-loss guarantee depends on that. Both ends
    /// of a link should share this value: the receiver batches its
    /// cumulative acks once per `replay_capacity / 4` frames, and a
    /// striped receiver bounds its reorder window by it.
    pub replay_capacity: usize,
    /// Total budget to get a link back after a failure; exhausted ⇒ the
    /// outage is reported as a hard error.
    pub reconnect_timeout: Duration,
    /// Budget for the FIRST connection of the session. Multi-process
    /// startup is order-independent, so the initial peer wait must be as
    /// generous as the plain-TCP connect retry — not the (typically
    /// tighter) mid-run reconnect budget.
    pub initial_timeout: Duration,
    /// First redial delay (doubles per attempt).
    pub backoff_base: Duration,
    /// Redial delay cap.
    pub backoff_max: Duration,
    /// Jitter fraction in `[0, 1]`: each delay is scaled by a factor from
    /// `[1 - jitter, 1]`.
    pub jitter: f64,
    /// How long the dialer waits for the peer's `HELLO` on a fresh
    /// connection before treating the attempt as failed.
    pub hello_timeout: Duration,
    /// Budget for the FIN/FIN_ACK drain at shutdown (includes any final
    /// reconnect + replay needed to deliver the tail).
    pub drain_timeout: Duration,
    /// Seed for the jitter RNG (deterministic schedules in tests).
    pub seed: u64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            replay_capacity: 128,
            reconnect_timeout: Duration::from_secs(10),
            initial_timeout: Duration::from_secs(30),
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_secs(1),
            jitter: 0.5,
            hello_timeout: Duration::from_secs(2),
            drain_timeout: Duration::from_secs(10),
            seed: 0x5150_1ead,
        }
    }
}

// ---------------------------------------------------------------------------
// Incremental wire decoder
// ---------------------------------------------------------------------------

/// One parsed item off a conduit's byte stream.
#[derive(Debug)]
pub enum WireItem {
    /// A data-plane activation frame.
    Frame(Frame),
    /// `(kind, seq)` control record.
    Ctrl(u8, u64),
    /// A telemetry record's opaque payload (already length-validated).
    Telemetry(Vec<u8>),
}

/// Incremental parser for the session wire format. Conduits read whatever
/// bytes are available (striped receivers cannot block on one connection
/// while another has data) and feed them here; complete items pop out as
/// they materialize. Any desync — a non-marker prefix that exceeds
/// [`MAX_FRAME_BYTES`], or a frame that fails its own header/CRC checks —
/// is an error: the conduit must be dropped and resynced (replay makes
/// that lossless), never skipped over.
#[derive(Debug, Default)]
pub struct WireDecoder {
    buf: Vec<u8>,
    pos: usize,
}

impl WireDecoder {
    /// Empty decoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append raw bytes read off a conduit.
    pub fn extend(&mut self, bytes: &[u8]) {
        // Compact lazily so the buffer doesn't grow without bound.
        if self.pos > 4096 && self.pos * 2 >= self.buf.len() {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    fn available(&self) -> &[u8] {
        &self.buf[self.pos..]
    }

    /// Next complete item, if the buffer holds one.
    pub fn next(&mut self) -> Result<Option<WireItem>> {
        let avail = self.available();
        if avail.len() < 4 {
            return Ok(None);
        }
        // lint: allow(unwrap): 4-byte slice into a 4-byte array, infallible by construction
        let prefix = u32::from_le_bytes(avail[0..4].try_into().unwrap());
        if prefix == CTRL_MARKER {
            if avail.len() < CTRL_LEN {
                return Ok(None);
            }
            let (kind, seq) = parse_ctrl(&avail[..CTRL_LEN]);
            if kind == K_TELEMETRY {
                // The one variable-length record: seq = payload length.
                let len = seq as usize;
                anyhow::ensure!(
                    seq <= MAX_TELEMETRY_BYTES as u64,
                    "corrupt stream: telemetry payload length {seq} exceeds {MAX_TELEMETRY_BYTES}"
                );
                if avail.len() < CTRL_LEN + len {
                    return Ok(None);
                }
                let payload = avail[CTRL_LEN..CTRL_LEN + len].to_vec();
                self.pos += CTRL_LEN + len;
                return Ok(Some(WireItem::Telemetry(payload)));
            }
            self.pos += CTRL_LEN;
            return Ok(Some(WireItem::Ctrl(kind, seq)));
        }
        let len = prefix as usize;
        anyhow::ensure!(
            len <= MAX_FRAME_BYTES,
            "corrupt stream: frame length prefix {len} exceeds {MAX_FRAME_BYTES}"
        );
        if avail.len() < 4 + len {
            return Ok(None);
        }
        // A corrupt frame is an error, not a skip: the resilient contract
        // is zero loss, and the sender's replay buffer still holds it.
        let frame = Frame::from_bytes(&avail[4..4 + len])?;
        self.pos += 4 + len;
        Ok(Some(WireItem::Frame(frame)))
    }
}

// ---------------------------------------------------------------------------
// Sender-side session state
// ---------------------------------------------------------------------------

/// Acked-frame serialization buffers kept for reuse by [`SessionTx::take_buf`].
/// Small: the sender serializes one frame at a time, so one spare usually
/// suffices; a few extra absorb ack batches without hoarding memory.
const SPARE_BUFS: usize = 4;

/// Sender half of the session: the bounded replay buffer plus the
/// cumulative-ACK / HELLO-resync / FIN bookkeeping. Owns no I/O: callers
/// record what they are about to write, apply the control records they
/// read, and iterate [`SessionTx::replay_tail`] after each resync.
///
/// Serialization buffers are pooled: frames acknowledged (and therefore
/// dropped from the replay buffer) hand their `Vec<u8>` back, and
/// [`SessionTx::take_buf`] supplies it for the next frame — steady-state
/// senders serialize without allocating.
///
/// `Clone` exists for the deterministic interleaving checker
/// ([`crate::analysis::schedule`]), which forks protocol state at every
/// scheduling choice; production code never clones a live session.
#[derive(Debug, Clone)]
pub struct SessionTx {
    /// `(seq, serialized frame)` for every sent-but-unacked frame,
    /// ascending and contiguous.
    replay: VecDeque<(u64, Vec<u8>)>,
    capacity: usize,
    /// Receiver's cumulative position: everything below is delivered.
    acked: u64,
    /// One past the highest seq ever recorded (the FIN boundary).
    next_seq: u64,
    fin_acked: bool,
    /// Recycled serialization buffers (bounded by [`SPARE_BUFS`]).
    spare: Vec<Vec<u8>>,
    /// Selective-ack state: seqs the peer reported already parked
    /// ([`K_HAVE`]), skipped by [`SessionTx::replay_tail`]. Trimmed as
    /// the cumulative ack advances; cleared on every `HELLO` resync
    /// (each reconnect renegotiates what the receiver holds).
    have: BTreeSet<u64>,
}

impl SessionTx {
    /// Sender-side session with a bounded replay buffer.
    pub fn new(replay_capacity: usize) -> Self {
        SessionTx {
            replay: VecDeque::new(),
            capacity: replay_capacity.max(1),
            acked: 0,
            next_seq: 0,
            fin_acked: false,
            spare: Vec::new(),
            have: BTreeSet::new(),
        }
    }

    /// A recycled serialization buffer (or a fresh one), for
    /// [`crate::net::frame::Frame::write_into`] before
    /// [`SessionTx::record_send`]. Contents are stale; `write_into`
    /// clears it.
    pub fn take_buf(&mut self) -> Vec<u8> {
        self.take_spare().unwrap_or_default()
    }

    /// Pop one recycled serialization buffer, or `None` when nothing has
    /// been acked since the last take. The copy-free send path uses this
    /// to hand retired wire buffers back to the codec thread's pool
    /// instead of allocating fresh ones there.
    pub fn take_spare(&mut self) -> Option<Vec<u8>> {
        self.spare.pop()
    }

    /// Replay-buffer capacity (frames).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Frames recorded but not yet acknowledged by the peer.
    pub fn unacked(&self) -> usize {
        self.replay.len()
    }

    /// Room for another frame? A full buffer is backpressure: the caller
    /// must pump acks (or resync a conduit) before recording more.
    pub fn has_room(&self) -> bool {
        self.replay.len() < self.capacity
    }

    /// One past the highest recorded seq — the FIN boundary.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Receiver's cumulative ack position.
    pub fn acked(&self) -> u64 {
        self.acked
    }

    /// Record a frame about to go on the wire. Fails on a full buffer
    /// (callers block for room first) or a non-ascending seq (the replay
    /// buffer's contiguity is what makes `HELLO` resync sound).
    pub fn record_send(&mut self, seq: u64, bytes: Vec<u8>) -> Result<()> {
        anyhow::ensure!(self.has_room(), "replay buffer full ({} frames)", self.capacity);
        anyhow::ensure!(
            self.replay.back().map_or(true, |(q, _)| *q < seq),
            "non-ascending seq {seq} recorded into the replay buffer"
        );
        self.replay.push_back((seq, bytes));
        if self.next_seq <= seq {
            self.next_seq = seq + 1;
        }
        Ok(())
    }

    /// The bytes of the most recently recorded frame (what `send` is
    /// about to write).
    pub fn latest(&self) -> Option<&[u8]> {
        self.replay.back().map(|(_, b)| b.as_slice())
    }

    /// Cumulative ack: drop everything below `next_expected`, recycling
    /// the dropped frames' serialization buffers into the spare pool and
    /// trimming now-covered selective-ack entries.
    pub fn on_ack(&mut self, next_expected: u64) {
        while self.replay.front().map_or(false, |(q, _)| *q < next_expected) {
            if let Some((_, buf)) = self.replay.pop_front() {
                if self.spare.len() < SPARE_BUFS {
                    self.spare.push(buf);
                }
            }
        }
        // split_off keeps everything >= the key: entries the cumulative
        // position has passed are dropped, still-unacked ones survive.
        self.have = self.have.split_off(&next_expected);
        self.acked = self.acked.max(next_expected);
    }

    /// A (re)connecting conduit's `HELLO{next_expected}`: trim to the
    /// receiver's cumulative position and validate that the replay buffer
    /// can cover the tail. After this the caller writes every frame from
    /// [`SessionTx::replay_tail`] onto that conduit.
    pub fn on_hello(&mut self, next_expected: u64) -> Result<()> {
        // Each resync renegotiates the receiver's window contents: any
        // HAVE records for the new conduit arrive after its HELLO, and
        // stale ones from the previous incarnation must not suppress a
        // replay the receiver now needs.
        self.have.clear();
        anyhow::ensure!(
            next_expected <= self.next_seq,
            "peer expects seq {next_expected} but only {} were ever sent",
            self.next_seq
        );
        self.on_ack(next_expected);
        if let Some((front, _)) = self.replay.front() {
            // Contiguity means the trimmed buffer starts exactly where the
            // receiver resumes; anything else is an unrecoverable gap
            // (e.g. a peer that lost acknowledged state).
            anyhow::ensure!(
                *front == next_expected,
                "replay buffer cannot cover the receiver's position: have seq {front}, peer needs {next_expected}"
            );
        }
        Ok(())
    }

    /// The unacked tail, in order, minus frames the peer selectively
    /// acked via [`K_HAVE`] — what a freshly resynced conduit must carry
    /// before any new frame. The skipped frames stay in the replay
    /// buffer (only a cumulative ack retires state), so a later resync
    /// that renegotiates the window can still cover them.
    pub fn replay_tail(&self) -> impl Iterator<Item = &[u8]> {
        self.replay
            .iter()
            .filter(|(q, _)| !self.have.contains(q))
            .map(|(_, b)| b.as_slice())
    }

    /// Advisory selective ack from the peer: `seq` is already parked in
    /// its reorder window. Ignored unless `seq` is genuinely in the
    /// unacked range — a stale or hostile HAVE must never grow state or
    /// suppress a replay the protocol needs.
    pub fn on_have(&mut self, seq: u64) {
        if self.acked <= seq && seq < self.next_seq {
            self.have.insert(seq);
        }
    }

    /// Sequence numbers currently held in the replay buffer, ascending.
    /// Introspection for invariant checks and state fingerprinting; the
    /// data path never needs it.
    pub fn replay_seqs(&self) -> impl Iterator<Item = u64> + '_ {
        self.replay.iter().map(|(q, _)| *q)
    }

    /// Apply one inbound control record. A mid-stream `HELLO` cannot
    /// happen on a healthy conduit, but as a cumulative position it is
    /// safe to treat like an ack. Unknown kinds are ignored (forward
    /// compatibility).
    pub fn apply_ctrl(&mut self, kind: u8, seq: u64) {
        match kind {
            K_ACK | K_HELLO => self.on_ack(seq),
            K_FIN_ACK => self.fin_acked = true,
            K_HAVE => self.on_have(seq),
            _ => {}
        }
    }

    /// Has the peer confirmed the drain?
    pub fn fin_acked(&self) -> bool {
        self.fin_acked
    }

    /// Reset the drain confirmation (a `finish` retry re-FINs).
    pub fn clear_fin_ack(&mut self) {
        self.fin_acked = false;
    }

    /// The `FIN{end_seq}` record closing this session.
    pub fn fin_record(&self) -> [u8; CTRL_LEN] {
        ctrl_record(K_FIN, self.next_seq)
    }
}

// ---------------------------------------------------------------------------
// Receiver-side session state
// ---------------------------------------------------------------------------

/// What [`SessionRx::on_frame`] did with a frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RxStep {
    /// At least one frame became deliverable — drain [`SessionRx::pop_ready`].
    Delivered,
    /// Already have it (replay overlap) — drop it and force an ack so the
    /// sender resyncs its buffer.
    Duplicate,
    /// Ahead of the in-order point (striped arrival) — parked in the
    /// reorder window.
    Buffered,
}

/// Receiver half of the session: in-order delivery point, dedup/reorder
/// window, cumulative-ack batching, and the FIN bookkeeping. Owns no I/O:
/// the caller writes the records this hands back ([`SessionRx::hello_record`],
/// [`SessionRx::ack_due`], FIN_ACK via [`SessionRx::fin_due`]) and commits
/// them only once the write succeeded — a failed write costs nothing, the
/// next conduit's `HELLO` re-establishes the cumulative position.
///
/// `Clone` exists for the deterministic interleaving checker
/// ([`crate::analysis::schedule`]); production code never clones a live
/// session.
#[derive(Debug, Clone)]
pub struct SessionRx {
    next_expected: u64,
    /// Cumulative position last successfully written as ACK (or HELLO).
    last_acked: u64,
    /// Ack once per this many delivered frames. Derived as a quarter of
    /// `replay_capacity`, so with both ends on one config the sender's
    /// buffer can never fill before the next ack boundary is crossed —
    /// per-frame ack packets would be pure overhead (the scheme is
    /// cumulative and `HELLO` re-syncs any lost tail).
    ack_every: u64,
    /// Out-of-order arrivals (striped conduits race); keyed by seq.
    pending: BTreeMap<u64, Frame>,
    /// Reorder bound: 0 = strict in-order (a single ordered conduit can
    /// never legitimately skip ahead, so a gap is a protocol error);
    /// striped boundaries bound it by `replay_capacity` (the sender can
    /// never be further ahead than its own unacked window).
    reorder_window: usize,
    /// In-order frames awaiting `pop_ready`.
    ready: VecDeque<Frame>,
    /// `FIN{end_seq}` received; FIN_ACK owed once everything below is in.
    fin_at: Option<u64>,
    /// FIN_ACK successfully written: the session is cleanly closed.
    fin_acked: bool,
}

impl SessionRx {
    /// `reorder_window` = 0 for a single ordered conduit, the sender's
    /// `replay_capacity` for a striped boundary.
    pub fn new(replay_capacity: usize, reorder_window: usize) -> Self {
        SessionRx {
            next_expected: 0,
            last_acked: 0,
            ack_every: (replay_capacity as u64 / 4).max(1),
            pending: BTreeMap::new(),
            reorder_window,
            ready: VecDeque::new(),
            fin_at: None,
            fin_acked: false,
        }
    }

    /// The in-order delivery point (next seq this session still needs).
    pub fn next_expected(&self) -> u64 {
        self.next_expected
    }

    /// The greeting for a (re)connecting conduit. Once written, the
    /// caller commits it with [`SessionRx::mark_acked`] — HELLO doubles
    /// as a cumulative ack.
    pub fn hello_record(&self) -> [u8; CTRL_LEN] {
        ctrl_record(K_HELLO, self.next_expected)
    }

    /// One inbound frame from any conduit.
    pub fn on_frame(&mut self, f: Frame) -> Result<RxStep> {
        if f.seq < self.next_expected || self.pending.contains_key(&f.seq) {
            return Ok(RxStep::Duplicate);
        }
        if f.seq > self.next_expected {
            anyhow::ensure!(
                self.reorder_window > 0,
                "sequence gap: got frame {}, expected {} (peer could not replay the tail)",
                f.seq,
                self.next_expected
            );
            anyhow::ensure!(
                self.pending.len() < self.reorder_window,
                "reorder window overflow: {} frames parked, still missing seq {}",
                self.pending.len(),
                self.next_expected
            );
        }
        self.pending.insert(f.seq, f);
        let mut delivered = false;
        while let Some(f) = self.pending.remove(&self.next_expected) {
            self.ready.push_back(f);
            self.next_expected += 1;
            delivered = true;
        }
        Ok(if delivered { RxStep::Delivered } else { RxStep::Buffered })
    }

    /// Next in-order frame ready for the application.
    pub fn pop_ready(&mut self) -> Option<Frame> {
        self.ready.pop_front()
    }

    /// Any frames waiting in the in-order delivery queue?
    pub fn has_ready(&self) -> bool {
        !self.ready.is_empty()
    }

    /// The cumulative ack that should go out now, if any: every ack-batch
    /// boundary, or unconditionally when `force`d (dedup resync). Commit
    /// with [`SessionRx::mark_acked`] after a successful write.
    pub fn ack_due(&self, force: bool) -> Option<u64> {
        if !force && self.next_expected.saturating_sub(self.last_acked) < self.ack_every {
            return None;
        }
        Some(self.next_expected)
    }

    /// Record that a cumulative position went out on the wire (ACK or
    /// HELLO written successfully).
    pub fn mark_acked(&mut self, pos: u64) {
        self.last_acked = self.last_acked.max(pos);
    }

    /// `FIN{end_seq}` arrived (on any conduit — stripes finish out of
    /// order, so frames above `next_expected` may still be in flight
    /// elsewhere; FIN_ACK waits for them via [`SessionRx::fin_due`]).
    pub fn on_fin(&mut self, end: u64) -> Result<()> {
        if self.reorder_window == 0 {
            // Single ordered conduit: FIN follows every frame/replay on
            // the same stream, so any mismatch means loss.
            anyhow::ensure!(
                end == self.next_expected,
                "peer finished at seq {end} but only {} frames were delivered: frames lost",
                self.next_expected
            );
        } else {
            anyhow::ensure!(
                end >= self.next_expected,
                "peer finished at seq {end} but {} frames were already delivered: frames lost",
                self.next_expected
            );
            if let Some(prev) = self.fin_at {
                anyhow::ensure!(
                    prev == end,
                    "conflicting FIN boundaries: {prev} vs {end}"
                );
            }
        }
        self.fin_at = Some(end);
        Ok(())
    }

    /// `Some(end)` when everything up to the FIN boundary has been
    /// received and the FIN_ACK has not been sent yet. Commit with
    /// [`SessionRx::mark_fin_acked`] after a successful write.
    pub fn fin_due(&self) -> Option<u64> {
        match self.fin_at {
            Some(end) if !self.fin_acked && self.next_expected == end => Some(end),
            _ => None,
        }
    }

    /// FIN_ACK went out: the session is cleanly closed (frames still in
    /// the ready queue drain to the application first).
    pub fn mark_fin_acked(&mut self) {
        self.fin_acked = true;
    }

    /// Cleanly closed (FIN received, everything delivered, FIN_ACK sent)?
    pub fn finished(&self) -> bool {
        self.fin_acked
    }

    /// Sequence numbers parked in the reorder window, ascending.
    /// Introspection for invariant checks and state fingerprinting.
    pub fn parked_seqs(&self) -> impl Iterator<Item = u64> + '_ {
        self.pending.keys().copied()
    }

    /// Cumulative position last committed as written (ACK or HELLO).
    pub fn last_acked(&self) -> u64 {
        self.last_acked
    }

    /// The FIN boundary received so far, if any.
    pub fn fin_boundary(&self) -> Option<u64> {
        self.fin_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codec::Codec;
    use crate::quant::Method;

    fn frame(seq: u64, n: usize) -> Frame {
        let x: Vec<f32> = (0..n).map(|i| ((i + seq as usize) as f32).sin()).collect();
        let mut c = Codec::default();
        Frame::new(seq, vec![n], c.encode(&x, Method::Pda, 8).unwrap())
    }

    #[test]
    fn tx_records_trims_and_replays() {
        let mut tx = SessionTx::new(8);
        for seq in 0..4 {
            tx.record_send(seq, frame(seq, 16).to_bytes()).unwrap();
        }
        assert_eq!(tx.unacked(), 4);
        assert_eq!(tx.next_seq(), 4);
        tx.on_ack(2);
        assert_eq!(tx.unacked(), 2, "ACK{{2}} trims exactly seqs 0 and 1");
        tx.on_hello(3).unwrap();
        assert_eq!(tx.unacked(), 1);
        let tail: Vec<_> = tx.replay_tail().collect();
        assert_eq!(tail.len(), 1);
        assert_eq!(Frame::from_bytes(tail[0]).unwrap().seq, 3);
    }

    #[test]
    fn tx_rejects_uncoverable_hello_and_future_hello() {
        let mut tx = SessionTx::new(8);
        tx.record_send(0, frame(0, 16).to_bytes()).unwrap();
        tx.record_send(1, frame(1, 16).to_bytes()).unwrap();
        // Peer claims to expect more than was ever sent.
        assert!(tx.on_hello(5).is_err());
        // Ack 1 away, then a HELLO asking for 0 again: the buffer no
        // longer covers seq 0.
        tx.on_ack(1);
        assert!(tx.on_hello(0).is_err());
    }

    #[test]
    fn tx_recycles_acked_serialization_buffers() {
        let mut tx = SessionTx::new(8);
        // Steady state: serialize into take_buf, record, get acked — the
        // acked frame's buffer must come back out of take_buf.
        let mut buf = tx.take_buf();
        frame(0, 64).write_into(&mut buf);
        let ptr = buf.as_ptr();
        tx.record_send(0, buf).unwrap();
        assert!(tx.take_buf().is_empty(), "nothing acked yet: fresh buffer");
        tx.on_ack(1);
        let recycled = tx.take_buf();
        assert_eq!(recycled.as_ptr(), ptr, "acked frame's buffer must be reused");
        // The pool is bounded: flooding acks never hoards more than a few.
        let mut tx = SessionTx::new(64);
        for seq in 0..32u64 {
            tx.record_send(seq, vec![0u8; 128]).unwrap();
        }
        tx.on_ack(32);
        assert!(tx.spare.len() <= SPARE_BUFS);
    }

    #[test]
    fn selective_acks_narrow_the_replay_tail() {
        let mut tx = SessionTx::new(8);
        for seq in 0..4 {
            tx.record_send(seq, frame(seq, 16).to_bytes()).unwrap();
        }
        // Reconnect: the receiver needs seq 1 onward but already parked
        // 2 — only 1 and 3 should replay.
        tx.on_hello(1).unwrap();
        tx.apply_ctrl(K_HAVE, 2);
        let replayed: Vec<u64> = tx
            .replay_tail()
            .map(|b| Frame::from_bytes(b).unwrap().seq)
            .collect();
        assert_eq!(replayed, vec![1, 3], "HAVE{{2}} must be skipped");
        assert_eq!(tx.unacked(), 3, "skipped frames stay in the replay buffer");
    }

    #[test]
    fn hello_clears_stale_haves() {
        let mut tx = SessionTx::new(8);
        for seq in 0..3 {
            tx.record_send(seq, frame(seq, 16).to_bytes()).unwrap();
        }
        tx.on_have(1);
        assert_eq!(tx.replay_tail().count(), 2);
        // A new resync renegotiates: the receiver of THIS incarnation
        // never claimed seq 1, so the full tail must replay again.
        tx.on_hello(0).unwrap();
        assert_eq!(tx.replay_tail().count(), 3, "resync must forget old HAVEs");
    }

    #[test]
    fn out_of_range_haves_are_ignored() {
        let mut tx = SessionTx::new(8);
        for seq in 0..3 {
            tx.record_send(seq, frame(seq, 16).to_bytes()).unwrap();
        }
        tx.on_ack(1);
        tx.on_have(0); // below the cumulative position: already retired
        tx.on_have(7); // beyond anything ever sent: bogus
        assert_eq!(tx.replay_tail().count(), 2, "neither HAVE may narrow the tail");
    }

    #[test]
    fn cumulative_ack_trims_covered_haves() {
        let mut tx = SessionTx::new(8);
        for seq in 0..4 {
            tx.record_send(seq, frame(seq, 16).to_bytes()).unwrap();
        }
        tx.on_have(1);
        tx.on_have(3);
        tx.on_ack(3); // passes seq 1's entry, keeps seq 3's
        let kept: Vec<u64> = tx.have.iter().copied().collect();
        assert_eq!(kept, vec![3], "covered HAVEs must be trimmed, live ones kept");
        assert_eq!(tx.replay_tail().count(), 0, "the one remaining frame is HAVEd");
        assert_eq!(tx.unacked(), 1);
    }

    #[test]
    fn tx_full_buffer_is_backpressure_not_eviction() {
        let mut tx = SessionTx::new(2);
        tx.record_send(0, vec![0]).unwrap();
        tx.record_send(1, vec![1]).unwrap();
        assert!(!tx.has_room());
        assert!(tx.record_send(2, vec![2]).is_err(), "full buffer must refuse, never evict");
        tx.on_ack(1);
        assert!(tx.has_room());
        tx.record_send(2, vec![2]).unwrap();
    }

    #[test]
    fn rx_strict_mode_errors_on_gap() {
        let mut rx = SessionRx::new(16, 0);
        assert_eq!(rx.on_frame(frame(0, 16)).unwrap(), RxStep::Delivered);
        let err = rx.on_frame(frame(2, 16)).unwrap_err();
        assert!(err.to_string().contains("sequence gap"), "{err:#}");
    }

    #[test]
    fn rx_reorders_across_stripes_and_dedups() {
        let mut rx = SessionRx::new(16, 16);
        assert_eq!(rx.on_frame(frame(1, 16)).unwrap(), RxStep::Buffered);
        assert_eq!(rx.on_frame(frame(2, 16)).unwrap(), RxStep::Buffered);
        assert_eq!(rx.on_frame(frame(1, 16)).unwrap(), RxStep::Duplicate, "parked frame re-arrives");
        assert_eq!(rx.on_frame(frame(0, 16)).unwrap(), RxStep::Delivered);
        let got: Vec<u64> = std::iter::from_fn(|| rx.pop_ready()).map(|f| f.seq).collect();
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(rx.on_frame(frame(0, 16)).unwrap(), RxStep::Duplicate, "delivered frame re-arrives");
        assert_eq!(rx.next_expected(), 3);
    }

    #[test]
    fn rx_ack_batching_and_force() {
        let mut rx = SessionRx::new(16, 0); // ack_every = 4
        for seq in 0..3 {
            rx.on_frame(frame(seq, 16)).unwrap();
        }
        assert_eq!(rx.ack_due(false), None, "below the batch boundary");
        assert_eq!(rx.ack_due(true), Some(3), "forced ack is unconditional");
        rx.on_frame(frame(3, 16)).unwrap();
        assert_eq!(rx.ack_due(false), Some(4));
        rx.mark_acked(4);
        assert_eq!(rx.ack_due(false), None);
    }

    #[test]
    fn rx_fin_waits_for_out_of_order_stripes() {
        // The striped drain: FIN rides one conduit while the last frames
        // are still in flight on another. FIN_ACK must wait for them.
        let mut rx = SessionRx::new(16, 16);
        rx.on_frame(frame(0, 16)).unwrap();
        rx.on_frame(frame(2, 16)).unwrap(); // stripe B finished first
        rx.on_fin(3).unwrap();
        assert_eq!(rx.fin_due(), None, "seq 1 still missing");
        rx.on_frame(frame(1, 16)).unwrap();
        assert_eq!(rx.fin_due(), Some(3));
        rx.mark_fin_acked();
        assert!(rx.finished());
        let got: Vec<u64> = std::iter::from_fn(|| rx.pop_ready()).map(|f| f.seq).collect();
        assert_eq!(got, vec![0, 1, 2], "ready frames still drain after the FIN_ACK");
    }

    #[test]
    fn rx_strict_fin_mismatch_is_loss() {
        let mut rx = SessionRx::new(16, 0);
        rx.on_frame(frame(0, 16)).unwrap();
        let err = rx.on_fin(3).unwrap_err();
        assert!(err.to_string().contains("frames lost"), "{err:#}");
    }

    #[test]
    fn decoder_splits_frames_and_ctrl_across_arbitrary_chunks() {
        let f0 = frame(0, 64);
        let f1 = frame(1, 64);
        let mut wire = Vec::new();
        let b = f0.to_bytes();
        wire.extend_from_slice(&(b.len() as u32).to_le_bytes());
        wire.extend_from_slice(&b);
        wire.extend_from_slice(&ctrl_record(K_ACK, 7));
        let b = f1.to_bytes();
        wire.extend_from_slice(&(b.len() as u32).to_le_bytes());
        wire.extend_from_slice(&b);
        wire.extend_from_slice(&ctrl_record(K_FIN, 2));
        // Feed one byte at a time: items must pop out exactly in order.
        let mut dec = WireDecoder::new();
        let mut items = Vec::new();
        for byte in wire {
            dec.extend(&[byte]);
            while let Some(item) = dec.next().unwrap() {
                items.push(item);
            }
        }
        assert_eq!(items.len(), 4);
        assert!(matches!(&items[0], WireItem::Frame(f) if f.seq == 0));
        assert!(matches!(&items[1], WireItem::Ctrl(K_ACK, 7)));
        assert!(matches!(&items[2], WireItem::Frame(f) if f.seq == 1));
        assert!(matches!(&items[3], WireItem::Ctrl(K_FIN, 2)));
    }

    #[test]
    fn telemetry_rides_the_wire_without_touching_the_session() {
        // The observability invariant: a telemetry record between two data
        // frames must decode in stream order, consume no data-plane seq,
        // and leave the receiver's ACK schedule EXACTLY as it would be
        // without it — telemetry may be lost, the data plane may not be
        // perturbed.
        let payload: Vec<u8> = (0..200u16).map(|i| (i % 251) as u8).collect();
        let build_wire = |with_telemetry: bool| {
            let mut wire = Vec::new();
            for seq in 0..8u64 {
                let b = frame(seq, 32).to_bytes();
                wire.extend_from_slice(&(b.len() as u32).to_le_bytes());
                wire.extend_from_slice(&b);
                if with_telemetry && seq == 3 {
                    append_telemetry_record(&mut wire, &payload).unwrap();
                }
            }
            wire.extend_from_slice(&ctrl_record(K_FIN, 8));
            wire
        };
        let run = |wire: Vec<u8>| {
            let mut rx = SessionRx::new(16, 0); // ack_every = 4
            let mut dec = WireDecoder::new();
            dec.extend(&wire);
            let mut acks = Vec::new();
            let mut delivered = Vec::new();
            let mut telemetry = Vec::new();
            while let Some(item) = dec.next().unwrap() {
                match item {
                    WireItem::Frame(f) => {
                        rx.on_frame(f).unwrap();
                        while let Some(f) = rx.pop_ready() {
                            delivered.push(f.seq);
                        }
                        if let Some(pos) = rx.ack_due(false) {
                            acks.push(pos);
                            rx.mark_acked(pos);
                        }
                    }
                    WireItem::Ctrl(K_FIN, end) => rx.on_fin(end).unwrap(),
                    WireItem::Ctrl(_, _) => {}
                    WireItem::Telemetry(p) => telemetry.push(p),
                }
            }
            assert_eq!(rx.fin_due(), Some(8));
            (acks, delivered, telemetry)
        };
        let (acks_plain, frames_plain, t_plain) = run(build_wire(false));
        let (acks_tele, frames_tele, t_tele) = run(build_wire(true));
        assert!(t_plain.is_empty());
        assert_eq!(t_tele, vec![payload], "payload must come through byte-identical");
        assert_eq!(frames_plain, frames_tele, "telemetry must not reorder frames");
        assert_eq!(
            acks_plain, acks_tele,
            "telemetry must not delay, force or suppress a data-plane ACK"
        );
        assert_eq!(acks_tele, vec![4, 8], "batched cumulative ACK schedule intact");
    }

    #[test]
    fn telemetry_record_split_across_chunks_and_oversized_len_rejected() {
        let payload = vec![7u8; 300];
        let mut wire = Vec::new();
        append_telemetry_record(&mut wire, &payload).unwrap();
        let b = frame(0, 32).to_bytes();
        wire.extend_from_slice(&(b.len() as u32).to_le_bytes());
        wire.extend_from_slice(&b);
        // One byte at a time: the record must only pop once complete.
        let mut dec = WireDecoder::new();
        let mut items = Vec::new();
        for byte in wire {
            dec.extend(&[byte]);
            while let Some(item) = dec.next().unwrap() {
                items.push(item);
            }
        }
        assert_eq!(items.len(), 2);
        assert!(matches!(&items[0], WireItem::Telemetry(p) if *p == payload));
        assert!(matches!(&items[1], WireItem::Frame(f) if f.seq == 0));
        // A hostile length is a desync, and the writer refuses to emit one.
        let mut dec = WireDecoder::new();
        dec.extend(&ctrl_record(K_TELEMETRY, MAX_TELEMETRY_BYTES as u64 + 1));
        assert!(dec.next().is_err(), "oversized telemetry length must desync");
        let mut out = Vec::new();
        assert!(append_telemetry_record(&mut out, &vec![0u8; MAX_TELEMETRY_BYTES + 1]).is_err());
    }

    #[test]
    fn decoder_rejects_oversized_prefix_and_corrupt_frame() {
        let mut dec = WireDecoder::new();
        dec.extend(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
        assert!(dec.next().is_err(), "oversized prefix is a desync");

        let mut dec = WireDecoder::new();
        let mut b = frame(0, 64).to_bytes();
        let n = b.len();
        b[n - 1] ^= 0xff; // CRC mismatch
        dec.extend(&(b.len() as u32).to_le_bytes());
        dec.extend(&b);
        assert!(dec.next().is_err(), "corrupt frame must force a resync, not a skip");
    }
}
