//! Deterministic byte-level link shaping: a root-free `tc netem`.
//!
//! Every impairment the real-TCP stack had ever faced was a hand-placed
//! [`super::conduit::LinkKillSwitch`] in a test; actual *shaping* (rate,
//! delay, jitter, corruption, partitions) lived only in the in-process
//! [`super::link::SimLink`]. The [`LinkShaper`] closes that gap: it sits
//! on the **sender threads** at the striped write path and renders a
//! [`super::trace::BandwidthTrace`] — plus seeded jitter, probabilistic
//! frame corruption, frame loss and partition windows — onto real
//! localhost sockets, fully deterministic from a seed.
//!
//! Placement is the whole design (see docs/ARCHITECTURE.md):
//!
//! * **All shaping happens on the write side.** The adaptive controller
//!   never reads the trace — it measures write-stall time — so a shaper
//!   sleep on the sender thread *is* the collapsed-bandwidth signal, and
//!   the reactor's read sweep stays untouched (a read-side throttle
//!   would delay acks and distort the very signal under test).
//! * **Loss is expressed as a conduit kill.** A lossy link on a session
//!   link means a frame died in flight; the honest model is the conduit
//!   dying with unacked frames, which makes the session machinery
//!   (reconnect → HELLO/HAVE → replay) earn its keep instead of
//!   silently skipping a sequence number.
//! * **Corruption flips a byte in a throwaway copy** of the wire bytes;
//!   the replay buffer keeps the pristine frame, so the receiver's CRC
//!   check fails, the conduit desyncs, and the post-reconnect replay
//!   delivers the original — exactly-once survives corruption.
//!
//! A disabled shaper is `None` at the call site: no shaper code runs at
//! all on an unshaped boundary, asserted by the [`hot_touches`] counter
//! regression test rather than a flaky wall-clock comparison.

use super::trace::BandwidthTrace;
use crate::util::rng::Rng;
use crate::util::sync::TrackedMutex;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::{Duration, Instant};

/// Upper bound (seconds) on any single shaping stall. A trace that pins
/// capacity at zero forever, or a pathological partition window, must
/// degrade into bounded stalls (which the resilience layer treats as an
/// outage) instead of hanging a sender thread for good.
const MAX_STALL_SECS: f64 = 30.0;

/// Every byte-flip lands in the trailing `CORRUPT_TAIL` bytes of the
/// frame's wire image: that region is payload and/or the CRC32 field for
/// every legal frame, so a flip is *guaranteed* to fail the CRC check
/// (never to forge a parseable header with a mangled seq, which the
/// session would treat as a protocol violation rather than line noise).
const CORRUPT_TAIL: usize = 4;

/// Global count of shaper hot-path decisions, across all shapers. The
/// zero-cost-when-disabled regression test asserts an unshaped transfer
/// leaves this untouched — i.e. no shaper code ran at all. Observe it
/// through a [`HotTouchScope`] in parallel test binaries; a bare
/// [`hot_touches`] read is only meaningful single-threaded.
static HOT_TOUCHES: AtomicU64 = AtomicU64::new(0);

/// Gate between the decision hot path (shared mode — an uncontended
/// read is one atomic op) and [`HotTouchScope`] observers (exclusive
/// mode). Leaf lock: nothing else is ever taken while it is held.
static OBSERVER: std::sync::RwLock<()> = std::sync::RwLock::new(());

/// How long a decision waits for an open [`HotTouchScope`] to close
/// before counting itself anyway. The timeout is what keeps a genuine
/// regression (shaper code on a supposedly-unshaped path, *inside* a
/// scope) a clean assertion failure instead of a deadlocked test
/// binary; it only ever elapses if a scope outlives it, which no
/// well-formed scope (a single short transfer) does.
const OBSERVER_PATIENCE: Duration = Duration::from_secs(5);

/// Total [`LinkShaper::decide`] / [`LinkShaper::decide_at`] calls ever
/// made in this process (see [`HOT_TOUCHES`]).
pub fn hot_touches() -> u64 {
    HOT_TOUCHES.load(Relaxed)
}

/// Count one hot-path decision, yielding to any open observation scope
/// first (bounded by [`OBSERVER_PATIENCE`]).
fn count_hot_touch() {
    // Fast path: no scope open. Poisoning is impossible to provoke here
    // (the critical sections hold no user code) but tolerated anyway.
    let gate = match OBSERVER.try_read() {
        Ok(g) => Some(g),
        Err(std::sync::TryLockError::Poisoned(e)) => Some(e.into_inner()),
        Err(std::sync::TryLockError::WouldBlock) => None,
    };
    if gate.is_some() {
        HOT_TOUCHES.fetch_add(1, Relaxed);
        return;
    }
    let deadline = Instant::now() + OBSERVER_PATIENCE;
    loop {
        std::thread::sleep(Duration::from_millis(1));
        match OBSERVER.try_read() {
            Ok(_g) => break,
            Err(std::sync::TryLockError::Poisoned(e)) => {
                let _g = e.into_inner();
                break;
            }
            Err(std::sync::TryLockError::WouldBlock) => {
                if Instant::now() >= deadline {
                    break;
                }
            }
        }
    }
    HOT_TOUCHES.fetch_add(1, Relaxed);
}

/// RAII observation window over the process-global decision counter —
/// what makes "no shaper code ran" assertions hold in a *parallel* test
/// binary without a file-local serialization mutex.
///
/// While a scope is open it holds the [`OBSERVER`] gate exclusively:
/// decisions made by concurrent tests park briefly at the gate (they
/// stall, they do not fail) instead of polluting the window, so
/// [`HotTouchScope::delta`] over a window whose own code path is
/// genuinely shaper-free is exactly 0. Scopes serialize against each
/// other the same way. Keep the scope to one short transfer; a
/// decision that waits longer than [`OBSERVER_PATIENCE`] counts itself
/// anyway, trading a theoretical long-scope race for deadlock freedom.
pub struct HotTouchScope {
    baseline: u64,
    _gate: std::sync::RwLockWriteGuard<'static, ()>,
}

impl HotTouchScope {
    /// Open an exclusive observation window: quiesces in-flight
    /// decisions, snapshots the counter, and holds the gate until drop.
    pub fn begin() -> Self {
        let gate = OBSERVER.write().unwrap_or_else(|e| e.into_inner());
        HotTouchScope { baseline: HOT_TOUCHES.load(Relaxed), _gate: gate }
    }

    /// Decisions counted since [`HotTouchScope::begin`]. Zero iff no
    /// shaper hot-path code ran inside the window.
    pub fn delta(&self) -> u64 {
        HOT_TOUCHES.load(Relaxed).saturating_sub(self.baseline)
    }
}

/// Declarative description of one shaped link. `Default` is a no-op
/// shaper: unlimited trace, zero delay/jitter, zero probabilities.
#[derive(Debug, Clone)]
pub struct ShaperSpec {
    /// Capacity schedule the token bucket serializes frames against
    /// (seconds are measured from shaper construction).
    pub trace: BandwidthTrace,
    /// Fixed one-way delay added to every shipped frame.
    pub delay: Duration,
    /// Jitter ceiling: each shipped frame waits an extra uniform
    /// `[0, jitter)` drawn from the seeded RNG.
    pub jitter: Duration,
    /// Per-frame probability of a byte flip on the wire copy.
    pub corrupt_p: f64,
    /// Per-frame probability the frame is "lost": the carrying conduit
    /// is killed before the write, forcing reconnect + replay.
    pub loss_p: f64,
    /// Blackhole windows `(start, end)` in seconds from construction,
    /// sorted by start: a frame decided inside a window waits until the
    /// window closes before serialization even begins.
    pub partitions: Vec<(f64, f64)>,
    /// Seed for the loss/jitter/corruption draws; the whole impairment
    /// timeline is a pure function of `(spec, decision times)`.
    pub seed: u64,
}

impl Default for ShaperSpec {
    fn default() -> Self {
        ShaperSpec {
            trace: BandwidthTrace::unlimited(),
            delay: Duration::ZERO,
            jitter: Duration::ZERO,
            corrupt_p: 0.0,
            loss_p: 0.0,
            partitions: Vec::new(),
            seed: 0,
        }
    }
}

/// What the shaper decided for one frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Verdict {
    /// The link ate the frame: kill the carrying conduit *instead of*
    /// writing, and let the session replay the tail on reconnect.
    Lose,
    /// Ship the frame after sleeping `delay` on the sender thread;
    /// `corrupt_at` is the byte index to flip in a throwaway wire copy
    /// (`None` = write the pristine bytes).
    Ship {
        /// Sender-thread sleep before the write (serialization + fixed
        /// delay + jitter + any partition-window remainder).
        delay: Duration,
        /// Byte index to flip in the wire copy, if corruption fired.
        corrupt_at: Option<usize>,
    },
}

/// Counter snapshot for assertions and reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShaperStats {
    /// Frames decided (shipped + lost).
    pub frames: u64,
    /// Frames turned into conduit kills.
    pub lost: u64,
    /// Frames shipped with a flipped byte.
    pub corrupted: u64,
    /// Total sender-thread stall the shaper imposed, in microseconds.
    pub stalled_us: u64,
}

/// Mutable decision state: one RNG stream plus the token bucket's
/// "earliest instant the link is free" horizon.
struct ShaperState {
    rng: Rng,
    /// Seconds-from-epoch when the previously queued bytes finish
    /// serializing; the next frame queues behind it.
    next_free: f64,
}

/// One shaped link. Shared (`Arc`) by however many stripes the scenario
/// says ride the same physical medium: a shared shaper means a shared
/// token bucket, i.e. boundary-level capacity; distinct shapers mean
/// per-stripe capacity.
pub struct LinkShaper {
    spec: ShaperSpec,
    epoch: Instant,
    state: TrackedMutex<ShaperState>,
    frames: AtomicU64,
    lost: AtomicU64,
    corrupted: AtomicU64,
    stalled_us: AtomicU64,
}

impl std::fmt::Debug for LinkShaper {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LinkShaper").field("spec", &self.spec).finish_non_exhaustive()
    }
}

impl LinkShaper {
    /// Shaper from a spec; the trace/partition clock starts now.
    pub fn new(spec: ShaperSpec) -> Self {
        let rng = Rng::seed(spec.seed);
        LinkShaper {
            spec,
            epoch: Instant::now(),
            state: TrackedMutex::new("shaper.state", ShaperState { rng, next_free: 0.0 }),
            frames: AtomicU64::new(0),
            lost: AtomicU64::new(0),
            corrupted: AtomicU64::new(0),
            stalled_us: AtomicU64::new(0),
        }
    }

    /// The spec this shaper renders.
    pub fn spec(&self) -> &ShaperSpec {
        &self.spec
    }

    /// Decide the fate of one `wire_len`-byte frame at the current
    /// wall-clock offset from construction.
    pub fn decide(&self, wire_len: usize) -> Verdict {
        self.decide_at(self.epoch.elapsed().as_secs_f64(), wire_len)
    }

    /// [`LinkShaper::decide`] at an explicit time offset (seconds from
    /// epoch) — the deterministic entry point scenario tests replay.
    ///
    /// Exactly four RNG draws happen per decision regardless of which
    /// impairments are enabled, so the impairment timeline of a seed is
    /// invariant under toggling individual probabilities.
    pub fn decide_at(&self, now: f64, wire_len: usize) -> Verdict {
        count_hot_touch();
        self.frames.fetch_add(1, Relaxed);
        let mut st = self.state.guard();
        let loss_draw = st.rng.f64();
        let jitter_draw = st.rng.f64();
        let corrupt_draw = st.rng.f64();
        let tail_draw = st.rng.usize(1, CORRUPT_TAIL + 1);
        if loss_draw < self.spec.loss_p {
            drop(st);
            self.lost.fetch_add(1, Relaxed);
            return Verdict::Lose;
        }
        // Token bucket first: queue behind bytes still serializing.
        // Partition windows then push the serialization start past their
        // end — looped to a fixpoint, because bucket backlog can queue a
        // frame *into* a window and one window's end can land inside the
        // next (windows never move a start backward, so this terminates
        // after at most `partitions.len()` passes).
        let mut start = now.max(st.next_free);
        loop {
            let before = start;
            for &(a, b) in &self.spec.partitions {
                if start >= a && start < b {
                    start = b;
                }
            }
            if start == before {
                break;
            }
        }
        // Pay this frame's serialization at the trace's capacity.
        let ser = self.spec.trace.transmit_secs(wire_len, start).min(MAX_STALL_SECS);
        st.next_free = start + ser;
        let wait = (st.next_free - now).max(0.0)
            + self.spec.delay.as_secs_f64()
            + self.spec.jitter.as_secs_f64() * jitter_draw;
        drop(st);
        let wait = wait.clamp(0.0, MAX_STALL_SECS);
        let corrupt_at = if corrupt_draw < self.spec.corrupt_p {
            self.corrupted.fetch_add(1, Relaxed);
            Some(wire_len.saturating_sub(tail_draw))
        } else {
            None
        };
        self.stalled_us.fetch_add((wait * 1e6) as u64, Relaxed);
        Verdict::Ship { delay: Duration::from_secs_f64(wait), corrupt_at }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ShaperStats {
        ShaperStats {
            frames: self.frames.load(Relaxed),
            lost: self.lost.load(Relaxed),
            corrupted: self.corrupted.load(Relaxed),
            stalled_us: self.stalled_us.load(Relaxed),
        }
    }
}

/// Build the corrupted wire image for a [`Verdict::Ship`] with
/// `corrupt_at`: copy `bytes` into `out` and XOR-flip the byte at `at`.
/// The caller writes `out` to the socket while the replay buffer keeps
/// the pristine `bytes`.
pub fn corrupt_into(bytes: &[u8], at: usize, out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(bytes);
    if let Some(b) = out.get_mut(at) {
        *b ^= 0xA5;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::mbps;

    fn verdicts(spec: &ShaperSpec, times: &[f64], wire: usize) -> Vec<Verdict> {
        let sh = LinkShaper::new(spec.clone());
        times.iter().map(|&t| sh.decide_at(t, wire)).collect()
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = ShaperSpec {
            trace: BandwidthTrace::constant(mbps(8.0)),
            jitter: Duration::from_millis(5),
            corrupt_p: 0.3,
            loss_p: 0.3,
            seed: 7,
            ..ShaperSpec::default()
        };
        let times: Vec<f64> = (0..64).map(|i| i as f64 * 0.01).collect();
        let a = verdicts(&spec, &times, 4096);
        let b = verdicts(&spec, &times, 4096);
        assert_eq!(a, b);
        let other = ShaperSpec { seed: 8, ..spec };
        assert_ne!(a, verdicts(&other, &times, 4096));
    }

    #[test]
    fn token_bucket_serializes_at_trace_rate() {
        // 8 Mbps = 1 MB/s: a 100 KB frame takes 0.1 s, and a second
        // frame decided at the same instant queues behind the first.
        let sh = LinkShaper::new(ShaperSpec {
            trace: BandwidthTrace::constant(mbps(8.0)),
            ..ShaperSpec::default()
        });
        let d1 = match sh.decide_at(0.0, 100_000) {
            Verdict::Ship { delay, .. } => delay.as_secs_f64(),
            v => panic!("unexpected {v:?}"),
        };
        let d2 = match sh.decide_at(0.0, 100_000) {
            Verdict::Ship { delay, .. } => delay.as_secs_f64(),
            v => panic!("unexpected {v:?}"),
        };
        assert!((d1 - 0.1).abs() < 1e-6, "{d1}");
        assert!((d2 - 0.2).abs() < 1e-6, "{d2}");
        // After the queue drains (t=1.0) the bucket is free again.
        let d3 = match sh.decide_at(1.0, 100_000) {
            Verdict::Ship { delay, .. } => delay.as_secs_f64(),
            v => panic!("unexpected {v:?}"),
        };
        assert!((d3 - 0.1).abs() < 1e-6, "{d3}");
    }

    #[test]
    fn partition_window_blocks_until_close() {
        let sh = LinkShaper::new(ShaperSpec {
            partitions: vec![(1.0, 1.5)],
            ..ShaperSpec::default()
        });
        match sh.decide_at(1.2, 1024) {
            Verdict::Ship { delay, .. } => {
                let d = delay.as_secs_f64();
                assert!((d - 0.3).abs() < 1e-6, "{d}");
            }
            v => panic!("unexpected {v:?}"),
        }
        // Outside the window: instant (unlimited trace, no delay).
        match sh.decide_at(2.0, 1024) {
            Verdict::Ship { delay, .. } => assert_eq!(delay, Duration::ZERO),
            v => panic!("unexpected {v:?}"),
        }
    }

    #[test]
    fn bucket_backlog_queued_into_a_window_waits_it_out() {
        // 8 Mbps = 1 MB/s: the first frame (800 KB, decided at t=0.4)
        // serializes until t=1.2 — *inside* the (1.0, 1.5) window. The
        // second frame queues behind it and must not serialize through
        // the blackhole: its start snaps to the window close, so it
        // finishes at 1.5 + 0.1, a 1.2 s wait from its decision at 0.4.
        let sh = LinkShaper::new(ShaperSpec {
            trace: BandwidthTrace::constant(mbps(8.0)),
            partitions: vec![(1.0, 1.5)],
            ..ShaperSpec::default()
        });
        let d1 = match sh.decide_at(0.4, 800_000) {
            Verdict::Ship { delay, .. } => delay.as_secs_f64(),
            v => panic!("unexpected {v:?}"),
        };
        assert!((d1 - 0.8).abs() < 1e-6, "{d1}");
        let d2 = match sh.decide_at(0.4, 100_000) {
            Verdict::Ship { delay, .. } => delay.as_secs_f64(),
            v => panic!("unexpected {v:?}"),
        };
        assert!((d2 - 1.2).abs() < 1e-6, "{d2}");
    }

    #[test]
    fn certain_loss_and_certain_corruption() {
        let lossy = LinkShaper::new(ShaperSpec { loss_p: 1.0, ..ShaperSpec::default() });
        assert_eq!(lossy.decide_at(0.0, 512), Verdict::Lose);
        assert_eq!(lossy.stats().lost, 1);
        let dirty = LinkShaper::new(ShaperSpec { corrupt_p: 1.0, ..ShaperSpec::default() });
        for _ in 0..32 {
            match dirty.decide_at(0.0, 512) {
                Verdict::Ship { corrupt_at: Some(at), .. } => {
                    // Trailing CORRUPT_TAIL bytes only: payload/CRC, so a
                    // flip always fails the CRC check at the receiver.
                    assert!(at >= 512 - CORRUPT_TAIL && at < 512, "{at}");
                }
                v => panic!("unexpected {v:?}"),
            }
        }
        assert_eq!(dirty.stats().corrupted, 32);
    }

    #[test]
    fn corrupt_copy_flips_exactly_one_byte() {
        let frame = crate::net::frame::Frame::new(
            3,
            vec![64],
            crate::quant::codec::Encoded {
                params: None,
                elems: 64,
                payload: vec![0x11; 256],
                tiled: false,
            },
        );
        let wire = frame.to_bytes();
        let mut out = Vec::new();
        corrupt_into(&wire, wire.len() - 2, &mut out);
        assert_eq!(out.len(), wire.len());
        let diff = wire.iter().zip(&out).filter(|(a, b)| a != b).count();
        assert_eq!(diff, 1);
        // And the flip is detected as line noise, not parsed as a frame.
        assert!(crate::net::frame::Frame::from_bytes(&out).is_err());
        assert!(crate::net::frame::Frame::from_bytes(&wire).is_ok());
    }

    #[test]
    fn dead_trace_stall_is_clamped() {
        let sh = LinkShaper::new(ShaperSpec {
            trace: BandwidthTrace::constant(0.0),
            ..ShaperSpec::default()
        });
        match sh.decide_at(0.0, 1024) {
            Verdict::Ship { delay, .. } => {
                assert!(delay.as_secs_f64() <= MAX_STALL_SECS + 1e-9);
            }
            v => panic!("unexpected {v:?}"),
        }
    }

    #[test]
    fn hot_touch_scope_window_is_exact_and_exclusive() {
        // An open scope quiesces the gate: no decision can land in the
        // window, so delta is exactly 0 however many parallel tests are
        // exercising shapers right now.
        let scope = HotTouchScope::begin();
        assert_eq!(scope.delta(), 0);
        drop(scope);
        // Outside any scope, decisions land on the counter immediately.
        let sh = LinkShaper::new(ShaperSpec::default());
        let before = hot_touches();
        sh.decide_at(0.0, 1024);
        assert!(hot_touches() > before, "decision not counted");
    }

    #[test]
    fn stats_accumulate() {
        let sh = LinkShaper::new(ShaperSpec {
            trace: BandwidthTrace::constant(mbps(80.0)),
            loss_p: 0.5,
            seed: 3,
            ..ShaperSpec::default()
        });
        for _ in 0..64 {
            sh.decide_at(0.0, 10_000);
        }
        let s = sh.stats();
        assert_eq!(s.frames, 64);
        assert!(s.lost > 10 && s.lost < 54, "{}", s.lost);
        assert!(s.stalled_us > 0);
    }
}
