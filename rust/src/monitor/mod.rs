//! Runtime monitor (paper §3): per-stage windowed measurements.
//!
//! "As is common in adaptive runtime systems, QuantPipe measures relevant
//! metrics over a window period, then makes an adaptive decision based on
//! the window average values" (§4.2: window = 50 microbatches). The
//! monitor tracks, per window:
//!
//! * **output bandwidth** `B_i` — payload bytes sent ÷ link-occupied time
//!   (what the link actually sustained, i.e. the measured capacity);
//! * **output rate** — images/sec leaving the stage (compared against the
//!   target rate `R` to detect violation);
//! * **quantized volume** `V` — mean wire bytes per microbatch (Eq. 2's
//!   numerator component).
//!
//! The monitor never reads the bandwidth trace — capacity is *inferred*
//! from measurements, exactly as on the paper's testbed. Timestamps are
//! passed in explicitly so tests drive a virtual clock.

use std::time::Instant;

/// One completed window's averages.
#[derive(Debug, Clone, Copy)]
pub struct WindowStats {
    /// Measured output bandwidth, bits/sec (wire bytes ÷ link busy time).
    /// `f64::INFINITY` when the link was never measurably busy — an
    /// *intentional* in-memory sentinel the controller branches on
    /// ("unconstrained link"). Serialization boundaries must clamp or
    /// omit it: JSON has no Infinity (`Timeline::to_json` omits, the CSV
    /// encodes -1).
    pub bandwidth_bps: f64,
    /// Achieved output rate, images/sec over the window wall time.
    pub rate: f64,
    /// Mean wire bytes per microbatch (V in Eq. 2).
    pub mean_bytes: f64,
    /// Microbatches in the window.
    pub microbatches: u64,
    /// Wall time covered, seconds.
    pub wall_secs: f64,
    /// Fraction of wall time the link was busy (≈1.0 ⇒ comm-bound).
    pub link_utilization: f64,
}

/// Sliding-window accumulator fed by the stage's send loop.
#[derive(Debug)]
pub struct WindowMonitor {
    window: u64,
    batch: usize,
    bytes: u64,
    busy_secs: f64,
    count: u64,
    window_start: Option<Instant>,
    last: Option<WindowStats>,
}

impl WindowMonitor {
    /// `window` = microbatches per adaptive decision (paper: 50);
    /// `batch` = images per microbatch (paper: 64).
    pub fn new(window: u64, batch: usize) -> Self {
        WindowMonitor {
            window: window.max(1),
            batch,
            bytes: 0,
            busy_secs: 0.0,
            count: 0,
            window_start: None,
            last: None,
        }
    }

    /// Record one sent microbatch at time `now`: wire bytes + seconds the
    /// link was busy. Returns `Some(stats)` when a window just completed.
    pub fn record_send_at(&mut self, wire_bytes: usize, busy_secs: f64, now: Instant) -> Option<WindowStats> {
        let start = *self.window_start.get_or_insert(now);
        self.bytes += wire_bytes as u64;
        self.busy_secs += busy_secs;
        self.count += 1;
        if self.count < self.window {
            return None;
        }
        let wall = now.duration_since(start).as_secs_f64().max(1e-9);
        let stats = WindowStats {
            bandwidth_bps: if self.busy_secs > 1e-9 {
                self.bytes as f64 * 8.0 / self.busy_secs
            } else {
                f64::INFINITY // link never measurably busy ⇒ unconstrained
            },
            rate: (self.count * self.batch as u64) as f64 / wall,
            mean_bytes: self.bytes as f64 / self.count as f64,
            microbatches: self.count,
            wall_secs: wall,
            link_utilization: (self.busy_secs / wall).min(1.0),
        };
        self.bytes = 0;
        self.busy_secs = 0.0;
        self.count = 0;
        self.window_start = Some(now);
        self.last = Some(stats);
        Some(stats)
    }

    /// Convenience: record at `Instant::now()`.
    pub fn record_send(&mut self, wire_bytes: usize, busy_secs: f64) -> Option<WindowStats> {
        self.record_send_at(wire_bytes, busy_secs, Instant::now())
    }

    /// Most recently completed window, if any.
    pub fn last(&self) -> Option<WindowStats> {
        self.last
    }

    /// Window length in microbatches.
    pub fn window_len(&self) -> u64 {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn t(epoch: Instant, ms: u64) -> Instant {
        epoch + Duration::from_millis(ms)
    }

    #[test]
    fn window_boundaries() {
        let epoch = Instant::now();
        let mut m = WindowMonitor::new(3, 64);
        assert!(m.record_send_at(1000, 0.001, t(epoch, 0)).is_none());
        assert!(m.record_send_at(1000, 0.001, t(epoch, 100)).is_none());
        let s = m.record_send_at(1000, 0.001, t(epoch, 200)).unwrap();
        assert_eq!(s.microbatches, 3);
        assert!((s.mean_bytes - 1000.0).abs() < 1e-9);
        assert!((s.wall_secs - 0.2).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_is_bytes_over_busy_time() {
        let epoch = Instant::now();
        let mut m = WindowMonitor::new(2, 64);
        // 2 MB over 2 s of busy time ⇒ 8 Mbps measured.
        m.record_send_at(1_000_000, 1.0, t(epoch, 0));
        let s = m.record_send_at(1_000_000, 1.0, t(epoch, 2000)).unwrap();
        assert!((s.bandwidth_bps - 8e6).abs() / 8e6 < 1e-6, "{s:?}");
        assert!(s.link_utilization > 0.99);
    }

    #[test]
    fn rate_uses_wall_time() {
        let epoch = Instant::now();
        let mut m = WindowMonitor::new(2, 64);
        m.record_send_at(10, 0.0, t(epoch, 0));
        let s = m.record_send_at(10, 0.0, t(epoch, 1000)).unwrap();
        // 2 microbatches × 64 images over 1 s wall.
        assert!((s.rate - 128.0).abs() < 1.0, "{s:?}");
        assert!(s.bandwidth_bps.is_infinite());
        assert!(s.link_utilization < 0.01);
    }

    #[test]
    fn window_resets_after_report() {
        let epoch = Instant::now();
        let mut m = WindowMonitor::new(2, 1);
        m.record_send_at(100, 0.1, t(epoch, 0));
        assert!(m.record_send_at(100, 0.1, t(epoch, 10)).is_some());
        // New window starts clean at the report instant.
        assert!(m.record_send_at(999, 0.9, t(epoch, 20)).is_none());
        assert_eq!(m.last().unwrap().mean_bytes, 100.0);
        let s2 = m.record_send_at(999, 0.9, t(epoch, 30)).unwrap();
        assert_eq!(s2.mean_bytes, 999.0);
        assert!((s2.wall_secs - 0.02).abs() < 1e-9);
    }

    #[test]
    fn utilization_capped_at_one() {
        let epoch = Instant::now();
        let mut m = WindowMonitor::new(1, 1);
        // busy 2 s inside 1 s wall (overlapped sends) ⇒ clamp to 1.0.
        m.record_send_at(10, 2.0, t(epoch, 0));
        let s = m.record_send_at(10, 2.0, t(epoch, 1000)).unwrap();
        assert_eq!(s.link_utilization, 1.0);
    }
}
