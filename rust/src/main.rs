//! QuantPipe CLI — the launcher.
//!
//! ```text
//! quantpipe run        [--config F] [--trace T] [--microbatches N]
//!                      [--method M] [--fixed-bits B] [--target-rate R]
//!                      [--timeline-csv F] [--report-json F]
//!                      [--codec-backend native|hlo]
//! quantpipe sweep      [--config F] [--bits 32,16,8,6,4,2]
//! quantpipe worker     --stage K [--listen A] [--connect A] [--mock SxD]
//! quantpipe coordinate [--config F] [--synthetic CxD] [--microbatches N]
//! quantpipe scenario   [NAME] [--scenario-seed S] [--stripes N]
//! quantpipe report     <run.json>
//! quantpipe partition  <profile.json> [--devices N]
//! quantpipe inspect    [--artifacts DIR]
//! ```
//!
//! `run`/`sweep` drive the single-process pipeline over shaped in-proc
//! links. `worker`/`coordinate` deploy the same pipeline across real TCP
//! sockets, one stage per process (config `transport` section or
//! `--listen`/`--connect` flags); bandwidth is then *measured* from
//! socket backpressure, never simulated.
//!
//! Arg parsing is hand-rolled (offline build: no clap).

use quantpipe::adapt::AdaptConfig;
use quantpipe::config::Config;
use quantpipe::data::EvalSet;
use quantpipe::metrics::ResilienceStats;
use quantpipe::net::link::SimLink;
use quantpipe::net::resilient::{ReconnectingRx, ReconnectingTx};
use quantpipe::net::scenario::ScenarioKind;
use quantpipe::net::stripe::{StripedRx, StripedTx};
use quantpipe::net::tcp;
use quantpipe::net::transport::{FrameRx, FrameTx, LinkSpec};
use quantpipe::partition::CostModel;
use quantpipe::pipeline::{
    self, hlo_stage_factory, mock_stage_factory, run_coordinator, run_serving_coordinator,
    run_worker, LinkQuant, PipelineSpec, ServeConfig, ServeWorkload, StageFactory, StreamSpec,
    WorkerConfig, Workload,
};
use quantpipe::quant::Method;
use quantpipe::runtime::Manifest;
use quantpipe::util::json::Value;
use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

const USAGE: &str = "\
quantpipe — adaptive PTQ for distributed transformer pipelines (QuantPipe reproduction)

USAGE:
  quantpipe run        [--config F] [--trace T] [--microbatches N] [--method M]
                       [--fixed-bits B] [--target-rate R] [--timeline-csv F]
                       [--report-json F] [--codec-backend native|hlo] [--artifacts DIR]
  quantpipe sweep      [--config F] [--bits 32,16,8,6,4,2] [--artifacts DIR]
  quantpipe worker     --stage K [--config F] [--listen ADDR] [--connect ADDR]
                       [--stages N] [--mock SxD] [--fixed-bits B] [--target-rate R]
                       [--resilient BOOL] [--stripes N] [--report-json F]
                       [--scenario NAME] [--scenario-seed S] [--artifacts DIR]
  quantpipe coordinate [--config F] [--microbatches N] [--synthetic CxD]
                       [--resilient BOOL] [--stripes N] [--report-json F]
                       [--scenario NAME] [--scenario-seed S] [--artifacts DIR]
                       [--max-streams N] [--stream-queue-depth D] [--streams W:M,W:M,…]
  quantpipe scenario   [NAME] [--scenario-seed S] [--stripes N]
  quantpipe report     <run.json>
  quantpipe partition  <profile.json> [--devices N]
  quantpipe inspect    [--artifacts DIR]

Multi-process mode: start `coordinate` plus one `worker` per stage (any
order; connects retry). Worker k listens on transport.stage_addrs[k] and
connects to stage k+1 (the last worker connects to transport.sink_addr).
`--mock 64x16` / `--synthetic 256x16` run without AOT artifacts.
`--resilient true` (or transport.resilient) survives transient link
failures: reconnect + sequenced replay + FIN/FIN_ACK drain; every
process in the chain must agree on the flag.
`--stripes N` (or transport.stripes; requires resilient) fans every stage
boundary over N TCP connections sharing one sequence space — for
high-BDP/multi-path edge links. All stripes dial the same stage address;
every process in the chain must agree on the value.
Every worker streams per-window telemetry forward to the coordinator
(transport.telemetry, default on), which merges all stages into one
PipelineReport: `coordinate --report-json run.json` persists it and
`quantpipe report run.json` renders it.
`--scenario NAME` (or transport.scenario; requires resilient) imposes a
named, seeded chaos schedule — trace-driven rate fades, delay+jitter,
corruption, loss, stripe partitions — on this process's outgoing links
(docs/SCENARIOS.md). Deterministic per `--scenario-seed`; shaping is
sender-side, so configure it on the processes that send. `quantpipe
scenario` lists the names; `quantpipe scenario NAME` prints its timeline.
Multi-stream serving: `--max-streams N` (or pipeline.max_streams) > 1
turns `coordinate` into a serving front-end — N concurrent client
sessions interleave through the one stage chain under weighted
round-robin with bounded per-stream queues (`--stream-queue-depth`,
pipeline.stream_queue_depth). `--streams 4:40,1:10` spells each client
out as WEIGHT:MICROBATCHES (must fit within --max-streams); without it
the run's microbatches split evenly across N weight-1 streams. The
report gains per-stream frame counts, backpressure stalls and
completion-latency percentiles.
";

/// Tiny flag parser: --key value pairs + positionals.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> quantpipe::Result<Args> {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "worker" => cmd_worker(&args),
        "coordinate" => cmd_coordinate(&args),
        "scenario" => cmd_scenario(&args),
        "report" => cmd_report(&args),
        "partition" => cmd_partition(&args),
        "inspect" => cmd_inspect(&args),
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> quantpipe::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(p) => Config::load(p)?,
        None => Config::default(),
    };
    if let Some(t) = args.get("trace") {
        cfg.net.traces = vec![t.to_string()];
    }
    if let Some(m) = args.get("microbatches") {
        cfg.run.microbatches = m.parse()?;
    }
    if let Some(m) = args.get("method") {
        cfg.quant.method = parse_method(m)?;
    }
    if let Some(b) = args.get("fixed-bits") {
        cfg.adapt.enabled = false;
        cfg.adapt.fixed_bits = b.parse()?;
    }
    if let Some(r) = args.get("target-rate") {
        cfg.adapt.target_rate = r.parse()?;
    }
    if let Some(f) = args.get("timeline-csv") {
        cfg.run.timeline_csv = f.to_string();
    }
    if let Some(f) = args.get("report-json") {
        cfg.run.report_json = f.to_string();
    }
    if let Some(cb) = args.get("codec-backend") {
        cfg.pipeline.codec_backend = cb.to_string();
    }
    if let Some(a) = args.get("artifacts") {
        cfg.run.artifacts = a.to_string();
    }
    if let Some(r) = args.get("resilient") {
        cfg.transport.resilient = parse_bool(r)?;
    }
    if let Some(s) = args.get("stripes") {
        cfg.transport.stripes = s.parse()?;
        anyhow::ensure!(cfg.transport.stripes >= 1, "--stripes must be >= 1");
    }
    if let Some(s) = args.get("scenario") {
        // Unknown names fail here, loudly, listing the valid set.
        ScenarioKind::parse(s)?;
        cfg.transport.scenario = s.to_string();
    }
    if let Some(s) = args.get("scenario-seed") {
        cfg.transport.scenario_seed = s
            .parse()
            .map_err(|e| anyhow::anyhow!("--scenario-seed wants a non-negative integer: {e}"))?;
    }
    if let Some(s) = args.get("max-streams") {
        cfg.pipeline.max_streams = s.parse()?;
        anyhow::ensure!(cfg.pipeline.max_streams >= 1, "--max-streams must be >= 1");
    }
    if let Some(s) = args.get("stream-queue-depth") {
        cfg.pipeline.stream_queue_depth = s.parse()?;
        anyhow::ensure!(cfg.pipeline.stream_queue_depth >= 1, "--stream-queue-depth must be >= 1");
    }
    // Re-validate after CLI overrides (the config parser enforces the
    // same invariants for file-borne settings).
    anyhow::ensure!(
        cfg.transport.stripes == 1 || cfg.transport.resilient,
        "--stripes > 1 requires resilient links (--resilient true): the striped boundary \
         rides the resilient session protocol"
    );
    anyhow::ensure!(
        cfg.transport.scenario == "none" || cfg.transport.resilient,
        "--scenario requires resilient links (--resilient true): chaos shaping expresses \
         loss and corruption as conduit death, which only the session protocol survives"
    );
    // Process-wide: every codec in this process honours the knob, and the
    // scalar fallback keeps the wire bytes identical either way.
    quantpipe::quant::fused::set_simd_enabled(cfg.pipeline.codec_simd);
    Ok(cfg)
}

fn parse_bool(s: &str) -> quantpipe::Result<bool> {
    match s {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        other => anyhow::bail!("expected a boolean (true/false), got {other:?}"),
    }
}

fn parse_method(s: &str) -> quantpipe::Result<Method> {
    Ok(match s {
        "naive" => Method::Naive,
        "aciq" => Method::Aciq,
        "ds_aciq" => Method::DsAciq,
        "pda" => Method::Pda,
        other => anyhow::bail!("unknown method {other:?}"),
    })
}

/// Build a PipelineSpec from config + artifacts.
fn build_spec(cfg: &Config, manifest: &Manifest, dir: &std::path::Path) -> quantpipe::Result<PipelineSpec> {
    let n = manifest.stages.len();
    let hlo_codec = cfg.pipeline.codec_backend == "hlo";
    let stages = (0..n)
        .map(|i| hlo_stage_factory(dir.to_path_buf(), manifest.clone(), i, hlo_codec))
        .collect();
    let links = (0..n - 1)
        .map(|i| {
            Ok(LinkSpec::Sim(Arc::new(SimLink::with_faults(
                cfg.trace_for_link(i)?,
                std::time::Duration::from_micros(cfg.net.latency_us),
                cfg.link_faults(),
            ))))
        })
        .collect::<quantpipe::Result<_>>()?;
    let quant = LinkQuant {
        method: cfg.quant.method,
        calib_every: cfg.quant.calib_every,
        initial_bits: if cfg.adapt.enabled { 32 } else { cfg.adapt.fixed_bits },
        codec_threads: cfg.pipeline.codec_threads,
        tile_elems: cfg.pipeline.tile_elems,
        outlier_frac: cfg.pipeline.outlier_frac,
    };
    let adapt: Option<AdaptConfig> = if cfg.adapt.enabled {
        let mut a = cfg.adapt_config()?;
        a.microbatch = manifest.microbatch;
        Some(a)
    } else {
        None
    };
    Ok(PipelineSpec {
        stages,
        links,
        quant,
        adapt,
        window: cfg.adapt.window,
        inflight: cfg.pipeline.inflight,
    })
}

/// `run`/`sweep` drive the single-process simulated pipeline; reject a
/// multi-process config instead of silently simulating it.
fn ensure_inproc(cfg: &Config, cmd: &str) -> quantpipe::Result<()> {
    anyhow::ensure!(
        cfg.transport.mode != "tcp",
        "transport.mode is \"tcp\": use `quantpipe coordinate` + `quantpipe worker` \
         for multi-process runs (`{cmd}` drives the single-process simulated pipeline)"
    );
    // Shapers attach to real socket conduits; silently ignoring a chaos
    // scenario on the simulated link would fake clean "chaos" results.
    anyhow::ensure!(
        cfg.transport.scenario == "none",
        "transport.scenario {:?} needs real sockets (`quantpipe coordinate`/`worker`); \
         `{cmd}` shapes its in-process link with --trace instead",
        cfg.transport.scenario
    );
    Ok(())
}

fn cmd_run(args: &Args) -> quantpipe::Result<()> {
    let cfg = load_config(args)?;
    ensure_inproc(&cfg, "run")?;
    let (manifest, dir) = Manifest::load(&cfg.run.artifacts)?;
    let eval = Arc::new(EvalSet::load(dir.join(&manifest.eval.file))?);
    let spec = build_spec(&cfg, &manifest, &dir)?;
    let s = manifest.microbatch;
    let workload = if cfg.run.microbatches == 0 {
        Workload::one_pass(eval, s)
    } else {
        Workload::repeat(eval, s, cfg.run.microbatches)
    };

    let report = pipeline::run(spec, workload)?;

    println!("== QuantPipe run ==");
    println!("microbatches      {}", report.microbatches);
    println!("images            {}", report.images);
    println!("wall              {:.2}s", report.wall_secs);
    println!("throughput        {:.1} img/s", report.throughput);
    println!("top-1 accuracy    {:.2}%", report.accuracy * 100.0);
    println!(
        "p50/p99 latency   {:?} / {:?}",
        report.latency.quantile(0.5),
        report.latency.quantile(0.99)
    );
    println!("link0 mean bytes  {:.0} B/microbatch", report.link0_mean_bytes);
    println!(
        "stage compute     {:?} ms",
        report
            .stage_compute_s
            .iter()
            .map(|s| (s * 1e3 * 10.0).round() / 10.0)
            .collect::<Vec<_>>()
    );
    if let Some(bits) = report.timeline.final_bits(0) {
        println!("final bits (l0)   {bits}");
        println!("bits sequence     {:?}", report.timeline.bits_sequence(0));
    }
    if !report.errors.is_empty() {
        eprintln!("link/stage failures during the run:");
        for e in &report.errors {
            eprintln!("  - {e}");
        }
    }
    if !cfg.run.timeline_csv.is_empty() {
        std::fs::write(&cfg.run.timeline_csv, report.timeline.to_csv())?;
        println!("timeline          -> {}", cfg.run.timeline_csv);
    }
    if !cfg.run.report_json.is_empty() {
        std::fs::write(&cfg.run.report_json, report.to_json().to_string_pretty())?;
        println!("report            -> {}", cfg.run.report_json);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Multi-process mode: one stage per `worker` process, `coordinate` is
// source + sink. See the `transport` config section for the topology.
// ---------------------------------------------------------------------------

/// Build (and announce) the configured chaos scenario's per-stripe
/// shapers for this process's outgoing links. `None` when the scenario
/// is "none" — the write path then has zero shaper code on it.
fn scenario_shapers(
    cfg: &Config,
    who: &str,
) -> quantpipe::Result<Option<Vec<Option<Arc<quantpipe::net::shaper::LinkShaper>>>>> {
    let kind = cfg.transport.scenario_kind()?;
    if kind == ScenarioKind::None {
        return Ok(None);
    }
    let seed = cfg.transport.scenario_seed;
    eprintln!("[{who}] chaos scenario {} (seed {seed}) on outgoing links:", kind.name());
    for line in kind.timeline(seed, cfg.transport.stripes) {
        eprintln!("[{who}]   {line}");
    }
    Ok(Some(kind.build(seed, cfg.transport.stripes)))
}

/// Print a chaos scenario's deterministic timeline, or list them all.
fn cmd_scenario(args: &Args) -> quantpipe::Result<()> {
    let seed: u64 = args.get("scenario-seed").map(str::parse).transpose()?.unwrap_or(0);
    let stripes: usize = args.get("stripes").map(str::parse).transpose()?.unwrap_or(3);
    anyhow::ensure!(stripes >= 1, "--stripes must be >= 1");
    match args.positional.first() {
        Some(name) => {
            let kind = ScenarioKind::parse(name)?;
            println!("scenario {} (seed {seed}, {stripes} stripes):", kind.name());
            for line in kind.timeline(seed, stripes) {
                println!("  {line}");
            }
        }
        None => {
            println!("available scenarios (inspect one: quantpipe scenario NAME):");
            for k in ScenarioKind::all() {
                println!("  {}", k.name());
            }
            println!("  none (the default: the unshaped write path)");
        }
    }
    Ok(())
}

/// Parse "AxB" (e.g. `--mock 64x16`, `--synthetic 256x16`).
fn parse_pair(s: &str, what: &str) -> quantpipe::Result<(usize, usize)> {
    let (a, b) = s
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("{what} wants AxB (e.g. 64x16), got {s:?}"))?;
    Ok((a.trim().parse()?, b.trim().parse()?))
}

/// Parse `--streams W:M,W:M,…` — one WEIGHT:MICROBATCHES entry per
/// client stream (e.g. `--streams 4:40,1:10,1:10`).
fn parse_streams(s: &str) -> quantpipe::Result<Vec<StreamSpec>> {
    s.split(',')
        .map(|e| {
            let (w, m) = e.split_once(':').ok_or_else(|| {
                anyhow::anyhow!("--streams wants WEIGHT:MICROBATCHES entries, got {e:?}")
            })?;
            let spec = StreamSpec { weight: w.trim().parse()?, microbatches: m.trim().parse()? };
            anyhow::ensure!(spec.microbatches > 0, "--streams entry {e:?} offers no microbatches");
            Ok(spec)
        })
        .collect()
}

/// Without an explicit `--streams` spec, split the run's microbatches
/// evenly across `n` weight-1 clients (earlier streams take the
/// remainder).
fn even_streams(total: u64, n: usize) -> Vec<StreamSpec> {
    let n64 = n as u64;
    (0..n64)
        .map(|i| StreamSpec {
            weight: 1,
            microbatches: total / n64 + u64::from(i < total % n64),
        })
        .collect()
}

fn cmd_worker(args: &Args) -> quantpipe::Result<()> {
    let cfg = load_config(args)?;
    if cfg.transport.reactor_pin_core >= 0 {
        quantpipe::net::reactor::set_pin_core(cfg.transport.reactor_pin_core as usize);
    }
    let stage: usize = args
        .get("stage")
        .ok_or_else(|| anyhow::anyhow!("worker needs --stage K"))?
        .parse()?;

    // Stage compute: a real HLO shard, or a mock for artifact-free runs.
    let (factory, n_stages, microbatch): (StageFactory, usize, usize) =
        if let Some(shape) = args.get("mock") {
            let (s, d) = parse_pair(shape, "--mock")?;
            let n: usize = args
                .get("stages")
                .map(str::parse::<usize>)
                .transpose()?
                .unwrap_or(cfg.pipeline.stages);
            (mock_stage_factory(1.0, 0.0, vec![s, d], Duration::ZERO), n, s)
        } else {
            let (manifest, dir) = Manifest::load(&cfg.run.artifacts)?;
            let hlo_codec = cfg.pipeline.codec_backend == "hlo";
            let n = manifest.stages.len();
            anyhow::ensure!(stage < n, "stage {stage} out of range (artifacts have {n})");
            let mb = manifest.microbatch;
            (hlo_stage_factory(dir, manifest, stage, hlo_codec), n, mb)
        };
    anyhow::ensure!(stage < n_stages, "stage {stage} out of range ({n_stages} stages)");
    let is_last = stage + 1 == n_stages;

    let listen = args
        .get("listen")
        .map(str::to_string)
        .or_else(|| cfg.transport.stage_addrs.get(stage).cloned())
        .ok_or_else(|| anyhow::anyhow!("worker {stage} needs --listen or transport.stage_addrs[{stage}]"))?;
    let connect = args
        .get("connect")
        .map(str::to_string)
        .or_else(|| {
            if is_last {
                Some(cfg.transport.sink_addr.clone())
            } else {
                cfg.transport.stage_addrs.get(stage + 1).cloned()
            }
        })
        .ok_or_else(|| anyhow::anyhow!("worker {stage} needs --connect or a transport address for stage {}", stage + 1))?;

    let listener = TcpListener::bind(&listen)?;
    eprintln!(
        "[worker {stage}] listening on {listen}, downstream {connect} (last={is_last}, resilient={}, stripes={})",
        cfg.transport.resilient, cfg.transport.stripes
    );
    let (up_rx, down_tx): (Box<dyn FrameRx>, Box<dyn FrameTx>) = if cfg.transport.stripes > 1 {
        // Striped boundary: one session, N connections per link. The
        // upstream listener multiplexes however many stripes dial in;
        // the downstream side dials `stripes` conduits to one address.
        let rcfg = cfg.transport.resilience_config();
        let up = StripedRx::accept_on(
            Arc::new(listener),
            rcfg.clone(),
            Arc::new(ResilienceStats::default()),
        );
        let mut down = StripedTx::connect_to(
            connect.clone(),
            cfg.transport.stripes,
            rcfg,
            Arc::new(ResilienceStats::default()),
        );
        if let Some(shapers) = scenario_shapers(&cfg, &format!("worker {stage}"))? {
            down.set_shapers(shapers);
        }
        (Box::new(up), Box::new(down))
    } else if cfg.transport.resilient {
        // Fault-tolerant endpoints: the listener is kept so a failed
        // upstream can come back; the downstream dial redials with
        // backoff. Connections are established lazily on first use.
        let rcfg = cfg.transport.resilience_config();
        let up = ReconnectingRx::accept_on(
            Arc::new(listener),
            rcfg.clone(),
            Arc::new(ResilienceStats::default()),
        );
        let mut down = ReconnectingTx::connect_to(
            connect.clone(),
            rcfg,
            Arc::new(ResilienceStats::default()),
        );
        if let Some(shapers) = scenario_shapers(&cfg, &format!("worker {stage}"))? {
            down.set_shaper(shapers.into_iter().next().flatten());
        }
        (Box::new(up), Box::new(down))
    } else {
        let (_up_tx, up_rx) = tcp::accept_one(&listener)?;
        let (down_tx, _down_rx) = tcp::connect_retry(
            &connect,
            cfg.transport.connect_timeout(),
            cfg.transport.connect_retry(),
        )?;
        eprintln!("[worker {stage}] chain connected");
        (Box::new(up_rx), Box::new(down_tx))
    };

    let quant = LinkQuant {
        method: cfg.quant.method,
        calib_every: cfg.quant.calib_every,
        initial_bits: if cfg.adapt.enabled { 32 } else { cfg.adapt.fixed_bits },
        codec_threads: cfg.pipeline.codec_threads,
        tile_elems: cfg.pipeline.tile_elems,
        outlier_frac: cfg.pipeline.outlier_frac,
    };
    let adapt: Option<AdaptConfig> = if cfg.adapt.enabled {
        let mut a = cfg.adapt_config()?;
        a.microbatch = microbatch;
        Some(a)
    } else {
        None
    };
    let wcfg = WorkerConfig {
        stage,
        quant,
        adapt,
        window: cfg.adapt.window,
        microbatch,
        quantize_output: !is_last,
        inflight: cfg.pipeline.inflight,
        telemetry: cfg.transport.telemetry,
    };
    let report = run_worker(factory, wcfg, up_rx, down_tx)?;

    println!("== worker {stage} done ==");
    println!("frames            {}", report.frames);
    println!("mean compute      {:.2} ms", report.mean_compute_s * 1e3);
    println!("out mean bytes    {:.0} B/frame", report.out_mean_bytes);
    if !is_last {
        println!("bits sequence     {:?}", report.timeline.bits_sequence(stage));
    }
    if cfg.transport.resilient {
        let r = report.resilience;
        println!(
            "resilience        {} reconnects / {} re-accepts, {} replayed, {} deduped, {:.2}s stalled",
            r.reconnects, r.reaccepts, r.replayed, r.deduped, r.stall_secs
        );
    }
    for (i, s) in report.stripes.iter().enumerate() {
        println!(
            "stripe {i:<2}         {} frames, {} B, {} reconnects, {:.2}s stalled",
            s.frames, s.bytes, s.reconnects, s.stall_secs
        );
    }
    for e in &report.errors {
        eprintln!("  link failure: {e}");
    }
    if !cfg.run.report_json.is_empty() {
        std::fs::write(&cfg.run.report_json, report.to_json().to_string_pretty())?;
        println!("report            -> {}", cfg.run.report_json);
    }
    anyhow::ensure!(report.errors.is_empty(), "worker {stage} saw link failures");
    Ok(())
}

fn cmd_coordinate(args: &Args) -> quantpipe::Result<()> {
    let cfg = load_config(args)?;
    if cfg.transport.reactor_pin_core >= 0 {
        quantpipe::net::reactor::set_pin_core(cfg.transport.reactor_pin_core as usize);
    }
    let (eval, microbatch) = if let Some(spec) = args.get("synthetic") {
        let (count, classes) = parse_pair(spec, "--synthetic")?;
        (Arc::new(EvalSet::synthetic_onehot(count, classes)), cfg.pipeline.microbatch)
    } else {
        let (manifest, dir) = Manifest::load(&cfg.run.artifacts)?;
        let eval = Arc::new(EvalSet::load(dir.join(&manifest.eval.file))?);
        (eval, manifest.microbatch)
    };
    anyhow::ensure!(microbatch > 0 && eval.count >= microbatch, "eval set smaller than one microbatch");

    // Bind the return listener BEFORE connecting so the last worker's
    // connect-retry always has a target.
    let listener = TcpListener::bind(&cfg.transport.sink_addr)?;
    let first = cfg
        .transport
        .stage_addrs
        .first()
        .ok_or_else(|| anyhow::anyhow!("transport.stage_addrs must name stage 0"))?;
    eprintln!(
        "[coordinator] feeding {first}, sink on {} (resilient={}, stripes={})",
        cfg.transport.sink_addr, cfg.transport.resilient, cfg.transport.stripes
    );
    let (feed_tx, ret_rx): (Box<dyn FrameTx>, Box<dyn FrameRx>) = if cfg.transport.stripes > 1 {
        let rcfg = cfg.transport.resilience_config();
        let mut feed = StripedTx::connect_to(
            first.clone(),
            cfg.transport.stripes,
            rcfg.clone(),
            Arc::new(ResilienceStats::default()),
        );
        if let Some(shapers) = scenario_shapers(&cfg, "coordinator")? {
            feed.set_shapers(shapers);
        }
        let ret = StripedRx::accept_on(
            Arc::new(listener),
            rcfg,
            Arc::new(ResilienceStats::default()),
        );
        (Box::new(feed), Box::new(ret))
    } else if cfg.transport.resilient {
        let rcfg = cfg.transport.resilience_config();
        let mut feed = ReconnectingTx::connect_to(
            first.clone(),
            rcfg.clone(),
            Arc::new(ResilienceStats::default()),
        );
        if let Some(shapers) = scenario_shapers(&cfg, "coordinator")? {
            feed.set_shaper(shapers.into_iter().next().flatten());
        }
        let ret = ReconnectingRx::accept_on(
            Arc::new(listener),
            rcfg,
            Arc::new(ResilienceStats::default()),
        );
        (Box::new(feed), Box::new(ret))
    } else {
        let (feed_tx, _feed_rx) = tcp::connect_retry(
            first,
            cfg.transport.connect_timeout(),
            cfg.transport.connect_retry(),
        )?;
        let (_ret_tx, ret_rx) = tcp::accept_one(&listener)?;
        eprintln!("[coordinator] chain connected");
        (Box::new(feed_tx), Box::new(ret_rx))
    };

    let total = if cfg.run.microbatches == 0 {
        eval.microbatches(microbatch) as u64
    } else {
        cfg.run.microbatches
    };
    let serving = args.get("streams").is_some() || cfg.pipeline.max_streams > 1;
    let report = if serving {
        let streams = match args.get("streams") {
            Some(s) => parse_streams(s)?,
            None => even_streams(total, cfg.pipeline.max_streams),
        };
        let workload = ServeWorkload {
            eval,
            microbatch,
            streams,
            serve: ServeConfig {
                max_streams: cfg.pipeline.max_streams,
                queue_depth: cfg.pipeline.stream_queue_depth,
            },
        };
        eprintln!(
            "[coordinator] serving {} streams (queue depth {})",
            workload.streams.len(),
            cfg.pipeline.stream_queue_depth
        );
        run_serving_coordinator(workload, feed_tx, ret_rx)?
    } else {
        run_coordinator(Workload::repeat(eval, microbatch, total), feed_tx, ret_rx)?
    };

    println!("== QuantPipe coordinate (tcp) ==");
    println!("microbatches      {}", report.microbatches);
    println!("images            {}", report.images);
    println!("wall              {:.2}s", report.wall_secs);
    println!("throughput        {:.1} img/s", report.throughput);
    println!("top-1 accuracy    {:.2}%", report.accuracy * 100.0);
    println!(
        "p50/p99 latency   {:?} / {:?}",
        report.latency.quantile(0.5),
        report.latency.quantile(0.99)
    );
    if cfg.transport.resilient {
        let r = report.resilience;
        println!(
            "resilience        {} reconnects / {} re-accepts, {} replayed, {} deduped, {:.2}s stalled",
            r.reconnects, r.reaccepts, r.replayed, r.deduped, r.stall_secs
        );
    }
    for (i, s) in report.stripes.iter().enumerate() {
        println!(
            "stripe {i:<2}         {} frames, {} B, {} reconnects, {:.2}s stalled",
            s.frames, s.bytes, s.reconnects, s.stall_secs
        );
    }
    // The merged run view: which stages reported, and whether their
    // microbatch counts line up across the boundaries.
    for st in report.pipeline.stages.values() {
        println!(
            "stage {:<2} telem   {} frames, {} windows, {}",
            st.stage,
            st.frames,
            st.points.len(),
            if st.complete { "complete" } else { "INCOMPLETE" }
        );
    }
    // Per-stream rows (serving runs only): who completed what, and who
    // absorbed the backpressure.
    if let Some(c) = report.pipeline.coordinator.as_ref() {
        for s in &c.streams {
            println!(
                "stream {:<3}       {} frames (weight {}), {} stalls, p50 {:.1} ms / p99 {:.1} ms",
                s.stream,
                s.frames,
                s.weight,
                s.stalls,
                s.p50_latency_s * 1e3,
                s.p99_latency_s * 1e3
            );
        }
    }
    for e in &report.errors {
        eprintln!("  link failure: {e}");
    }
    if !cfg.run.report_json.is_empty() {
        std::fs::write(
            &cfg.run.report_json,
            report.pipeline.to_json().to_string_pretty(),
        )?;
        println!("pipeline report   -> {} (render: quantpipe report {})", cfg.run.report_json, cfg.run.report_json);
    }
    anyhow::ensure!(report.errors.is_empty(), "coordinator saw link failures");
    Ok(())
}

/// Render a persisted `PipelineReport` JSON (written by
/// `quantpipe coordinate --report-json`) human-readably.
fn cmd_report(args: &Args) -> quantpipe::Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("report needs a run.json path (from coordinate --report-json)"))?;
    let text = std::fs::read_to_string(path)?;
    let report = quantpipe::metrics::telemetry::PipelineReport::from_json(&Value::parse(&text)?)?;
    print!("{}", report.render());
    Ok(())
}

fn cmd_sweep(args: &Args) -> quantpipe::Result<()> {
    let cfg = load_config(args)?;
    ensure_inproc(&cfg, "sweep")?;
    let bits: Vec<u8> = args
        .get("bits")
        .unwrap_or("32,16,8,6,4,2")
        .split(',')
        .map(|b| b.trim().parse())
        .collect::<std::result::Result<_, _>>()?;
    let (manifest, dir) = Manifest::load(&cfg.run.artifacts)?;
    let eval = Arc::new(EvalSet::load(dir.join(&manifest.eval.file))?);
    let s = manifest.microbatch;

    println!(
        "== Table 1: top-1 accuracy (fp32 reference = {:.2}%) ==",
        manifest.model.fp32_top1 * 100.0
    );
    print!("{:<8}", "method");
    for b in &bits {
        print!("{:>9}", format!("{b}bit"));
    }
    println!();
    for method in [Method::Naive, Method::Aciq, Method::Pda] {
        print!("{:<8}", method.name());
        for &b in &bits {
            let mut c = cfg.clone();
            c.adapt.enabled = false;
            c.adapt.fixed_bits = b;
            c.quant.method = method;
            let spec = build_spec(&c, &manifest, &dir)?;
            let report = pipeline::run(spec, Workload::one_pass(eval.clone(), s))?;
            print!("{:>8.2}%", report.accuracy * 100.0);
        }
        println!();
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> quantpipe::Result<()> {
    let profile = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("partition needs a profile.json path"))?;
    let devices: usize = args.get("devices").unwrap_or("4").parse()?;
    let v = Value::parse(&std::fs::read_to_string(profile)?)?;
    let block_s: Vec<Vec<f64>> = v
        .at("block_s")?
        .as_arr()?
        .iter()
        .map(|r| r.f64_vec())
        .collect::<quantpipe::Result<_>>()?;
    let comm_s = v.at("comm_s")?.f64_vec()?;
    let costs = CostModel::new(block_s, comm_s);
    let p = quantpipe::partition::partition(&costs, devices);
    println!(
        "partition (bottleneck {:.4}s, est. throughput {:.2}/s):",
        p.bottleneck(&costs),
        p.throughput(&costs)
    );
    for (d, (lo, hi)) in p.cuts.iter().enumerate() {
        println!("  device {d}: blocks {lo}..{hi}");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> quantpipe::Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let (m, dir) = Manifest::load(dir)?;
    println!("artifacts     {}", dir.display());
    println!(
        "model         ViT d{} dim{} heads{} ({:.2}M params, trained={})",
        m.model.depth,
        m.model.dim,
        m.model.heads,
        m.model.params as f64 / 1e6,
        m.model.trained
    );
    println!("fp32 top-1    {:.2}%", m.model.fp32_top1 * 100.0);
    println!("microbatch    {}", m.microbatch);
    println!(
        "activation    {:?} ({} KB fp32)",
        m.activation_shape,
        m.activation_shape.iter().product::<usize>() * 4 / 1024
    );
    println!("stages        {}", m.stages.len());
    for (i, s) in m.stages.iter().enumerate() {
        println!("  {i}: blocks {:?} {} -> {:?}", s.blocks, s.file, s.out_shape);
    }
    println!("eval          {} images", m.eval.count);
    Ok(())
}
