//! QuantPipe CLI — the launcher.
//!
//! ```text
//! quantpipe run       [--config F] [--trace T] [--microbatches N]
//!                     [--method M] [--fixed-bits B] [--target-rate R]
//!                     [--timeline-csv F] [--codec-backend native|hlo]
//! quantpipe sweep     [--config F] [--bits 32,16,8,6,4,2]
//! quantpipe partition <profile.json> [--devices N]
//! quantpipe inspect   [--artifacts DIR]
//! ```
//!
//! Arg parsing is hand-rolled (offline build: no clap).

use quantpipe::adapt::AdaptConfig;
use quantpipe::config::Config;
use quantpipe::data::EvalSet;
use quantpipe::net::link::SimLink;
use quantpipe::partition::CostModel;
use quantpipe::pipeline::{self, hlo_stage_factory, LinkQuant, PipelineSpec, Workload};
use quantpipe::quant::Method;
use quantpipe::runtime::Manifest;
use quantpipe::util::json::Value;
use std::sync::Arc;

const USAGE: &str = "\
quantpipe — adaptive PTQ for distributed transformer pipelines (QuantPipe reproduction)

USAGE:
  quantpipe run       [--config F] [--trace T] [--microbatches N] [--method M]
                      [--fixed-bits B] [--target-rate R] [--timeline-csv F]
                      [--codec-backend native|hlo] [--artifacts DIR]
  quantpipe sweep     [--config F] [--bits 32,16,8,6,4,2] [--artifacts DIR]
  quantpipe partition <profile.json> [--devices N]
  quantpipe inspect   [--artifacts DIR]
";

/// Tiny flag parser: --key value pairs + positionals.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> quantpipe::Result<Args> {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "partition" => cmd_partition(&args),
        "inspect" => cmd_inspect(&args),
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> quantpipe::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(p) => Config::load(p)?,
        None => Config::default(),
    };
    if let Some(t) = args.get("trace") {
        cfg.net.traces = vec![t.to_string()];
    }
    if let Some(m) = args.get("microbatches") {
        cfg.run.microbatches = m.parse()?;
    }
    if let Some(m) = args.get("method") {
        cfg.quant.method = parse_method(m)?;
    }
    if let Some(b) = args.get("fixed-bits") {
        cfg.adapt.enabled = false;
        cfg.adapt.fixed_bits = b.parse()?;
    }
    if let Some(r) = args.get("target-rate") {
        cfg.adapt.target_rate = r.parse()?;
    }
    if let Some(f) = args.get("timeline-csv") {
        cfg.run.timeline_csv = f.to_string();
    }
    if let Some(cb) = args.get("codec-backend") {
        cfg.pipeline.codec_backend = cb.to_string();
    }
    if let Some(a) = args.get("artifacts") {
        cfg.run.artifacts = a.to_string();
    }
    Ok(cfg)
}

fn parse_method(s: &str) -> quantpipe::Result<Method> {
    Ok(match s {
        "naive" => Method::Naive,
        "aciq" => Method::Aciq,
        "ds_aciq" => Method::DsAciq,
        "pda" => Method::Pda,
        other => anyhow::bail!("unknown method {other:?}"),
    })
}

/// Build a PipelineSpec from config + artifacts.
fn build_spec(cfg: &Config, manifest: &Manifest, dir: &std::path::Path) -> quantpipe::Result<PipelineSpec> {
    let n = manifest.stages.len();
    let hlo_codec = cfg.pipeline.codec_backend == "hlo";
    let stages = (0..n)
        .map(|i| hlo_stage_factory(dir.to_path_buf(), manifest.clone(), i, hlo_codec))
        .collect();
    let links = (0..n - 1)
        .map(|i| {
            Ok(Arc::new(SimLink::with_faults(
                cfg.trace_for_link(i)?,
                std::time::Duration::from_micros(cfg.net.latency_us),
                cfg.link_faults(),
            )))
        })
        .collect::<quantpipe::Result<_>>()?;
    let quant = LinkQuant {
        method: cfg.quant.method,
        calib_every: cfg.quant.calib_every,
        initial_bits: if cfg.adapt.enabled { 32 } else { cfg.adapt.fixed_bits },
    };
    let adapt: Option<AdaptConfig> = if cfg.adapt.enabled {
        let mut a = cfg.adapt_config()?;
        a.microbatch = manifest.microbatch;
        Some(a)
    } else {
        None
    };
    Ok(PipelineSpec {
        stages,
        links,
        quant,
        adapt,
        window: cfg.adapt.window,
        inflight: cfg.pipeline.inflight,
    })
}

fn cmd_run(args: &Args) -> quantpipe::Result<()> {
    let cfg = load_config(args)?;
    let (manifest, dir) = Manifest::load(&cfg.run.artifacts)?;
    let eval = Arc::new(EvalSet::load(dir.join(&manifest.eval.file))?);
    let spec = build_spec(&cfg, &manifest, &dir)?;
    let s = manifest.microbatch;
    let workload = if cfg.run.microbatches == 0 {
        Workload::one_pass(eval, s)
    } else {
        Workload::repeat(eval, s, cfg.run.microbatches)
    };

    let report = pipeline::run(spec, workload)?;

    println!("== QuantPipe run ==");
    println!("microbatches      {}", report.microbatches);
    println!("images            {}", report.images);
    println!("wall              {:.2}s", report.wall_secs);
    println!("throughput        {:.1} img/s", report.throughput);
    println!("top-1 accuracy    {:.2}%", report.accuracy * 100.0);
    println!(
        "p50/p99 latency   {:?} / {:?}",
        report.latency.quantile(0.5),
        report.latency.quantile(0.99)
    );
    println!("link0 mean bytes  {:.0} B/microbatch", report.link0_mean_bytes);
    println!(
        "stage compute     {:?} ms",
        report
            .stage_compute_s
            .iter()
            .map(|s| (s * 1e3 * 10.0).round() / 10.0)
            .collect::<Vec<_>>()
    );
    if let Some(bits) = report.timeline.final_bits(0) {
        println!("final bits (l0)   {bits}");
        println!("bits sequence     {:?}", report.timeline.bits_sequence(0));
    }
    if !cfg.run.timeline_csv.is_empty() {
        std::fs::write(&cfg.run.timeline_csv, report.timeline.to_csv())?;
        println!("timeline          -> {}", cfg.run.timeline_csv);
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> quantpipe::Result<()> {
    let cfg = load_config(args)?;
    let bits: Vec<u8> = args
        .get("bits")
        .unwrap_or("32,16,8,6,4,2")
        .split(',')
        .map(|b| b.trim().parse())
        .collect::<std::result::Result<_, _>>()?;
    let (manifest, dir) = Manifest::load(&cfg.run.artifacts)?;
    let eval = Arc::new(EvalSet::load(dir.join(&manifest.eval.file))?);
    let s = manifest.microbatch;

    println!(
        "== Table 1: top-1 accuracy (fp32 reference = {:.2}%) ==",
        manifest.model.fp32_top1 * 100.0
    );
    print!("{:<8}", "method");
    for b in &bits {
        print!("{:>9}", format!("{b}bit"));
    }
    println!();
    for method in [Method::Naive, Method::Aciq, Method::Pda] {
        print!("{:<8}", method.name());
        for &b in &bits {
            let mut c = cfg.clone();
            c.adapt.enabled = false;
            c.adapt.fixed_bits = b;
            c.quant.method = method;
            let spec = build_spec(&c, &manifest, &dir)?;
            let report = pipeline::run(spec, Workload::one_pass(eval.clone(), s))?;
            print!("{:>8.2}%", report.accuracy * 100.0);
        }
        println!();
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> quantpipe::Result<()> {
    let profile = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("partition needs a profile.json path"))?;
    let devices: usize = args.get("devices").unwrap_or("4").parse()?;
    let v = Value::parse(&std::fs::read_to_string(profile)?)?;
    let block_s: Vec<Vec<f64>> = v
        .at("block_s")?
        .as_arr()?
        .iter()
        .map(|r| r.f64_vec())
        .collect::<quantpipe::Result<_>>()?;
    let comm_s = v.at("comm_s")?.f64_vec()?;
    let costs = CostModel::new(block_s, comm_s);
    let p = quantpipe::partition::partition(&costs, devices);
    println!(
        "partition (bottleneck {:.4}s, est. throughput {:.2}/s):",
        p.bottleneck(&costs),
        p.throughput(&costs)
    );
    for (d, (lo, hi)) in p.cuts.iter().enumerate() {
        println!("  device {d}: blocks {lo}..{hi}");
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> quantpipe::Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let (m, dir) = Manifest::load(dir)?;
    println!("artifacts     {}", dir.display());
    println!(
        "model         ViT d{} dim{} heads{} ({:.2}M params, trained={})",
        m.model.depth,
        m.model.dim,
        m.model.heads,
        m.model.params as f64 / 1e6,
        m.model.trained
    );
    println!("fp32 top-1    {:.2}%", m.model.fp32_top1 * 100.0);
    println!("microbatch    {}", m.microbatch);
    println!(
        "activation    {:?} ({} KB fp32)",
        m.activation_shape,
        m.activation_shape.iter().product::<usize>() * 4 / 1024
    );
    println!("stages        {}", m.stages.len());
    for (i, s) in m.stages.iter().enumerate() {
        println!("  {i}: blocks {:?} {} -> {:?}", s.blocks, s.file, s.out_shape);
    }
    println!("eval          {} images", m.eval.count);
    Ok(())
}
