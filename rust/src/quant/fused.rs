//! Fused single-pass codec kernels: quantize+pack on encode, unpack+
//! dequantize on decode — the native data path of [`super::codec::Codec`].
//!
//! The two-pass path ([`super::uniform::quantize_into`] then
//! [`super::pack::pack`], and the mirror image on receive) walks the
//! tensor twice and stages every element through an `i32` code buffer:
//! ~12.5 bytes of memory traffic per element at 4-bit where the packed
//! stream is half a byte. These kernels read the f32s once and emit the
//! packed bytes directly (and symmetrically on decode), which is what
//! makes the codec — the per-stage cost that bounds pipeline throughput
//! once the wire stops being the bottleneck — memory-minimal.
//!
//! **Fusion invariants** (checked in tests and `tests/codec_hotpath.rs`):
//!
//! * the per-element arithmetic is *identical* to `uniform`'s —
//!   `clamp(round(x/scale + zp), lo, hi)` spelled as `round().max(lo)
//!   .min(hi)` in the same order, so the fused payload is **byte-identical**
//!   to quantize-then-pack and the fused decode is **bit-identical** to
//!   unpack-then-dequantize (the same contract the AOT Pallas backend
//!   honors against `uniform`, which is why the codec can swap paths
//!   freely for the native backend only);
//! * sub-byte widths are processed in byte-aligned element groups
//!   (`lcm(bits, 8) / bits` elements ↦ `lcm(bits, 8) / 8` bytes) with no
//!   bit-accumulator carried across groups, so iterations are independent
//!   (vectorizable) and any chunk split on a group boundary produces the
//!   exact bytes of the serial kernel — the property the multicore encode
//!   ([`encode_into_mt`]) is built on;
//! * decode validates payload length up front exactly like
//!   [`super::pack::unpack`]: a truncated payload is an error, never a
//!   panic or a short output.
//!
//! [`encode_into_mt`] chunks large tensors across scoped worker threads
//! (chunk boundaries aligned to the group size, each worker writing its
//! own disjoint byte range), gated by the `codec_threads` config knob /
//! [`super::codec::Codec::set_threads`]; `threads = 1` (the default) never
//! spawns.

use super::pack::packed_len;
use super::QuantParams;
use crate::Result;

/// Elements per byte-aligned group at `bits`: `lcm(bits, 8) / bits`.
/// Chunk boundaries for parallel encode must be multiples of this so the
/// packed stream stays byte-exact vs the serial kernel. Generic over any
/// width (2 → 4, 4 → 2, 6 → 4, 8/16 → 1, 3 → 8, …): since 8 = 2³,
/// `lcm(bits, 8) / bits = 8 / gcd(bits, 8)`, and the gcd is the largest
/// power of two ≤ 8 dividing `bits`.
pub fn group_elems(bits: u8) -> usize {
    let b = (bits as u32).max(1);
    8 >> b.trailing_zeros().min(3)
}

/// Per-worker minimum chunk for the multicore encode. Scoped threads are
/// spawned and joined on every call (no persistent pool — keeping the
/// borrow story trivially safe), which costs tens of µs per worker on
/// the stage thread's critical path each microbatch; a ≥64k-element
/// chunk (~100 µs+ of encode work) keeps that overhead well amortized.
/// Tensors below 2× this always encode serially regardless of
/// `codec_threads`.
pub const MT_MIN_CHUNK_ELEMS: usize = 1 << 16;

/// The quantizer arithmetic, spelled exactly as
/// [`super::uniform::quantize_into`] spells it (same ops, same order) so
/// fused and two-pass codes can never differ.
#[inline(always)]
fn quantize_one(v: f32, inv: f32, zp: f32, lo: f32, hi: f32) -> i32 {
    let c = (v * inv + zp).round();
    c.max(lo).min(hi) as i32
}

/// The dequantizer arithmetic of [`super::uniform::dequantize_into`],
/// applied to an unpacked field `u` (offset `off` restores the signed
/// code, matching `pack::unpack`'s `+ lo`).
#[inline(always)]
fn dequantize_one(u: u32, off: i32, scale: f32, zp: f32) -> f32 {
    ((u as i32 + off) as f32 - zp) * scale
}

/// Fused quantize+pack of `x` into `out` (cleared and resized to the
/// packed length). Single-threaded; see [`encode_into_mt`] for the
/// chunked multicore variant.
pub fn encode_into(x: &[f32], p: &QuantParams, out: &mut Vec<u8>) {
    // resize, not clear+resize: every output byte is written below, so
    // stale contents never leak into the wire, and a recycled same-size
    // buffer costs zero memset (clear() would zero-fill the whole
    // buffer again on the resize).
    out.resize(packed_len(x.len(), p.bits), 0);
    encode_chunk(x, p, out);
}

/// Fused quantize+pack with up to `threads` scoped workers. Chunk
/// boundaries are aligned to [`group_elems`], every worker writes its own
/// disjoint byte range of `out`, and each chunk runs the same
/// [`encode_chunk`] kernel — so the result is byte-identical to
/// [`encode_into`] for every thread count (asserted in tests). Workers
/// are capped so each gets at least [`MT_MIN_CHUNK_ELEMS`] elements;
/// smaller tensors and `threads <= 1` stay serial (no spawn at all).
pub fn encode_into_mt(x: &[f32], p: &QuantParams, threads: usize, out: &mut Vec<u8>) {
    // resize, not clear+resize — see `encode_into`.
    out.resize(packed_len(x.len(), p.bits), 0);
    let workers = threads.min(x.len() / MT_MIN_CHUNK_ELEMS).max(1);
    if workers == 1 {
        encode_chunk(x, p, out);
        return;
    }
    let group = group_elems(p.bits);
    let per = x.len().div_ceil(workers).next_multiple_of(group);
    std::thread::scope(|scope| {
        let mut rest_x = x;
        let mut rest_out: &mut [u8] = out;
        loop {
            let take = per.min(rest_x.len());
            let (chunk_x, nx) = rest_x.split_at(take);
            // Non-final chunks are group-aligned, so their packed length
            // is exact (no partial byte); the final chunk takes the rest.
            let split = packed_len(take, p.bits).min(rest_out.len());
            let (chunk_out, no) = std::mem::take(&mut rest_out).split_at_mut(split);
            rest_x = nx;
            rest_out = no;
            if rest_x.is_empty() {
                // Final chunk runs on the calling thread, which would
                // otherwise idle in the scope join — one fewer
                // spawn/join per encode.
                encode_chunk(chunk_x, p, chunk_out);
                break;
            }
            scope.spawn(move || encode_chunk(chunk_x, p, chunk_out));
        }
    });
}

/// The fused kernel over one byte-aligned chunk. `out.len()` must equal
/// `packed_len(x.len(), p.bits)`; every output byte is written.
fn encode_chunk(x: &[f32], p: &QuantParams, out: &mut [u8]) {
    debug_assert_eq!(out.len(), packed_len(x.len(), p.bits));
    let inv = 1.0 / p.scale;
    let (zp, lo, hi) = (p.zero_point, p.lo, p.hi);
    let off = p.pack_offset();
    match p.bits {
        8 => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = (quantize_one(v, inv, zp, lo, hi) - off) as u8;
            }
        }
        16 => {
            for (o, &v) in out.chunks_exact_mut(2).zip(x) {
                let u = (quantize_one(v, inv, zp, lo, hi) - off) as u16;
                o.copy_from_slice(&u.to_le_bytes());
            }
        }
        2 => {
            // 4 elements ↦ 1 byte, LSB-first (pack's bit order).
            let groups = x.len() / 4;
            for (o, g) in out[..groups].iter_mut().zip(x.chunks_exact(4)) {
                let q0 = (quantize_one(g[0], inv, zp, lo, hi) - off) as u32 & 3;
                let q1 = (quantize_one(g[1], inv, zp, lo, hi) - off) as u32 & 3;
                let q2 = (quantize_one(g[2], inv, zp, lo, hi) - off) as u32 & 3;
                let q3 = (quantize_one(g[3], inv, zp, lo, hi) - off) as u32 & 3;
                *o = (q0 | (q1 << 2) | (q2 << 4) | (q3 << 6)) as u8;
            }
            encode_tail(&x[groups * 4..], p, &mut out[groups..]);
        }
        4 => {
            // 2 elements ↦ 1 byte.
            let groups = x.len() / 2;
            for (o, g) in out[..groups].iter_mut().zip(x.chunks_exact(2)) {
                let q0 = (quantize_one(g[0], inv, zp, lo, hi) - off) as u32 & 0xf;
                let q1 = (quantize_one(g[1], inv, zp, lo, hi) - off) as u32 & 0xf;
                *o = (q0 | (q1 << 4)) as u8;
            }
            encode_tail(&x[groups * 2..], p, &mut out[groups..]);
        }
        6 => {
            // 4 elements ↦ 3 bytes (24 bits), LSB-first.
            let groups = x.len() / 4;
            for (o, g) in out[..groups * 3].chunks_exact_mut(3).zip(x.chunks_exact(4)) {
                let q0 = (quantize_one(g[0], inv, zp, lo, hi) - off) as u32 & 0x3f;
                let q1 = (quantize_one(g[1], inv, zp, lo, hi) - off) as u32 & 0x3f;
                let q2 = (quantize_one(g[2], inv, zp, lo, hi) - off) as u32 & 0x3f;
                let q3 = (quantize_one(g[3], inv, zp, lo, hi) - off) as u32 & 0x3f;
                o[0] = (q0 | (q1 << 6)) as u8;
                o[1] = ((q1 >> 2) | (q2 << 4)) as u8;
                o[2] = ((q2 >> 4) | (q3 << 2)) as u8;
            }
            encode_tail(&x[groups * 4..], p, &mut out[groups * 3..]);
        }
        // Non-standard sub-byte widths: the generic accumulator (pack's
        // own fallback shape, same `bits < 8` contract). Never hit by
        // SUPPORTED_BITS; encode params always come from `calibrate`.
        _ => {
            debug_assert!((1..8).contains(&p.bits), "unsupported bitwidth {}", p.bits);
            encode_tail(x, p, out);
        }
    }
}

/// Generic bit-accumulator encode for a (short) byte-aligned tail — the
/// exact loop shape of [`super::pack::pack`]'s sub-byte branch, so tail
/// bytes match the serial reference bit for bit.
fn encode_tail(x: &[f32], p: &QuantParams, out: &mut [u8]) {
    let inv = 1.0 / p.scale;
    let (zp, lo, hi) = (p.zero_point, p.lo, p.hi);
    let off = p.pack_offset();
    let bits = p.bits as u32;
    let mask = (1u32 << bits) - 1;
    let mut acc: u32 = 0;
    let mut nbits: u32 = 0;
    let mut w = 0usize;
    for &v in x {
        let u = (quantize_one(v, inv, zp, lo, hi) - off) as u32 & mask;
        acc |= u << nbits;
        nbits += bits;
        while nbits >= 8 {
            out[w] = (acc & 0xff) as u8;
            w += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[w] = (acc & 0xff) as u8;
    }
}

/// Fused unpack+dequantize of `out.len()` elements from `bytes`.
///
/// Like [`super::pack::unpack`], the payload length is validated up
/// front: a truncated payload (cut stream, corrupt frame) is an error the
/// driver can report, never a panic or a silently-short output.
pub fn decode_into(bytes: &[u8], p: &QuantParams, out: &mut [f32]) -> Result<()> {
    let n = out.len();
    let need = packed_len(n, p.bits);
    anyhow::ensure!(
        bytes.len() >= need,
        "bitstream truncated: {n} codes at {} bits need {need} bytes, got {}",
        p.bits,
        bytes.len()
    );
    let (s, zp) = (p.scale, p.zero_point);
    let off = p.pack_offset();
    match p.bits {
        8 => {
            for (o, &b) in out.iter_mut().zip(bytes) {
                *o = dequantize_one(b as u32, off, s, zp);
            }
        }
        16 => {
            for (o, ch) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                *o = dequantize_one(u16::from_le_bytes([ch[0], ch[1]]) as u32, off, s, zp);
            }
        }
        2 => {
            let groups = n / 4;
            for (og, &b) in out[..groups * 4].chunks_exact_mut(4).zip(&bytes[..groups]) {
                let b = b as u32;
                og[0] = dequantize_one(b & 3, off, s, zp);
                og[1] = dequantize_one((b >> 2) & 3, off, s, zp);
                og[2] = dequantize_one((b >> 4) & 3, off, s, zp);
                og[3] = dequantize_one((b >> 6) & 3, off, s, zp);
            }
            decode_tail(&bytes[groups..], p, &mut out[groups * 4..]);
        }
        4 => {
            let groups = n / 2;
            for (og, &b) in out[..groups * 2].chunks_exact_mut(2).zip(&bytes[..groups]) {
                let b = b as u32;
                og[0] = dequantize_one(b & 0xf, off, s, zp);
                og[1] = dequantize_one((b >> 4) & 0xf, off, s, zp);
            }
            decode_tail(&bytes[groups..], p, &mut out[groups * 2..]);
        }
        6 => {
            let groups = n / 4;
            for (og, bg) in out[..groups * 4]
                .chunks_exact_mut(4)
                .zip(bytes[..groups * 3].chunks_exact(3))
            {
                let (b0, b1, b2) = (bg[0] as u32, bg[1] as u32, bg[2] as u32);
                og[0] = dequantize_one(b0 & 0x3f, off, s, zp);
                og[1] = dequantize_one(((b0 >> 6) | (b1 << 2)) & 0x3f, off, s, zp);
                og[2] = dequantize_one(((b1 >> 4) | (b2 << 4)) & 0x3f, off, s, zp);
                og[3] = dequantize_one((b2 >> 2) & 0x3f, off, s, zp);
            }
            decode_tail(&bytes[groups * 3..], p, &mut out[groups * 4..]);
        }
        // Decode params come off the wire: a frame claiming a bitwidth
        // the generic accumulator can't handle (0, or >= 8 other than
        // the explicit arms) is a corrupt/hostile stream — surface an
        // error, never garbage.
        bits => {
            anyhow::ensure!((1..8).contains(&bits), "unsupported wire bitwidth {bits}");
            decode_tail(bytes, p, out);
        }
    }
    Ok(())
}

/// Generic bit-accumulator decode for a (short) byte-aligned tail — the
/// exact loop shape of [`super::pack::unpack`]'s sub-byte branch.
fn decode_tail(bytes: &[u8], p: &QuantParams, out: &mut [f32]) {
    let (s, zp) = (p.scale, p.zero_point);
    let off = p.pack_offset();
    let bits = p.bits as u32;
    let mask = (1u32 << bits) - 1;
    let mut acc: u32 = 0;
    let mut nbits: u32 = 0;
    let mut iter = bytes.iter();
    for o in out.iter_mut() {
        while nbits < bits {
            // Cannot run dry: the caller validated the payload length.
            acc |= (*iter.next().expect("decode length invariant") as u32) << nbits;
            nbits += 8;
        }
        *o = dequantize_one(acc & mask, off, s, zp);
        acc >>= bits;
        nbits -= bits;
    }
}

/// Bulk raw-f32 passthrough (`bits == 32`): one pre-sized copy into the
/// payload buffer instead of per-element `extend_from_slice` pushes.
/// `chunks_exact_mut(4)` + `copy_from_slice` compiles to straight-line
/// 4-byte stores with no per-push capacity checks. resize, not
/// clear+resize: the copy overwrites every byte, so a recycled
/// same-size buffer costs no memset.
pub fn raw_f32_into(x: &[f32], out: &mut Vec<u8>) {
    out.resize(x.len() * 4, 0);
    for (dst, v) in out.chunks_exact_mut(4).zip(x) {
        dst.copy_from_slice(&v.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{pack, uniform, SUPPORTED_BITS};

    fn test_tensor(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::seed(seed);
        (0..n)
            .map(|i| {
                let v = rng.laplace(0.8) as f32;
                if i % 113 == 0 {
                    v * 9.0 // outliers exercise both clamp edges
                } else {
                    v
                }
            })
            .collect()
    }

    /// Both code-range conventions: symmetric signed (zp = 0, lo < 0, the
    /// ACIQ family) and asymmetric unsigned (naive min/max, lo = 0).
    fn param_set(x: &[f32], bits: u8) -> [QuantParams; 2] {
        [
            uniform::symmetric_params(1.5, bits),
            uniform::naive_params(x, bits),
        ]
    }

    fn legacy_encode(x: &[f32], p: &QuantParams) -> Vec<u8> {
        let codes = uniform::quantize(x, p);
        pack::pack_vec(&codes, p.bits, p.pack_offset())
    }

    fn legacy_decode(bytes: &[u8], n: usize, p: &QuantParams) -> Vec<f32> {
        let codes = pack::unpack_vec(bytes, n, p.bits, p.pack_offset()).unwrap();
        uniform::dequantize(&codes, p)
    }

    #[test]
    fn fused_encode_byte_identical_to_two_pass() {
        for bits in SUPPORTED_BITS {
            for n in [0usize, 1, 3, 5, 7, 8, 31, 63, 97, 255, 1000, 1001] {
                let x = test_tensor(n, 11 + n as u64);
                for p in param_set(&x, bits) {
                    let legacy = legacy_encode(&x, &p);
                    let mut fusedv = Vec::new();
                    encode_into(&x, &p, &mut fusedv);
                    assert_eq!(fusedv, legacy, "bits={bits} n={n} lo={}", p.lo);
                }
            }
        }
    }

    #[test]
    fn fused_decode_bit_identical_to_two_pass() {
        for bits in SUPPORTED_BITS {
            for n in [1usize, 3, 7, 63, 97, 1001] {
                let x = test_tensor(n, 29 + n as u64);
                for p in param_set(&x, bits) {
                    let payload = legacy_encode(&x, &p);
                    let legacy = legacy_decode(&payload, n, &p);
                    let mut fusedv = vec![0f32; n];
                    decode_into(&payload, &p, &mut fusedv).unwrap();
                    // Bit-level equality, not approximate: the fused path
                    // must be a drop-in for unpack+dequantize.
                    let a: Vec<u32> = legacy.iter().map(|v| v.to_bits()).collect();
                    let b: Vec<u32> = fusedv.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(a, b, "bits={bits} n={n} lo={}", p.lo);
                }
            }
        }
    }

    #[test]
    fn roundtrip_matches_uniform_roundtrip() {
        for bits in SUPPORTED_BITS {
            let x = test_tensor(513, 7);
            let p = uniform::symmetric_params(1.0, bits);
            let mut payload = Vec::new();
            encode_into(&x, &p, &mut payload);
            let mut back = vec![0f32; x.len()];
            decode_into(&payload, &p, &mut back).unwrap();
            assert_eq!(back, uniform::roundtrip(&x, &p), "bits={bits}");
        }
    }

    #[test]
    fn parallel_encode_equals_serial_bytes() {
        // Odd length: the final chunk is unaligned and the tail crosses a
        // partial byte at sub-byte widths.
        let n = MT_MIN_CHUNK_ELEMS * 3 + 37;
        let x = test_tensor(n, 3);
        for bits in SUPPORTED_BITS {
            for p in param_set(&x, bits) {
                let mut serial = Vec::new();
                encode_into(&x, &p, &mut serial);
                for threads in [2usize, 3, 5, 16] {
                    let mut par = Vec::new();
                    encode_into_mt(&x, &p, threads, &mut par);
                    assert_eq!(par, serial, "bits={bits} threads={threads}");
                }
            }
        }
        // Generic sub-byte widths (the accumulator fallback): chunk
        // alignment must hold there too — group_elems(3) = 8, not 1.
        for bits in [3u8, 5, 7] {
            let p = uniform::symmetric_params(1.0, bits);
            let mut serial = Vec::new();
            encode_into(&x, &p, &mut serial);
            for threads in [2usize, 3] {
                let mut par = Vec::new();
                encode_into_mt(&x, &p, threads, &mut par);
                assert_eq!(par, serial, "bits={bits} threads={threads}");
            }
        }
    }

    #[test]
    fn small_tensors_stay_serial_and_equal() {
        let x = test_tensor(1000, 5);
        let p = uniform::symmetric_params(1.0, 4);
        let mut serial = Vec::new();
        encode_into(&x, &p, &mut serial);
        let mut par = Vec::new();
        encode_into_mt(&x, &p, 8, &mut par);
        assert_eq!(par, serial);
    }

    #[test]
    fn truncated_payload_is_error() {
        let x = test_tensor(100, 17);
        for bits in SUPPORTED_BITS {
            let p = uniform::symmetric_params(1.0, bits);
            let mut payload = Vec::new();
            encode_into(&x, &p, &mut payload);
            let mut out = vec![0f32; x.len()];
            let err = decode_into(&payload[..payload.len() - 1], &p, &mut out).unwrap_err();
            assert!(err.to_string().contains("truncated"), "bits={bits}: {err:#}");
        }
    }

    #[test]
    fn hostile_wire_bitwidth_is_an_error_not_garbage() {
        // A frame can claim any bits value; the generic fallback only
        // handles sub-byte widths (pack's own contract) — anything else
        // must surface as a decode error.
        let mut p = uniform::symmetric_params(1.0, 4);
        let bytes = vec![0u8; 64];
        let mut out = vec![0f32; 16];
        // bits = 0 would pass the length check trivially (0 bytes
        // needed) and decode to constant garbage without the guard.
        for bad in [0u8, 13, 24] {
            p.bits = bad;
            let err = decode_into(&bytes, &p, &mut out).unwrap_err();
            assert!(err.to_string().contains("unsupported"), "bits={bad}: {err:#}");
        }
        // Odd-but-sub-byte widths still decode through the accumulator.
        p.bits = 3;
        assert!(decode_into(&bytes, &p, &mut out).is_ok());
    }

    #[test]
    fn raw_passthrough_is_exact_le_bytes() {
        let x = test_tensor(257, 23);
        let mut out = Vec::new();
        raw_f32_into(&x, &mut out);
        assert_eq!(out.len(), x.len() * 4);
        for (v, ch) in x.iter().zip(out.chunks_exact(4)) {
            assert_eq!(ch, v.to_le_bytes());
        }
        // Buffer reuse: capacity survives a second fill.
        let ptr = out.as_ptr();
        raw_f32_into(&x, &mut out);
        assert_eq!(out.as_ptr(), ptr);
    }

    #[test]
    fn group_alignment_constants() {
        for bits in 1u8..=16 {
            let g = group_elems(bits);
            // lcm(bits, 8) / bits: groups end exactly on byte boundaries,
            // and g is minimal (no smaller positive multiple aligns).
            assert_eq!(
                (g * bits as usize) % 8,
                0,
                "group of {g} elems at {bits}-bit must be byte-aligned"
            );
            for smaller in 1..g {
                assert_ne!((smaller * bits as usize) % 8, 0, "g={g} not minimal at {bits}-bit");
            }
        }
        assert_eq!(group_elems(2), 4);
        assert_eq!(group_elems(4), 2);
        assert_eq!(group_elems(6), 4);
        assert_eq!(group_elems(8), 1);
        assert_eq!(group_elems(16), 1);
        assert_eq!(group_elems(3), 8);
    }
}
