//! Fused single-pass codec kernels: quantize+pack on encode, unpack+
//! dequantize on decode — the native data path of [`super::codec::Codec`].
//!
//! The two-pass path ([`super::uniform::quantize_into`] then
//! [`super::pack::pack`], and the mirror image on receive) walks the
//! tensor twice and stages every element through an `i32` code buffer:
//! ~12.5 bytes of memory traffic per element at 4-bit where the packed
//! stream is half a byte. These kernels read the f32s once and emit the
//! packed bytes directly (and symmetrically on decode), which is what
//! makes the codec — the per-stage cost that bounds pipeline throughput
//! once the wire stops being the bottleneck — memory-minimal.
//!
//! **Fusion invariants** (checked in tests and `tests/codec_hotpath.rs`):
//!
//! * the per-element arithmetic is *identical* to `uniform`'s —
//!   `clamp(round(x/scale + zp), lo, hi)` spelled as `round().max(lo)
//!   .min(hi)` in the same order, so the fused payload is **byte-identical**
//!   to quantize-then-pack and the fused decode is **bit-identical** to
//!   unpack-then-dequantize (the same contract the AOT Pallas backend
//!   honors against `uniform`, which is why the codec can swap paths
//!   freely for the native backend only);
//! * sub-byte widths are processed in byte-aligned element groups
//!   (`lcm(bits, 8) / bits` elements ↦ `lcm(bits, 8) / 8` bytes) with no
//!   bit-accumulator carried across groups, so iterations are independent
//!   (vectorizable) and any chunk split on a group boundary produces the
//!   exact bytes of the serial kernel — the property the multicore encode
//!   ([`encode_into_mt`]) is built on;
//! * decode validates payload length up front exactly like
//!   [`super::pack::unpack`]: a truncated payload is an error, never a
//!   panic or a short output.
//!
//! **SIMD kernels** (x86_64): the group-independence invariant above is
//! exactly what lets the inner loops be expressed over explicit fixed-width
//! lanes. On x86_64 the dispatcher routes `SUPPORTED_BITS` widths through
//! `core::arch` SSE2 kernels (baseline, no detection needed) or AVX2
//! kernels (gated on `is_x86_feature_detected!`), with the scalar loops
//! retained verbatim as the portable fallback and the numerical reference
//! ([`encode_into_scalar`] / [`decode_into_scalar`]). The SIMD paths are
//! **byte-identical** to the scalar paths — `round()`'s half-away-from-zero
//! semantics are reproduced exactly with a truncate-then-adjust sequence
//! rather than the hardware's round-half-to-even conversion, NaN and ±inf
//! lanes clamp exactly like the scalar `max(lo).min(hi)` chain, and encode
//! only engages SIMD when [`QuantParams`] bounds are integer-valued and
//! small enough that clamp-then-round commutes with round-then-clamp
//! (every calibrated parameter set qualifies; anything else falls back to
//! scalar, keeping the contract unconditional). The runtime toggle
//! [`set_simd_enabled`] (config: `pipeline.codec_simd`) forces the scalar
//! path for A/B measurement; `benches/quant_codec.rs` reports both.
//!
//! [`encode_into_mt`] chunks large tensors across scoped worker threads
//! (chunk boundaries aligned to the group size, each worker writing its
//! own disjoint byte range), gated by the `codec_threads` config knob /
//! [`super::codec::Codec::set_threads`]; `threads = 1` (the default) never
//! spawns. The SIMD dispatch composes underneath: each worker's chunk is
//! group-aligned, so per-chunk SIMD blocks plus scalar tails still produce
//! the serial kernel's exact bytes.

use super::pack::packed_len;
use super::QuantParams;
use crate::Result;
use std::sync::atomic::{AtomicBool, Ordering};

/// Elements per byte-aligned group at `bits`: `lcm(bits, 8) / bits`.
/// Chunk boundaries for parallel encode must be multiples of this so the
/// packed stream stays byte-exact vs the serial kernel. Generic over any
/// width (2 → 4, 4 → 2, 6 → 4, 8/16 → 1, 3 → 8, …): since 8 = 2³,
/// `lcm(bits, 8) / bits = 8 / gcd(bits, 8)`, and the gcd is the largest
/// power of two ≤ 8 dividing `bits`.
///
/// **Contract:** `bits` must be in `1..=16` — the widths the packed wire
/// format can express. Wider values would silently alias a narrower group
/// (`group_elems(32)` would return 1, as if 8-bit), so the contract is
/// enforced with a `debug_assert!`; callers validating *wire* input must
/// reject out-of-range widths before calling (the codec layer does, see
/// [`decode_into`] and `quant::tile`).
pub fn group_elems(bits: u8) -> usize {
    debug_assert!((1..=16).contains(&bits), "group_elems: bitwidth {bits} outside 1..=16");
    let b = (bits as u32).max(1);
    8 >> b.trailing_zeros().min(3)
}

/// Per-worker minimum chunk for the multicore encode. Scoped threads are
/// spawned and joined on every call (no persistent pool — keeping the
/// borrow story trivially safe), which costs tens of µs per worker on
/// the stage thread's critical path each microbatch; a ≥64k-element
/// chunk (~100 µs+ of encode work) keeps that overhead well amortized.
/// Tensors below 2× this always encode serially regardless of
/// `codec_threads`.
pub const MT_MIN_CHUNK_ELEMS: usize = 1 << 16;

/// Process-wide SIMD toggle (default on). Scalar and SIMD kernels are
/// byte-identical, so flipping this mid-run is always safe; it exists for
/// the `pipeline.codec_simd` config knob and for A/B benchmarking.
static SIMD_ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable or disable the SIMD kernels process-wide (default: enabled).
/// The scalar fallback is byte-identical, so this only affects speed —
/// it is the runtime face of the `pipeline.codec_simd` config knob.
pub fn set_simd_enabled(on: bool) {
    SIMD_ENABLED.store(on, Ordering::Relaxed);
}

/// Whether the SIMD kernels are currently enabled (see
/// [`set_simd_enabled`]). Enabled does not imply *used*: non-x86_64
/// targets and non-eligible parameter sets still run scalar.
pub fn simd_enabled() -> bool {
    SIMD_ENABLED.load(Ordering::Relaxed)
}

/// The instruction set the dispatcher will pick right now: `"avx2"`,
/// `"sse2"`, or `"scalar"` (non-x86_64 target, or SIMD disabled via
/// [`set_simd_enabled`]). Reported by `benches/quant_codec.rs` next to
/// its scalar-vs-SIMD rows. Individual calls may still fall back to
/// scalar when the parameter set is not SIMD-eligible.
pub fn simd_active() -> &'static str {
    if !simd_enabled() {
        return "scalar";
    }
    #[cfg(target_arch = "x86_64")]
    {
        if simd::avx2_available() {
            "avx2"
        } else {
            "sse2"
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        "scalar"
    }
}

/// The quantizer arithmetic, spelled exactly as
/// [`super::uniform::quantize_into`] spells it (same ops, same order) so
/// fused and two-pass codes can never differ.
#[inline(always)]
fn quantize_one(v: f32, inv: f32, zp: f32, lo: f32, hi: f32) -> i32 {
    let c = (v * inv + zp).round();
    c.max(lo).min(hi) as i32
}

/// The dequantizer arithmetic of [`super::uniform::dequantize_into`],
/// applied to an unpacked field `u` (offset `off` restores the signed
/// code, matching `pack::unpack`'s `+ lo`).
#[inline(always)]
fn dequantize_one(u: u32, off: i32, scale: f32, zp: f32) -> f32 {
    ((u as i32 + off) as f32 - zp) * scale
}

/// Fused quantize+pack of `x` into `out` (cleared and resized to the
/// packed length). Single-threaded; see [`encode_into_mt`] for the
/// chunked multicore variant. Dispatches to the SIMD kernels when
/// enabled and eligible (see module docs); [`encode_into_scalar`] pins
/// the portable path.
pub fn encode_into(x: &[f32], p: &QuantParams, out: &mut Vec<u8>) {
    // resize, not clear+resize: every output byte is written below, so
    // stale contents never leak into the wire, and a recycled same-size
    // buffer costs zero memset (clear() would zero-fill the whole
    // buffer again on the resize).
    out.resize(packed_len(x.len(), p.bits), 0);
    encode_chunk(x, p, out);
}

/// Fused quantize+pack through the scalar kernels only — the portable
/// reference the SIMD dispatch is tested against (byte-identical by
/// contract). Useful for A/B benchmarking and for pinning tests.
pub fn encode_into_scalar(x: &[f32], p: &QuantParams, out: &mut Vec<u8>) {
    // resize, not clear+resize — see `encode_into`.
    out.resize(packed_len(x.len(), p.bits), 0);
    encode_chunk_scalar(x, p, out);
}

/// Fused quantize+pack with up to `threads` scoped workers. Chunk
/// boundaries are aligned to [`group_elems`], every worker writes its own
/// disjoint byte range of `out`, and each chunk runs the same
/// [`encode_chunk`] kernel — so the result is byte-identical to
/// [`encode_into`] for every thread count (asserted in tests). Workers
/// are capped so each gets at least [`MT_MIN_CHUNK_ELEMS`] elements;
/// smaller tensors and `threads <= 1` stay serial (no spawn at all).
pub fn encode_into_mt(x: &[f32], p: &QuantParams, threads: usize, out: &mut Vec<u8>) {
    // resize, not clear+resize — see `encode_into`.
    out.resize(packed_len(x.len(), p.bits), 0);
    let workers = threads.min(x.len() / MT_MIN_CHUNK_ELEMS).max(1);
    if workers == 1 {
        encode_chunk(x, p, out);
        return;
    }
    let group = group_elems(p.bits);
    let per = x.len().div_ceil(workers).next_multiple_of(group);
    std::thread::scope(|scope| {
        let mut rest_x = x;
        let mut rest_out: &mut [u8] = out;
        loop {
            let take = per.min(rest_x.len());
            let (chunk_x, nx) = rest_x.split_at(take);
            // Non-final chunks are group-aligned, so their packed length
            // is exact (no partial byte); the final chunk takes the rest.
            let split = packed_len(take, p.bits).min(rest_out.len());
            let (chunk_out, no) = std::mem::take(&mut rest_out).split_at_mut(split);
            rest_x = nx;
            rest_out = no;
            if rest_x.is_empty() {
                // Final chunk runs on the calling thread, which would
                // otherwise idle in the scope join — one fewer
                // spawn/join per encode.
                encode_chunk(chunk_x, p, chunk_out);
                break;
            }
            scope.spawn(move || encode_chunk(chunk_x, p, chunk_out));
        }
    });
}

/// The fused kernel dispatcher over one byte-aligned chunk. `out.len()`
/// must equal `packed_len(x.len(), p.bits)`; every output byte is
/// written. Routes to the SIMD kernels when the target, the toggle, and
/// the parameter set all allow it; otherwise (and for any SIMD-internal
/// tail) runs [`encode_chunk_scalar`]. `pub(crate)` so `quant::tile` can
/// encode per-tile subranges through the same dispatch.
pub(crate) fn encode_chunk(x: &[f32], p: &QuantParams, out: &mut [u8]) {
    debug_assert_eq!(out.len(), packed_len(x.len(), p.bits));
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() && simd::encode_chunk(x, p, out) {
        return;
    }
    encode_chunk_scalar(x, p, out);
}

/// The scalar fused kernel over one byte-aligned chunk — the portable
/// reference implementation. `out.len()` must equal
/// `packed_len(x.len(), p.bits)`; every output byte is written.
fn encode_chunk_scalar(x: &[f32], p: &QuantParams, out: &mut [u8]) {
    debug_assert_eq!(out.len(), packed_len(x.len(), p.bits));
    let inv = 1.0 / p.scale;
    let (zp, lo, hi) = (p.zero_point, p.lo, p.hi);
    let off = p.pack_offset();
    match p.bits {
        8 => {
            for (o, &v) in out.iter_mut().zip(x) {
                *o = (quantize_one(v, inv, zp, lo, hi) - off) as u8;
            }
        }
        16 => {
            for (o, &v) in out.chunks_exact_mut(2).zip(x) {
                let u = (quantize_one(v, inv, zp, lo, hi) - off) as u16;
                o.copy_from_slice(&u.to_le_bytes());
            }
        }
        2 => {
            // 4 elements ↦ 1 byte, LSB-first (pack's bit order).
            let groups = x.len() / 4;
            for (o, g) in out[..groups].iter_mut().zip(x.chunks_exact(4)) {
                let q0 = (quantize_one(g[0], inv, zp, lo, hi) - off) as u32 & 3;
                let q1 = (quantize_one(g[1], inv, zp, lo, hi) - off) as u32 & 3;
                let q2 = (quantize_one(g[2], inv, zp, lo, hi) - off) as u32 & 3;
                let q3 = (quantize_one(g[3], inv, zp, lo, hi) - off) as u32 & 3;
                *o = (q0 | (q1 << 2) | (q2 << 4) | (q3 << 6)) as u8;
            }
            encode_tail(&x[groups * 4..], p, &mut out[groups..]);
        }
        4 => {
            // 2 elements ↦ 1 byte.
            let groups = x.len() / 2;
            for (o, g) in out[..groups].iter_mut().zip(x.chunks_exact(2)) {
                let q0 = (quantize_one(g[0], inv, zp, lo, hi) - off) as u32 & 0xf;
                let q1 = (quantize_one(g[1], inv, zp, lo, hi) - off) as u32 & 0xf;
                *o = (q0 | (q1 << 4)) as u8;
            }
            encode_tail(&x[groups * 2..], p, &mut out[groups..]);
        }
        6 => {
            // 4 elements ↦ 3 bytes (24 bits), LSB-first.
            let groups = x.len() / 4;
            for (o, g) in out[..groups * 3].chunks_exact_mut(3).zip(x.chunks_exact(4)) {
                let q0 = (quantize_one(g[0], inv, zp, lo, hi) - off) as u32 & 0x3f;
                let q1 = (quantize_one(g[1], inv, zp, lo, hi) - off) as u32 & 0x3f;
                let q2 = (quantize_one(g[2], inv, zp, lo, hi) - off) as u32 & 0x3f;
                let q3 = (quantize_one(g[3], inv, zp, lo, hi) - off) as u32 & 0x3f;
                o[0] = (q0 | (q1 << 6)) as u8;
                o[1] = ((q1 >> 2) | (q2 << 4)) as u8;
                o[2] = ((q2 >> 4) | (q3 << 2)) as u8;
            }
            encode_tail(&x[groups * 4..], p, &mut out[groups * 3..]);
        }
        // Non-standard sub-byte widths: the generic accumulator (pack's
        // own fallback shape, same `bits < 8` contract). Never hit by
        // SUPPORTED_BITS; encode params always come from `calibrate`.
        _ => {
            debug_assert!((1..8).contains(&p.bits), "unsupported bitwidth {}", p.bits);
            encode_tail(x, p, out);
        }
    }
}

/// Generic bit-accumulator encode for a (short) byte-aligned tail — the
/// exact loop shape of [`super::pack::pack`]'s sub-byte branch, so tail
/// bytes match the serial reference bit for bit.
fn encode_tail(x: &[f32], p: &QuantParams, out: &mut [u8]) {
    let inv = 1.0 / p.scale;
    let (zp, lo, hi) = (p.zero_point, p.lo, p.hi);
    let off = p.pack_offset();
    let bits = p.bits as u32;
    let mask = (1u32 << bits) - 1;
    let mut acc: u32 = 0;
    let mut nbits: u32 = 0;
    let mut w = 0usize;
    for &v in x {
        let u = (quantize_one(v, inv, zp, lo, hi) - off) as u32 & mask;
        acc |= u << nbits;
        nbits += bits;
        while nbits >= 8 {
            out[w] = (acc & 0xff) as u8;
            w += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[w] = (acc & 0xff) as u8;
    }
}

/// Fused unpack+dequantize of `out.len()` elements from `bytes`.
///
/// Like [`super::pack::unpack`], the payload length is validated up
/// front: a truncated payload (cut stream, corrupt frame) is an error the
/// driver can report, never a panic or a silently-short output.
/// Dispatches to the SIMD kernels when enabled (see module docs);
/// [`decode_into_scalar`] pins the portable path.
pub fn decode_into(bytes: &[u8], p: &QuantParams, out: &mut [f32]) -> Result<()> {
    decode_impl(bytes, p, out, true)
}

/// Fused unpack+dequantize through the scalar kernels only — the
/// portable reference the SIMD dispatch is tested against
/// (bit-identical by contract). Same validation as [`decode_into`].
pub fn decode_into_scalar(bytes: &[u8], p: &QuantParams, out: &mut [f32]) -> Result<()> {
    decode_impl(bytes, p, out, false)
}

/// Shared decode core: validate, then dispatch SIMD or scalar. The
/// validation order is part of the error contract (tests pin it): a
/// truncated payload reports "truncated" even at a hostile bitwidth, and
/// a width outside `1..8` ∪ {8, 16} reports "unsupported wire bitwidth".
fn decode_impl(bytes: &[u8], p: &QuantParams, out: &mut [f32], simd_ok: bool) -> Result<()> {
    let n = out.len();
    let need = packed_len(n, p.bits);
    anyhow::ensure!(
        bytes.len() >= need,
        "bitstream truncated: {n} codes at {} bits need {need} bytes, got {}",
        p.bits,
        bytes.len()
    );
    if !matches!(p.bits, 2 | 4 | 6 | 8 | 16) {
        // Decode params come off the wire: a frame claiming a bitwidth
        // the generic accumulator can't handle (0, or >= 8 other than
        // the explicit arms) is a corrupt/hostile stream — surface an
        // error, never garbage.
        anyhow::ensure!((1..8).contains(&p.bits), "unsupported wire bitwidth {}", p.bits);
        decode_tail(bytes, p, out);
        return Ok(());
    }
    #[cfg(target_arch = "x86_64")]
    if simd_ok && simd_enabled() && simd::decode_chunk(bytes, p, out) {
        return Ok(());
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = simd_ok;
    decode_chunk_scalar(bytes, p, out);
    Ok(())
}

/// The scalar fused decode over one validated chunk — the portable
/// reference implementation. `bytes` must hold at least
/// `packed_len(out.len(), p.bits)` bytes (callers validate).
fn decode_chunk_scalar(bytes: &[u8], p: &QuantParams, out: &mut [f32]) {
    let n = out.len();
    let (s, zp) = (p.scale, p.zero_point);
    let off = p.pack_offset();
    match p.bits {
        8 => {
            for (o, &b) in out.iter_mut().zip(bytes) {
                *o = dequantize_one(b as u32, off, s, zp);
            }
        }
        16 => {
            for (o, ch) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                *o = dequantize_one(u16::from_le_bytes([ch[0], ch[1]]) as u32, off, s, zp);
            }
        }
        2 => {
            let groups = n / 4;
            for (og, &b) in out[..groups * 4].chunks_exact_mut(4).zip(&bytes[..groups]) {
                let b = b as u32;
                og[0] = dequantize_one(b & 3, off, s, zp);
                og[1] = dequantize_one((b >> 2) & 3, off, s, zp);
                og[2] = dequantize_one((b >> 4) & 3, off, s, zp);
                og[3] = dequantize_one((b >> 6) & 3, off, s, zp);
            }
            decode_tail(&bytes[groups..], p, &mut out[groups * 4..]);
        }
        4 => {
            let groups = n / 2;
            for (og, &b) in out[..groups * 2].chunks_exact_mut(2).zip(&bytes[..groups]) {
                let b = b as u32;
                og[0] = dequantize_one(b & 0xf, off, s, zp);
                og[1] = dequantize_one((b >> 4) & 0xf, off, s, zp);
            }
            decode_tail(&bytes[groups..], p, &mut out[groups * 2..]);
        }
        6 => {
            let groups = n / 4;
            for (og, bg) in out[..groups * 4]
                .chunks_exact_mut(4)
                .zip(bytes[..groups * 3].chunks_exact(3))
            {
                let (b0, b1, b2) = (bg[0] as u32, bg[1] as u32, bg[2] as u32);
                og[0] = dequantize_one(b0 & 0x3f, off, s, zp);
                og[1] = dequantize_one(((b0 >> 6) | (b1 << 2)) & 0x3f, off, s, zp);
                og[2] = dequantize_one(((b1 >> 4) | (b2 << 4)) & 0x3f, off, s, zp);
                og[3] = dequantize_one((b2 >> 2) & 0x3f, off, s, zp);
            }
            decode_tail(&bytes[groups * 3..], p, &mut out[groups * 4..]);
        }
        // Callers (decode_impl) validated 1..8 for non-standard widths.
        _ => decode_tail(bytes, p, out),
    }
}

/// Generic bit-accumulator decode for a (short) byte-aligned tail — the
/// exact loop shape of [`super::pack::unpack`]'s sub-byte branch.
fn decode_tail(bytes: &[u8], p: &QuantParams, out: &mut [f32]) {
    let (s, zp) = (p.scale, p.zero_point);
    let off = p.pack_offset();
    let bits = p.bits as u32;
    let mask = (1u32 << bits) - 1;
    let mut acc: u32 = 0;
    let mut nbits: u32 = 0;
    let mut iter = bytes.iter();
    for o in out.iter_mut() {
        while nbits < bits {
            // Cannot run dry: the caller validated the payload length.
            acc |= (*iter.next().expect("decode length invariant") as u32) << nbits;
            nbits += 8;
        }
        *o = dequantize_one(acc & mask, off, s, zp);
        acc >>= bits;
        nbits -= bits;
    }
}

/// Bulk raw-f32 passthrough (`bits == 32`): one pre-sized copy into the
/// payload buffer instead of per-element `extend_from_slice` pushes.
/// `chunks_exact_mut(4)` + `copy_from_slice` compiles to straight-line
/// 4-byte stores with no per-push capacity checks. resize, not
/// clear+resize: the copy overwrites every byte, so a recycled
/// same-size buffer costs no memset.
pub fn raw_f32_into(x: &[f32], out: &mut Vec<u8>) {
    out.resize(x.len() * 4, 0);
    for (dst, v) in out.chunks_exact_mut(4).zip(x) {
        dst.copy_from_slice(&v.to_le_bytes());
    }
}

/// Explicit SSE2/AVX2 kernels for the `SUPPORTED_BITS` widths.
///
/// Everything here is byte-identical to the scalar kernels (asserted in
/// tests across widths × signedness × odd lengths × special values):
///
/// * **rounding** — `f32::round()` rounds half away from zero, but the
///   hardware float→int conversions round half to even (`cvtps`) or
///   truncate (`cvttps`). The kernels truncate, then add ±1 on lanes
///   whose fractional magnitude is ≥ 0.5. The fraction `c - trunc(c)` is
///   exact in f32 for `|c| ≤ 65536` (Sterbenz), which [`encode_eligible`]
///   guarantees via the clamp bounds — so the adjustment decision is
///   exact, never off by an ulp.
/// * **clamp order** — scalar rounds then clamps; the kernels clamp then
///   round. The two commute because [`encode_eligible`] requires
///   integer-valued `lo`/`hi` and rounding is monotone. Clamping first
///   also resolves NaN exactly like the scalar `max(lo).min(hi)` chain:
///   `max_ps(c, lo)` returns its *second* operand on unordered, so a NaN
///   lane becomes `lo`, same as `f32::max`.
/// * **no FMA** — multiply and add stay separate instructions, matching
///   scalar f32 arithmetic (Rust never contracts).
/// * **packing** — 8-bit uses saturating packs (exact: eligible codes fit
///   `0..=255`); 16-bit biases codes by 32768 so SSE2's signed-saturating
///   pack is exact, then flips the sign bit back (no SSE4.1 `packus`
///   needed at baseline). Sub-byte widths quantize through a 32-element
///   u8 staging block, then bit-pack scalar-wise (the shifts are cheap;
///   the float math dominates). 16-bit stays SSE2 even when AVX2 is
///   available: at 2 B/elem the loop is memory-bound and wider vectors
///   measured no faster.
///
/// Block tails (and whole ineligible calls) run the scalar kernels on
/// group-aligned boundaries, so the multicore chunking invariant makes
/// the mixed output byte-exact.
#[cfg(target_arch = "x86_64")]
mod simd {
    use super::{decode_chunk_scalar, encode_chunk_scalar, QuantParams};
    use core::arch::x86_64::*;
    use std::sync::OnceLock;

    /// Elements per sub-byte staging block: a multiple of every
    /// `SUPPORTED_BITS` group size (4, 2, 4) and of both u8-kernel block
    /// widths (SSE2: 16, AVX2: 32), so block boundaries are always
    /// group-aligned and the scalar tail stays byte-exact.
    const BLOCK: usize = 32;

    /// Cached AVX2 runtime detection (one `cpuid` ever).
    pub(super) fn avx2_available() -> bool {
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
    }

    /// True when the SIMD *encode* sequence is provably byte-identical to
    /// scalar for `p`:
    ///
    /// * `lo`/`hi` integer-valued (clamp-then-round == round-then-clamp),
    ///   which also rejects NaN bounds (`fract()` of NaN is NaN);
    /// * `|lo|, |hi| ≤ 65536` (truncate+adjust rounding is exact —
    ///   Sterbenz — and the i32 conversion cannot overflow);
    /// * the code span fits the staging width (u8 blocks for ≤ 8-bit,
    ///   i16-biased packing for 16-bit).
    ///
    /// Every parameter set produced by `calibrate` qualifies; hand-built
    /// ones that don't simply run scalar. Decode needs no gate: unpacked
    /// wire codes are already bounded by the staging width, and the
    /// dequantize arithmetic is the same IEEE ops in both paths.
    fn encode_eligible(p: &QuantParams) -> bool {
        let span = p.hi - p.lo;
        let span_ok = match p.bits {
            2 | 4 | 6 | 8 => span <= 255.0,
            16 => span <= 65535.0,
            _ => false,
        };
        span_ok
            && span >= 0.0
            && p.lo.fract() == 0.0
            && p.hi.fract() == 0.0
            && (-65536.0..=65536.0).contains(&p.lo)
            && (-65536.0..=65536.0).contains(&p.hi)
    }

    /// Broadcast quantizer constants for the 4-lane (SSE2) kernels.
    struct Ctx128 {
        inv: __m128,
        zp: __m128,
        lo: __m128,
        hi: __m128,
        half: __m128,
        absmask: __m128,
        one: __m128i,
        off: __m128i,
    }

    impl Ctx128 {
        fn new(p: &QuantParams) -> Self {
            // SAFETY: SSE2 register broadcasts; SSE2 is baseline on
            // x86_64, so these are always available.
            unsafe {
                Ctx128 {
                    inv: _mm_set1_ps(1.0 / p.scale),
                    zp: _mm_set1_ps(p.zero_point),
                    lo: _mm_set1_ps(p.lo),
                    hi: _mm_set1_ps(p.hi),
                    half: _mm_set1_ps(0.5),
                    absmask: _mm_castsi128_ps(_mm_set1_epi32(0x7fff_ffff)),
                    one: _mm_set1_epi32(1),
                    off: _mm_set1_epi32(p.pack_offset()),
                }
            }
        }
    }

    /// Broadcast dequantizer constants for the 4-lane kernels.
    struct DecCtx128 {
        scale: __m128,
        zp: __m128,
        off: __m128i,
    }

    impl DecCtx128 {
        fn new(p: &QuantParams) -> Self {
            // SAFETY: SSE2 register broadcasts; SSE2 is x86_64 baseline.
            unsafe {
                DecCtx128 {
                    scale: _mm_set1_ps(p.scale),
                    zp: _mm_set1_ps(p.zero_point),
                    off: _mm_set1_epi32(p.pack_offset()),
                }
            }
        }
    }

    /// Four lanes of `quantize_one` minus `pack_offset`: multiply-add
    /// (no FMA), clamp (NaN → lo via operand order), then exact
    /// round-half-away-from-zero by truncate + conditional ±1.
    #[inline(always)]
    fn quantize4(c: &Ctx128, v: __m128) -> __m128i {
        // SAFETY: SSE2-only arithmetic; SSE2 is x86_64 baseline.
        unsafe {
            let x = _mm_add_ps(_mm_mul_ps(v, c.inv), c.zp);
            // Clamp before rounding: commutes with the scalar order
            // because lo/hi are integers (encode_eligible), and max's
            // unordered rule turns NaN lanes into lo like f32::max.
            let x = _mm_min_ps(_mm_max_ps(x, c.lo), c.hi);
            let t = _mm_cvttps_epi32(x);
            // Fraction is exact (|x| ≤ 65536, Sterbenz), so the ≥ 0.5
            // test reproduces f32::round's half-away-from-zero exactly.
            let d = _mm_sub_ps(x, _mm_cvtepi32_ps(t));
            let ge = _mm_castps_si128(_mm_cmpge_ps(_mm_and_ps(d, c.absmask), c.half));
            let neg = _mm_castps_si128(_mm_cmplt_ps(x, _mm_setzero_ps()));
            // +1 on non-negative lanes, -1 on negative: (1 ^ m) - m for
            // the all-ones/-zero mask m.
            let pm1 = _mm_sub_epi32(_mm_xor_si128(c.one, neg), neg);
            let q = _mm_add_epi32(t, _mm_and_si128(ge, pm1));
            _mm_sub_epi32(q, c.off)
        }
    }

    /// Four lanes of `dequantize_one`: the same IEEE ops in the same
    /// order, so no eligibility gate is needed on decode.
    #[inline(always)]
    fn dequant4(c: &DecCtx128, u: __m128i) -> __m128 {
        // SAFETY: SSE2-only arithmetic; SSE2 is x86_64 baseline.
        unsafe {
            _mm_mul_ps(_mm_sub_ps(_mm_cvtepi32_ps(_mm_add_epi32(u, c.off)), c.zp), c.scale)
        }
    }

    /// SSE2 quantize of `x` into u8 codes, 16 elements per iteration.
    /// `x.len()` must be a multiple of 16 and equal `codes.len()`.
    fn codes_u8_sse2(c: &Ctx128, x: &[f32], codes: &mut [u8]) {
        debug_assert_eq!(x.len() % 16, 0);
        debug_assert_eq!(x.len(), codes.len());
        for (xb, ob) in x.chunks_exact(16).zip(codes.chunks_exact_mut(16)) {
            // SAFETY: SSE2 baseline; unaligned loads/stores, and every
            // pointer stays inside the 16-element chunk_exact windows.
            unsafe {
                let q0 = quantize4(c, _mm_loadu_ps(xb.as_ptr()));
                let q1 = quantize4(c, _mm_loadu_ps(xb.as_ptr().add(4)));
                let q2 = quantize4(c, _mm_loadu_ps(xb.as_ptr().add(8)));
                let q3 = quantize4(c, _mm_loadu_ps(xb.as_ptr().add(12)));
                // Saturating packs are exact: eligible codes are 0..=255.
                let w01 = _mm_packs_epi32(q0, q1);
                let w23 = _mm_packs_epi32(q2, q3);
                let b = _mm_packus_epi16(w01, w23);
                _mm_storeu_si128(ob.as_mut_ptr() as *mut __m128i, b);
            }
        }
    }

    /// SSE2 dequantize of u8 codes, 16 per iteration. `codes.len()` must
    /// be a multiple of 16 and equal `out.len()`.
    fn dequant_u8_sse2(c: &DecCtx128, codes: &[u8], out: &mut [f32]) {
        debug_assert_eq!(codes.len() % 16, 0);
        debug_assert_eq!(codes.len(), out.len());
        for (cb, ob) in codes.chunks_exact(16).zip(out.chunks_exact_mut(16)) {
            // SAFETY: SSE2 baseline; unaligned loads/stores inside the
            // 16-element chunk_exact windows.
            unsafe {
                let b = _mm_loadu_si128(cb.as_ptr() as *const __m128i);
                let z = _mm_setzero_si128();
                let w0 = _mm_unpacklo_epi8(b, z);
                let w1 = _mm_unpackhi_epi8(b, z);
                _mm_storeu_ps(ob.as_mut_ptr(), dequant4(c, _mm_unpacklo_epi16(w0, z)));
                _mm_storeu_ps(ob.as_mut_ptr().add(4), dequant4(c, _mm_unpackhi_epi16(w0, z)));
                _mm_storeu_ps(ob.as_mut_ptr().add(8), dequant4(c, _mm_unpacklo_epi16(w1, z)));
                _mm_storeu_ps(ob.as_mut_ptr().add(12), dequant4(c, _mm_unpackhi_epi16(w1, z)));
            }
        }
    }

    /// Broadcast quantizer constants for the 8-lane (AVX2) kernels.
    struct Ctx256 {
        inv: __m256,
        zp: __m256,
        lo: __m256,
        hi: __m256,
        half: __m256,
        absmask: __m256,
        one: __m256i,
        off: __m256i,
    }

    impl Ctx256 {
        #[target_feature(enable = "avx2")]
        // SAFETY: to call — caller must have verified AVX2 support
        // (avx2_available()); register broadcasts only.
        unsafe fn new(p: &QuantParams) -> Self {
            Ctx256 {
                inv: _mm256_set1_ps(1.0 / p.scale),
                zp: _mm256_set1_ps(p.zero_point),
                lo: _mm256_set1_ps(p.lo),
                hi: _mm256_set1_ps(p.hi),
                half: _mm256_set1_ps(0.5),
                absmask: _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff)),
                one: _mm256_set1_epi32(1),
                off: _mm256_set1_epi32(p.pack_offset()),
            }
        }
    }

    /// Broadcast dequantizer constants for the 8-lane kernels.
    struct DecCtx256 {
        scale: __m256,
        zp: __m256,
        off: __m256i,
    }

    impl DecCtx256 {
        #[target_feature(enable = "avx2")]
        // SAFETY: to call — caller must have verified AVX2 support
        // (avx2_available()); register broadcasts only.
        unsafe fn new(p: &QuantParams) -> Self {
            DecCtx256 {
                scale: _mm256_set1_ps(p.scale),
                zp: _mm256_set1_ps(p.zero_point),
                off: _mm256_set1_epi32(p.pack_offset()),
            }
        }
    }

    /// Eight lanes of `quantize_one` minus `pack_offset` — the AVX2
    /// mirror of [`quantize4`], same exact-rounding sequence.
    #[target_feature(enable = "avx2")]
    // SAFETY: to call — caller must have verified AVX2 support.
    unsafe fn quantize8(c: &Ctx256, v: __m256) -> __m256i {
        let x = _mm256_add_ps(_mm256_mul_ps(v, c.inv), c.zp);
        let x = _mm256_min_ps(_mm256_max_ps(x, c.lo), c.hi);
        let t = _mm256_cvttps_epi32(x);
        let d = _mm256_sub_ps(x, _mm256_cvtepi32_ps(t));
        let ad = _mm256_and_ps(d, c.absmask);
        let ge = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_GE_OQ>(ad, c.half));
        let neg = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(x, _mm256_setzero_ps()));
        let pm1 = _mm256_sub_epi32(_mm256_xor_si256(c.one, neg), neg);
        let q = _mm256_add_epi32(t, _mm256_and_si256(ge, pm1));
        _mm256_sub_epi32(q, c.off)
    }

    /// Eight lanes of `dequantize_one` — the AVX2 mirror of [`dequant4`].
    #[target_feature(enable = "avx2")]
    // SAFETY: to call — caller must have verified AVX2 support.
    unsafe fn dequant8(c: &DecCtx256, u: __m256i) -> __m256 {
        let f = _mm256_cvtepi32_ps(_mm256_add_epi32(u, c.off));
        _mm256_mul_ps(_mm256_sub_ps(f, c.zp), c.scale)
    }

    /// AVX2 quantize of `x` into u8 codes, 32 elements per iteration.
    /// `x.len()` must be a multiple of 32 and equal `codes.len()`.
    #[target_feature(enable = "avx2")]
    // SAFETY: to call — caller must have verified AVX2 support; pointers
    // stay inside the 32-element chunk_exact windows.
    unsafe fn codes_u8_avx2(c: &Ctx256, x: &[f32], codes: &mut [u8]) {
        debug_assert_eq!(x.len() % 32, 0);
        debug_assert_eq!(x.len(), codes.len());
        // The 128-bit-lane packs interleave q0..q3 per lane; this dword
        // permutation restores element order (d0 d4 d1 d5 d2 d6 d3 d7).
        let order = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
        for (xb, ob) in x.chunks_exact(32).zip(codes.chunks_exact_mut(32)) {
            let q0 = quantize8(c, _mm256_loadu_ps(xb.as_ptr()));
            let q1 = quantize8(c, _mm256_loadu_ps(xb.as_ptr().add(8)));
            let q2 = quantize8(c, _mm256_loadu_ps(xb.as_ptr().add(16)));
            let q3 = quantize8(c, _mm256_loadu_ps(xb.as_ptr().add(24)));
            let w01 = _mm256_packs_epi32(q0, q1);
            let w23 = _mm256_packs_epi32(q2, q3);
            let b = _mm256_packus_epi16(w01, w23);
            let b = _mm256_permutevar8x32_epi32(b, order);
            _mm256_storeu_si256(ob.as_mut_ptr() as *mut __m256i, b);
        }
    }

    /// AVX2 dequantize of u8 codes, 8 per iteration. `codes.len()` must
    /// be a multiple of 8 and equal `out.len()`.
    #[target_feature(enable = "avx2")]
    // SAFETY: to call — caller must have verified AVX2 support; the
    // 8-byte load and 8-float store stay inside the chunk_exact windows.
    unsafe fn dequant_u8_avx2(c: &DecCtx256, codes: &[u8], out: &mut [f32]) {
        debug_assert_eq!(codes.len() % 8, 0);
        debug_assert_eq!(codes.len(), out.len());
        for (cb, ob) in codes.chunks_exact(8).zip(out.chunks_exact_mut(8)) {
            let b = _mm_loadl_epi64(cb.as_ptr() as *const __m128i);
            let u = _mm256_cvtepu8_epi32(b);
            _mm256_storeu_ps(ob.as_mut_ptr(), dequant8(c, u));
        }
    }

    /// 8-bit encode: the u8 code stream *is* the wire stream.
    fn encode_u8(x: &[f32], p: &QuantParams, out: &mut [u8]) {
        if avx2_available() {
            let n = x.len() / 32 * 32;
            // SAFETY: avx2_available() checked the CPUID feature bit.
            unsafe {
                let c = Ctx256::new(p);
                codes_u8_avx2(&c, &x[..n], &mut out[..n]);
            }
            encode_chunk_scalar(&x[n..], p, &mut out[n..]);
        } else {
            let c = Ctx128::new(p);
            let n = x.len() / 16 * 16;
            codes_u8_sse2(&c, &x[..n], &mut out[..n]);
            encode_chunk_scalar(&x[n..], p, &mut out[n..]);
        }
    }

    /// 8-bit decode: the wire stream *is* the u8 code stream.
    fn decode_u8(bytes: &[u8], p: &QuantParams, out: &mut [f32]) {
        if avx2_available() {
            let n = out.len() / 8 * 8;
            // SAFETY: avx2_available() checked the CPUID feature bit.
            unsafe {
                let c = DecCtx256::new(p);
                dequant_u8_avx2(&c, &bytes[..n], &mut out[..n]);
            }
            decode_chunk_scalar(&bytes[n..], p, &mut out[n..]);
        } else {
            let c = DecCtx128::new(p);
            let n = out.len() / 16 * 16;
            dequant_u8_sse2(&c, &bytes[..n], &mut out[..n]);
            decode_chunk_scalar(&bytes[n..], p, &mut out[n..]);
        }
    }

    /// 16-bit encode, SSE2 (kept SSE2 even under AVX2: 2 B/elem is
    /// memory-bound). Codes are biased by 32768 so the signed-saturating
    /// pack is exact for the full `0..=65535` range, then the sign bit is
    /// flipped back — `(u - 32768) ^ 0x8000 ≡ u (mod 2^16)`.
    fn encode_u16(x: &[f32], p: &QuantParams, out: &mut [u8]) {
        let c = Ctx128::new(p);
        let blocks = x.len() / 8;
        // SAFETY: SSE2 baseline; unaligned loads/stores inside the
        // chunk_exact windows (8 floats in, 16 bytes out per block).
        unsafe {
            let bias = _mm_set1_epi32(1 << 15);
            let flip = _mm_set1_epi16(i16::MIN);
            for (xb, ob) in x[..blocks * 8]
                .chunks_exact(8)
                .zip(out[..blocks * 16].chunks_exact_mut(16))
            {
                let q0 = quantize4(&c, _mm_loadu_ps(xb.as_ptr()));
                let q1 = quantize4(&c, _mm_loadu_ps(xb.as_ptr().add(4)));
                let w = _mm_packs_epi32(_mm_sub_epi32(q0, bias), _mm_sub_epi32(q1, bias));
                let w = _mm_xor_si128(w, flip);
                _mm_storeu_si128(ob.as_mut_ptr() as *mut __m128i, w);
            }
        }
        encode_chunk_scalar(&x[blocks * 8..], p, &mut out[blocks * 16..]);
    }

    /// 16-bit decode, SSE2: little-endian u16 lanes zero-extend to u32
    /// exactly like `u16::from_le_bytes` on this target.
    fn decode_u16(bytes: &[u8], p: &QuantParams, out: &mut [f32]) {
        let c = DecCtx128::new(p);
        let blocks = out.len() / 8;
        for (bb, ob) in bytes[..blocks * 16]
            .chunks_exact(16)
            .zip(out[..blocks * 8].chunks_exact_mut(8))
        {
            // SAFETY: SSE2 baseline; unaligned loads/stores inside the
            // chunk_exact windows (16 bytes in, 8 floats out per block).
            unsafe {
                let w = _mm_loadu_si128(bb.as_ptr() as *const __m128i);
                let z = _mm_setzero_si128();
                _mm_storeu_ps(ob.as_mut_ptr(), dequant4(&c, _mm_unpacklo_epi16(w, z)));
                _mm_storeu_ps(ob.as_mut_ptr().add(4), dequant4(&c, _mm_unpackhi_epi16(w, z)));
            }
        }
        decode_chunk_scalar(&bytes[blocks * 16..], p, &mut out[blocks * 8..]);
    }

    /// Scalar bit-pack of one staging block of u8 codes — the mask/shift
    /// patterns of `encode_chunk_scalar`'s 2/4/6-bit arms, applied to
    /// already-quantized codes.
    fn pack_codes(codes: &[u8], bits: u8, out: &mut [u8]) {
        match bits {
            2 => {
                for (o, g) in out.iter_mut().zip(codes.chunks_exact(4)) {
                    *o = (g[0] & 3) | ((g[1] & 3) << 2) | ((g[2] & 3) << 4) | ((g[3] & 3) << 6);
                }
            }
            4 => {
                for (o, g) in out.iter_mut().zip(codes.chunks_exact(2)) {
                    *o = (g[0] & 0xf) | ((g[1] & 0xf) << 4);
                }
            }
            6 => {
                for (o, g) in out.chunks_exact_mut(3).zip(codes.chunks_exact(4)) {
                    let (q0, q1) = (g[0] as u32 & 0x3f, g[1] as u32 & 0x3f);
                    let (q2, q3) = (g[2] as u32 & 0x3f, g[3] as u32 & 0x3f);
                    o[0] = (q0 | (q1 << 6)) as u8;
                    o[1] = ((q1 >> 2) | (q2 << 4)) as u8;
                    o[2] = ((q2 >> 4) | (q3 << 2)) as u8;
                }
            }
            _ => unreachable!("pack_codes only handles 2/4/6-bit"),
        }
    }

    /// Scalar bit-unpack of one staging block into u8 codes — the
    /// mask/shift patterns of `decode_chunk_scalar`'s 2/4/6-bit arms.
    fn unpack_codes(bytes: &[u8], bits: u8, codes: &mut [u8]) {
        match bits {
            2 => {
                for (g, &b) in codes.chunks_exact_mut(4).zip(bytes) {
                    g[0] = b & 3;
                    g[1] = (b >> 2) & 3;
                    g[2] = (b >> 4) & 3;
                    g[3] = b >> 6;
                }
            }
            4 => {
                for (g, &b) in codes.chunks_exact_mut(2).zip(bytes) {
                    g[0] = b & 0xf;
                    g[1] = b >> 4;
                }
            }
            6 => {
                for (g, bg) in codes.chunks_exact_mut(4).zip(bytes.chunks_exact(3)) {
                    let (b0, b1, b2) = (bg[0] as u32, bg[1] as u32, bg[2] as u32);
                    g[0] = (b0 & 0x3f) as u8;
                    g[1] = (((b0 >> 6) | (b1 << 2)) & 0x3f) as u8;
                    g[2] = (((b1 >> 4) | (b2 << 4)) & 0x3f) as u8;
                    g[3] = ((b2 >> 2) & 0x3f) as u8;
                }
            }
            _ => unreachable!("unpack_codes only handles 2/4/6-bit"),
        }
    }

    /// Sub-byte (2/4/6-bit) encode: SIMD float math into a [`BLOCK`]-wide
    /// u8 staging buffer, then scalar bit-packing per block.
    fn encode_subbyte(x: &[f32], p: &QuantParams, out: &mut [u8]) {
        let bpb = BLOCK * p.bits as usize / 8;
        let blocks = x.len() / BLOCK;
        let mut codes = [0u8; BLOCK];
        if avx2_available() {
            // SAFETY: avx2_available() checked the CPUID feature bit.
            let c = unsafe { Ctx256::new(p) };
            for i in 0..blocks {
                // SAFETY: avx2_available() checked the CPUID feature bit.
                unsafe { codes_u8_avx2(&c, &x[i * BLOCK..][..BLOCK], &mut codes) };
                pack_codes(&codes, p.bits, &mut out[i * bpb..][..bpb]);
            }
        } else {
            let c = Ctx128::new(p);
            for i in 0..blocks {
                codes_u8_sse2(&c, &x[i * BLOCK..][..BLOCK], &mut codes);
                pack_codes(&codes, p.bits, &mut out[i * bpb..][..bpb]);
            }
        }
        encode_chunk_scalar(&x[blocks * BLOCK..], p, &mut out[blocks * bpb..]);
    }

    /// Sub-byte (2/4/6-bit) decode: scalar bit-unpack into the staging
    /// buffer, then SIMD dequantize per block.
    fn decode_subbyte(bytes: &[u8], p: &QuantParams, out: &mut [f32]) {
        let bpb = BLOCK * p.bits as usize / 8;
        let blocks = out.len() / BLOCK;
        let mut codes = [0u8; BLOCK];
        if avx2_available() {
            // SAFETY: avx2_available() checked the CPUID feature bit.
            let c = unsafe { DecCtx256::new(p) };
            for i in 0..blocks {
                unpack_codes(&bytes[i * bpb..][..bpb], p.bits, &mut codes);
                // SAFETY: avx2_available() checked the CPUID feature bit.
                unsafe { dequant_u8_avx2(&c, &codes, &mut out[i * BLOCK..][..BLOCK]) };
            }
        } else {
            let c = DecCtx128::new(p);
            for i in 0..blocks {
                unpack_codes(&bytes[i * bpb..][..bpb], p.bits, &mut codes);
                dequant_u8_sse2(&c, &codes, &mut out[i * BLOCK..][..BLOCK]);
            }
        }
        decode_chunk_scalar(&bytes[blocks * bpb..], p, &mut out[blocks * BLOCK..]);
    }

    /// SIMD encode dispatch. Returns `false` (caller runs scalar) when
    /// the width has no SIMD kernel or the params are not
    /// [`encode_eligible`].
    pub(super) fn encode_chunk(x: &[f32], p: &QuantParams, out: &mut [u8]) -> bool {
        if !encode_eligible(p) {
            return false;
        }
        match p.bits {
            8 => encode_u8(x, p, out),
            16 => encode_u16(x, p, out),
            2 | 4 | 6 => encode_subbyte(x, p, out),
            _ => return false,
        }
        true
    }

    /// SIMD decode dispatch. Returns `false` (caller runs scalar) when
    /// the width has no SIMD kernel. No parameter gate: decode is
    /// bit-identical for every parameter set (see [`encode_eligible`]).
    pub(super) fn decode_chunk(bytes: &[u8], p: &QuantParams, out: &mut [f32]) -> bool {
        match p.bits {
            8 => decode_u8(bytes, p, out),
            16 => decode_u16(bytes, p, out),
            2 | 4 | 6 => decode_subbyte(bytes, p, out),
            _ => return false,
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{pack, uniform, SUPPORTED_BITS};

    fn test_tensor(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::seed(seed);
        (0..n)
            .map(|i| {
                let v = rng.laplace(0.8) as f32;
                if i % 113 == 0 {
                    v * 9.0 // outliers exercise both clamp edges
                } else {
                    v
                }
            })
            .collect()
    }

    /// Both code-range conventions: symmetric signed (zp = 0, lo < 0, the
    /// ACIQ family) and asymmetric unsigned (naive min/max, lo = 0).
    fn param_set(x: &[f32], bits: u8) -> [QuantParams; 2] {
        [
            uniform::symmetric_params(1.5, bits),
            uniform::naive_params(x, bits),
        ]
    }

    fn legacy_encode(x: &[f32], p: &QuantParams) -> Vec<u8> {
        let codes = uniform::quantize(x, p);
        pack::pack_vec(&codes, p.bits, p.pack_offset())
    }

    fn legacy_decode(bytes: &[u8], n: usize, p: &QuantParams) -> Vec<f32> {
        let codes = pack::unpack_vec(bytes, n, p.bits, p.pack_offset()).unwrap();
        uniform::dequantize(&codes, p)
    }

    #[test]
    fn fused_encode_byte_identical_to_two_pass() {
        for bits in SUPPORTED_BITS {
            for n in [0usize, 1, 3, 5, 7, 8, 31, 63, 97, 255, 1000, 1001] {
                let x = test_tensor(n, 11 + n as u64);
                for p in param_set(&x, bits) {
                    let legacy = legacy_encode(&x, &p);
                    let mut fusedv = Vec::new();
                    encode_into(&x, &p, &mut fusedv);
                    assert_eq!(fusedv, legacy, "bits={bits} n={n} lo={}", p.lo);
                }
            }
        }
    }

    #[test]
    fn fused_decode_bit_identical_to_two_pass() {
        for bits in SUPPORTED_BITS {
            for n in [1usize, 3, 7, 63, 97, 1001] {
                let x = test_tensor(n, 29 + n as u64);
                for p in param_set(&x, bits) {
                    let payload = legacy_encode(&x, &p);
                    let legacy = legacy_decode(&payload, n, &p);
                    let mut fusedv = vec![0f32; n];
                    decode_into(&payload, &p, &mut fusedv).unwrap();
                    // Bit-level equality, not approximate: the fused path
                    // must be a drop-in for unpack+dequantize.
                    let a: Vec<u32> = legacy.iter().map(|v| v.to_bits()).collect();
                    let b: Vec<u32> = fusedv.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(a, b, "bits={bits} n={n} lo={}", p.lo);
                }
            }
        }
    }

    #[test]
    fn roundtrip_matches_uniform_roundtrip() {
        for bits in SUPPORTED_BITS {
            let x = test_tensor(513, 7);
            let p = uniform::symmetric_params(1.0, bits);
            let mut payload = Vec::new();
            encode_into(&x, &p, &mut payload);
            let mut back = vec![0f32; x.len()];
            decode_into(&payload, &p, &mut back).unwrap();
            assert_eq!(back, uniform::roundtrip(&x, &p), "bits={bits}");
        }
    }

    #[test]
    fn parallel_encode_equals_serial_bytes() {
        // Odd length: the final chunk is unaligned and the tail crosses a
        // partial byte at sub-byte widths.
        let n = MT_MIN_CHUNK_ELEMS * 3 + 37;
        let x = test_tensor(n, 3);
        for bits in SUPPORTED_BITS {
            for p in param_set(&x, bits) {
                let mut serial = Vec::new();
                encode_into(&x, &p, &mut serial);
                for threads in [2usize, 3, 5, 16] {
                    let mut par = Vec::new();
                    encode_into_mt(&x, &p, threads, &mut par);
                    assert_eq!(par, serial, "bits={bits} threads={threads}");
                }
            }
        }
        // Generic sub-byte widths (the accumulator fallback): chunk
        // alignment must hold there too — group_elems(3) = 8, not 1.
        for bits in [3u8, 5, 7] {
            let p = uniform::symmetric_params(1.0, bits);
            let mut serial = Vec::new();
            encode_into(&x, &p, &mut serial);
            for threads in [2usize, 3] {
                let mut par = Vec::new();
                encode_into_mt(&x, &p, threads, &mut par);
                assert_eq!(par, serial, "bits={bits} threads={threads}");
            }
        }
    }

    #[test]
    fn small_tensors_stay_serial_and_equal() {
        let x = test_tensor(1000, 5);
        let p = uniform::symmetric_params(1.0, 4);
        let mut serial = Vec::new();
        encode_into(&x, &p, &mut serial);
        let mut par = Vec::new();
        encode_into_mt(&x, &p, 8, &mut par);
        assert_eq!(par, serial);
    }

    #[test]
    fn truncated_payload_is_error() {
        let x = test_tensor(100, 17);
        for bits in SUPPORTED_BITS {
            let p = uniform::symmetric_params(1.0, bits);
            let mut payload = Vec::new();
            encode_into(&x, &p, &mut payload);
            let mut out = vec![0f32; x.len()];
            let err = decode_into(&payload[..payload.len() - 1], &p, &mut out).unwrap_err();
            assert!(err.to_string().contains("truncated"), "bits={bits}: {err:#}");
        }
    }

    #[test]
    fn hostile_wire_bitwidth_is_an_error_not_garbage() {
        // A frame can claim any bits value; the generic fallback only
        // handles sub-byte widths (pack's own contract) — anything else
        // must surface as a decode error.
        let mut p = uniform::symmetric_params(1.0, 4);
        let bytes = vec![0u8; 64];
        let mut out = vec![0f32; 16];
        // bits = 0 would pass the length check trivially (0 bytes
        // needed) and decode to constant garbage without the guard.
        for bad in [0u8, 13, 24] {
            p.bits = bad;
            let err = decode_into(&bytes, &p, &mut out).unwrap_err();
            assert!(err.to_string().contains("unsupported"), "bits={bad}: {err:#}");
        }
        // Odd-but-sub-byte widths still decode through the accumulator.
        p.bits = 3;
        assert!(decode_into(&bytes, &p, &mut out).is_ok());
    }

    #[test]
    fn raw_passthrough_is_exact_le_bytes() {
        let x = test_tensor(257, 23);
        let mut out = Vec::new();
        raw_f32_into(&x, &mut out);
        assert_eq!(out.len(), x.len() * 4);
        for (v, ch) in x.iter().zip(out.chunks_exact(4)) {
            assert_eq!(ch, v.to_le_bytes());
        }
        // Buffer reuse: capacity survives a second fill.
        let ptr = out.as_ptr();
        raw_f32_into(&x, &mut out);
        assert_eq!(out.as_ptr(), ptr);
    }

    #[test]
    fn group_alignment_constants() {
        for bits in 1u8..=16 {
            let g = group_elems(bits);
            // lcm(bits, 8) / bits: groups end exactly on byte boundaries,
            // and g is minimal (no smaller positive multiple aligns).
            assert_eq!(
                (g * bits as usize) % 8,
                0,
                "group of {g} elems at {bits}-bit must be byte-aligned"
            );
            for smaller in 1..g {
                assert_ne!((smaller * bits as usize) % 8, 0, "g={g} not minimal at {bits}-bit");
            }
        }
        assert_eq!(group_elems(2), 4);
        assert_eq!(group_elems(4), 2);
        assert_eq!(group_elems(6), 4);
        assert_eq!(group_elems(8), 1);
        assert_eq!(group_elems(16), 1);
        assert_eq!(group_elems(3), 8);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "outside 1..=16")]
    fn group_elems_rejects_out_of_contract_widths() {
        let _ = group_elems(17);
    }

    #[test]
    fn simd_matches_scalar_bytes_for_all_widths() {
        // Lengths straddle every SIMD block boundary (16/32-element
        // blocks plus group-aligned tails), both signedness conventions.
        for bits in SUPPORTED_BITS {
            for n in [1usize, 3, 15, 16, 17, 31, 32, 33, 63, 97, 255, 1000, 1001, 4097] {
                let x = test_tensor(n, 41 + n as u64);
                for p in param_set(&x, bits) {
                    let mut scalar = Vec::new();
                    encode_into_scalar(&x, &p, &mut scalar);
                    let mut dispatched = Vec::new();
                    encode_into(&x, &p, &mut dispatched);
                    assert_eq!(dispatched, scalar, "encode bits={bits} n={n} lo={}", p.lo);
                    let mut a = vec![0f32; n];
                    let mut b = vec![0f32; n];
                    decode_into_scalar(&scalar, &p, &mut a).unwrap();
                    decode_into(&scalar, &p, &mut b).unwrap();
                    let abits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                    let bbits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(abits, bbits, "decode bits={bits} n={n} lo={}", p.lo);
                }
            }
        }
    }

    #[test]
    fn simd_rounding_matches_round_half_away_from_zero() {
        // Values that distinguish truncation, round-half-to-even (the
        // hardware cvtps default), and f32::round (half away from zero),
        // plus the largest f32 strictly below 0.5 — an add-0.5-and-
        // truncate shortcut would round it up.
        let mut x = vec![
            0.5f32,
            -0.5,
            1.5,
            -1.5,
            2.5,
            -2.5,
            0.499_999_97,
            -0.499_999_97,
            3.499_999_8,
            126.5,
            -126.5,
            16384.5,
            -16384.5,
            32766.5,
            -32766.5,
            0.0,
            -0.0,
            0.75,
            -0.75,
        ];
        while x.len() % 32 != 0 {
            x.push(0.25); // pad so the SIMD block path engages
        }
        for bits in SUPPORTED_BITS {
            let half = 1i64 << (bits - 1);
            let p = QuantParams {
                scale: 1.0,
                zero_point: 0.0,
                lo: (-half) as f32,
                hi: (half - 1) as f32,
                bits,
            };
            let mut scalar = Vec::new();
            encode_into_scalar(&x, &p, &mut scalar);
            let mut dispatched = Vec::new();
            encode_into(&x, &p, &mut dispatched);
            assert_eq!(dispatched, scalar, "bits={bits}");
        }
    }

    #[test]
    fn simd_special_values_match_scalar() {
        let mut x = test_tensor(256, 77);
        x[0] = f32::NAN;
        x[17] = f32::INFINITY;
        x[33] = f32::NEG_INFINITY;
        x[64] = f32::MAX;
        x[100] = f32::MIN;
        x[130] = -0.0;
        for bits in SUPPORTED_BITS {
            for p in param_set(&x, bits) {
                let mut scalar = Vec::new();
                encode_into_scalar(&x, &p, &mut scalar);
                let mut dispatched = Vec::new();
                encode_into(&x, &p, &mut dispatched);
                assert_eq!(dispatched, scalar, "bits={bits} lo={}", p.lo);
            }
        }
    }

    #[test]
    fn non_integer_clip_bounds_fall_back_to_scalar_bytes() {
        // Hand-built params with fractional bounds are not SIMD-eligible;
        // the dispatcher must still produce the scalar bytes (by falling
        // back), keeping the byte-identical contract unconditional.
        let x = test_tensor(512, 91);
        let p = QuantParams { scale: 0.037, zero_point: 0.25, lo: -7.5, hi: 7.5, bits: 4 };
        let mut scalar = Vec::new();
        encode_into_scalar(&x, &p, &mut scalar);
        let mut dispatched = Vec::new();
        encode_into(&x, &p, &mut dispatched);
        assert_eq!(dispatched, scalar);
    }

    #[test]
    fn simd_toggle_and_reporting() {
        // The only test that flips the toggle: byte-identity makes the
        // flip invisible to every other test's results, but simd_active()
        // readings would race if asserted from two tests at once.
        assert!(["avx2", "sse2", "scalar"].contains(&simd_active()));
        let x = test_tensor(1000, 51);
        let p = uniform::symmetric_params(1.2, 4);
        let mut on = Vec::new();
        encode_into(&x, &p, &mut on);
        set_simd_enabled(false);
        assert_eq!(simd_active(), "scalar");
        let mut off = Vec::new();
        encode_into(&x, &p, &mut off);
        set_simd_enabled(true);
        assert_eq!(on, off);
        assert!(simd_enabled());
    }
}
