//! DS-ACIQ: the paper's directed-search refinement of ACIQ (§3, Eq. 1).
//!
//! ACIQ's moment estimate `b_E = mean(|x|)` fits a Laplace whose density can
//! be far from the real activation histogram — the "gap between the
//! estimated and real data distributions" the paper identifies. DS-ACIQ
//! bridges it by numerically searching for
//!
//! ```text
//! b* = argmin_{b in [b_E, b_R]}  MSE(D_R, D_E(b))            (Eq. 1)
//! ```
//!
//! where `D_R` is the real density histogram, `D_E(b)` the Laplace(0, b)
//! density, and the boundary `b_R = [2 · max(D_R)]^{-1}` is the Laplace
//! scale whose peak equals the real peak. The search direction follows the
//! peak comparison: if `max(D_R) < max(D_E)` the real distribution is
//! broader than the estimate, so candidates increase towards `b_R`; vice
//! versa (the heavy-tailed transformer case — a sharper real bulk means
//! `b* < b_E`, a *tighter* clip `alpha = F(q) b*`, and that is what rescues
//! 2-bit accuracy in Table 1). `t` is heuristically 100 (paper §3); the
//! search either finds a strictly better fit or falls back to `b_E`.
//!
//! Cost: one |x| histogram pass + `t` closed-form density evaluations over
//! the bins — <1% of stage compute (measured in benches/quant_codec.rs,
//! matching the paper's "<1% overhead" claim).

use super::stats::{AbsHistogram, CalibScan, DEFAULT_BINS};

/// `t` from the paper: number of directed-search steps.
pub const DEFAULT_STEPS: usize = 100;

/// Outcome of the directed search (Fig 4's data).
#[derive(Debug, Clone, Copy)]
pub struct DsResult {
    /// Moment estimate the search started from.
    pub b_e: f32,
    /// Search boundary derived from the real density peak.
    pub b_r: f32,
    /// The refined scale (== `b_e` if no candidate improved the fit).
    pub b_star: f32,
    /// Density-fit MSE at `b_e` (ACIQ's implicit estimate quality).
    pub fit_mse_e: f64,
    /// Density-fit MSE at `b_star`.
    pub fit_mse_star: f64,
}

impl DsResult {
    /// Relative fit improvement (paper reports ~50% at 2-bit on ViT-Base).
    pub fn improvement(&self) -> f64 {
        if self.fit_mse_e <= 0.0 {
            return 0.0;
        }
        1.0 - self.fit_mse_star / self.fit_mse_e
    }
}

/// Eq. 1 objective: MSE between the real histogram density and the
/// Laplace(0, b) density over the histogram support.
///
/// Perf: the bin centers are uniformly spaced, so the Laplace density
/// follows a geometric recurrence `d_e(i+1) = d_e(i) · e^{-w/b}` — one
/// `exp` per call instead of one per bin. This is what gets the paper's
/// "<1% overhead" claim for the 100-step search (EXPERIMENTS.md §Perf:
/// 2.3 ms → ~0.25 ms per search on the 131k-element boundary activation).
pub fn density_fit_mse(hist: &AbsHistogram, b: f64) -> f64 {
    let bins = hist.counts.len();
    let inv_2b = 1.0 / (2.0 * b);
    let decay = (-hist.width / b).exp();
    // d_e at the first bin center (width/2).
    let mut d_e = (-hist.center(0) / b).exp() * inv_2b;
    let norm = 1.0 / (hist.total.max(1) as f64 * hist.width) / 2.0;
    let mut acc = 0f64;
    for &c in hist.counts.iter() {
        let d_r = c as f64 * norm;
        let d = d_r - d_e;
        acc += d * d;
        d_e *= decay;
    }
    acc / bins as f64
}

/// Quantization reconstruction MSE at clip `alpha`, evaluated on the |x|
/// histogram (the quantizer is odd, so folding onto |x| is exact). Used by
/// the acceptance guard — "it either finds the parameter b* that gives a
/// lower MSE or otherwise uses b_E" (§3).
pub fn hist_quant_mse(hist: &AbsHistogram, alpha: f32, bits: u8) -> f64 {
    let p = super::uniform::symmetric_params(alpha, bits);
    let inv = 1.0 / p.scale as f64;
    let (lo, hi) = (p.lo as f64, p.hi as f64);
    let mut acc = 0f64;
    for (i, &c) in hist.counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let x = hist.center(i);
        let code = (x * inv).round().clamp(lo, hi);
        let xh = code * p.scale as f64;
        acc += c as f64 * (x - xh) * (x - xh);
    }
    acc / hist.total.max(1) as f64
}

/// Run the directed search on a precomputed histogram: argmin over b of
/// the Eq. 1 density-fit MSE, falling back to `b_E` when no candidate
/// improves the fit ("it either finds the parameter b* that gives a lower
/// MSE or otherwise use the b_E"). `bits` selects the clip ratio used by
/// downstream calibration; the fit objective itself is bitwidth-free.
pub fn ds_search(hist: &AbsHistogram, b_e: f32, bits: u8, steps: usize) -> DsResult {
    let _ = bits;
    let peak_r = hist.peak_density().max(1e-300);
    let b_r = (1.0 / (2.0 * peak_r)) as f32;
    let fit_e = density_fit_mse(hist, b_e.max(1e-12) as f64);

    let mut best_b = b_e;
    let mut best = fit_e;
    for i in 1..=steps {
        let b = b_e + (b_r - b_e) * (i as f32 / steps as f32);
        if b <= 0.0 {
            break;
        }
        let m = density_fit_mse(hist, b as f64);
        if m < best {
            best = m;
            best_b = b;
        }
    }
    DsResult { b_e, b_r, b_star: best_b, fit_mse_e: fit_e, fit_mse_star: best }
}

/// Full DS-ACIQ calibration for tensor `x` at `bits` (exact: full data,
/// DEFAULT_BINS — matches ref.py bit-for-bit and is what the golden tests
/// pin). The fused [`CalibScan`] derives `b_E` and the histogram's top
/// from one stats pass, so calibration is a stats pass + a binning pass
/// instead of the old three separate scans (mean|x|, max|x|, binning) —
/// numerically identical output.
pub fn ds_aciq_b(x: &[f32], bits: u8, steps: usize) -> DsResult {
    let scan = CalibScan::compute(x, DEFAULT_BINS);
    ds_search(&scan.hist, scan.b_e(), bits, steps)
}

/// Hot-path variant: build the search histogram from a strided subsample
/// of at most `max_n` elements. Calibration is a statistical estimate, so
/// a 16k subsample of a 131k activation moves b* negligibly (validated in
/// tests) while cutting the per-microbatch search cost ~4x — this is how
/// the deployed PDA module keeps the paper's "<1% overhead" property even
/// on testbeds with much faster stage compute than the paper's Jetsons.
/// Full-tensor memory traffic is a single strided read (materializing the
/// sample); the fused scan's stats and binning passes then run over the
/// cache-resident ≤`max_n`-element sample.
pub fn ds_aciq_b_sampled(x: &[f32], bits: u8, steps: usize, max_n: usize) -> DsResult {
    let stride = x.len().div_ceil(max_n.max(1)).max(1);
    if stride == 1 {
        return ds_aciq_b(x, bits, steps);
    }
    let sample: Vec<f32> = x.iter().step_by(stride).copied().collect();
    let scan = CalibScan::compute(&sample, DEFAULT_BINS);
    ds_search(&scan.hist, scan.b_e(), bits, steps)
}

/// Subsample cap used by the pipeline's per-microbatch calibration.
pub const CALIB_MAX_SAMPLES: usize = 16384;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn laplace(n: usize, b: f32, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.laplace(b as f64) as f32).collect()
    }

    fn gauss(n: usize, sigma: f32, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| (rng.gaussian() * sigma as f64) as f32).collect()
    }

    #[test]
    fn pure_laplace_needs_no_correction() {
        let mut rng = Rng::seed(1);
        let x = laplace(60000, 1.0, &mut rng);
        let r = ds_aciq_b(&x, 2, DEFAULT_STEPS);
        // b* stays within a few percent of the (correct) moment estimate.
        assert!((r.b_star / r.b_e - 1.0).abs() < 0.15, "{r:?}");
    }

    #[test]
    fn never_worse_than_moment_estimate() {
        let mut rng = Rng::seed(2);
        for _ in 0..5 {
            let mut x = gauss(20000, 0.3, &mut rng);
            x.extend(laplace(5000, 2.0, &mut rng));
            let r = ds_aciq_b(&x, 2, DEFAULT_STEPS);
            assert!(r.fit_mse_star <= r.fit_mse_e + 1e-18);
        }
    }

    #[test]
    fn peaked_mixture_searches_down() {
        // Heavy-tailed scale mixture: narrow bulk + wide tail. The moment
        // estimate overshoots the bulk; the real peak is higher than the
        // Laplace(b_E) peak, so the search moves b downwards (b_r < b_e)
        // and finds a strictly better fit — the Fig 4 regime.
        let mut rng = Rng::seed(3);
        let mut x = laplace(50000, 0.1, &mut rng);
        x.extend(laplace(5000, 2.0, &mut rng));
        let r = ds_aciq_b(&x, 2, DEFAULT_STEPS);
        assert!(r.b_r < r.b_e, "{r:?}");
        assert!(r.b_star < r.b_e, "{r:?}");
        assert!(r.improvement() > 0.3, "{r:?}");
    }

    #[test]
    fn broad_distribution_searches_up() {
        // Sub-Laplace (uniform-ish) data: real peak lower than estimate's.
        let x: Vec<f32> = (0..40000).map(|i| (i as f32 / 20000.0) - 1.0).collect();
        let r = ds_aciq_b(&x, 2, DEFAULT_STEPS);
        assert!(r.b_r > r.b_e, "{r:?}");
        assert!(r.b_star >= r.b_e, "{r:?}");
    }

    #[test]
    fn fit_mse_zero_iff_perfect_laplace_shape() {
        // Construct a histogram directly from the Laplace density: the fit
        // at the true b should be near-zero and far better than 2x-off b.
        let b = 0.7f64;
        let bins = 512;
        let top = 8.0 * b;
        let width = top / bins as f64;
        let mut counts = vec![0u64; bins];
        let total: u64 = 1 << 22;
        for i in 0..bins {
            let c = (i as f64 + 0.5) * width;
            let p = ((-c / b).exp() / b) * width; // |x| density * width
            counts[i] = (p * total as f64) as u64;
        }
        let hist = AbsHistogram {
            total: counts.iter().sum(),
            counts,
            width,
        };
        let at_true = density_fit_mse(&hist, b);
        let at_wrong = density_fit_mse(&hist, 2.0 * b);
        assert!(at_true < at_wrong * 0.05, "{at_true} vs {at_wrong}");
    }

    #[test]
    fn sampled_calibration_close_to_exact() {
        let mut rng = Rng::seed(8);
        let mut x = laplace(100_000, 0.2, &mut rng);
        x.extend(laplace(10_000, 1.5, &mut rng));
        let exact = ds_aciq_b(&x, 2, DEFAULT_STEPS);
        let fast = ds_aciq_b_sampled(&x, 2, DEFAULT_STEPS, CALIB_MAX_SAMPLES);
        assert!(
            (fast.b_star / exact.b_star - 1.0).abs() < 0.1,
            "exact {exact:?} vs sampled {fast:?}"
        );
    }

    #[test]
    fn search_cost_is_bounded() {
        // DEFAULT_STEPS evaluations over DEFAULT_BINS bins: sanity-check the
        // search completes fast enough to be control-path (<1% overhead is
        // measured properly in benches/quant_codec.rs).
        let mut rng = Rng::seed(4);
        let x = laplace(1024 * 128, 0.5, &mut rng);
        let t0 = std::time::Instant::now();
        let _ = ds_aciq_b(&x, 2, DEFAULT_STEPS);
        assert!(t0.elapsed().as_millis() < 2000);
    }
}
