//! Tile-wise hybrid quantization: per-tile scales, a sparse outlier
//! side-channel, and a non-uniform bit allocation — the TAH-QUANT-style
//! codec layer on top of the fused kernels.
//!
//! One scale per tensor (the flat path) makes every element pay for the
//! worst outlier: a single |x| spike widens the quantization interval of
//! the whole activation. Splitting the tensor into fixed-size tiles and
//! calibrating each independently localizes that damage; pulling the
//! top-k |x| elements out into a raw-f32 side-channel before calibration
//! removes it almost entirely; and letting the adaptive controller spend
//! a *bit budget* non-uniformly across tiles (more bits where the
//! histogram says quantization hurts, fewer where the tile is flat)
//! makes every wire byte worth more at a fixed bandwidth.
//!
//! **Tiled payload layout** (normative copy in `docs/WIRE_PROTOCOL.md`;
//! cross-checked by `analysis/spec.rs`):
//!
//! ```text
//! tile header (12 bytes)   ntiles u32 | tile_elems u32 | noutliers u32
//! tile param table         ntiles × tile param record (17 bytes):
//!                          scale f32 | zero_point f32 | lo f32 | hi f32 | bits u8
//! outlier side-channel     noutliers × outlier record (8 bytes):
//!                          index u32 | value f32   (ascending index)
//! packed streams           per-tile fused streams, each byte-aligned
//! ```
//!
//! All integers and floats are little-endian. Tile `t` covers elements
//! `[t*tile_elems, min((t+1)*tile_elems, elems))`; only the final tile
//! may be ragged. `tile_elems` is a multiple of 8 (every
//! [`super::fused::group_elems`] value divides 8), so each tile's packed
//! stream carries no padding bits except possibly the final one, and the
//! fused single-pass / multicore structure applies per tile unchanged. A
//! payload with `ntiles = 1` and no outliers carries exactly the flat
//! fused stream after its 29 header/table bytes (asserted byte-for-byte
//! in tests) — and the *old* flat format keeps its own frame kind, so
//! pre-tiling peers still decode.
//!
//! Decode is hostile-input safe: every header field is validated
//! (`ntiles` against [`MAX_TILES`] and `elems`, outlier indices against
//! `elems`, per-tile `bits` against [`super::SUPPORTED_BITS`] — a wire
//! width like 13 is an error here exactly as on the flat path), and
//! stream lengths are checked before any kernel runs.

use super::ds_aciq::hist_quant_mse;
use super::fused;
use super::pack::packed_len;
use super::stats::{top_abs_indices, CalibScan, DEFAULT_BINS};
use super::{calibrate, Method, QuantParams, SUPPORTED_BITS};
use crate::Result;

/// Bytes in the tiled-payload header: `ntiles u32 | tile_elems u32 |
/// noutliers u32`.
pub const TILE_HDR_BYTES: usize = 12;

/// Bytes per tile param record: `scale f32 | zero_point f32 | lo f32 |
/// hi f32 | bits u8`.
pub const TILE_PARAM_BYTES: usize = 17;

/// Bytes per outlier record: `index u32 | value f32`.
pub const OUTLIER_BYTES: usize = 8;

/// Hard cap on the tile count a payload may claim (2^16). Real configs
/// sit far below this; the cap bounds hostile-header allocation.
pub const MAX_TILES: usize = 1 << 16;

/// Ladder of widths the budget allocator spends across tiles. Raw f32
/// and 16-bit stay whole-tensor decisions (the controller only enters
/// budget territory once it has left the high-precision regime).
const BUDGET_LADDER: [u8; 4] = [8, 6, 4, 2];

/// Static tiling configuration (the `pipeline.tile_elems` /
/// `pipeline.outlier_frac` knobs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TileConfig {
    /// Elements per tile; must be a positive multiple of 8.
    pub tile_elems: usize,
    /// Fraction of elements routed to the raw-f32 outlier side-channel
    /// (top-k by |x|); `0.0` disables the side-channel.
    pub outlier_frac: f64,
}

impl TileConfig {
    /// Validate the invariants the encoder relies on.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.tile_elems > 0 && self.tile_elems % 8 == 0,
            "tile_elems must be a positive multiple of 8, got {}",
            self.tile_elems
        );
        anyhow::ensure!(
            (0.0..=0.5).contains(&self.outlier_frac),
            "outlier_frac must be in [0, 0.5], got {}",
            self.outlier_frac
        );
        Ok(())
    }
}

/// Cached per-tile calibration: recomputed when the tensor shape, the
/// requested width, or the bit budget changes, and refreshed every
/// `calib_every` microbatches — the tile-path mirror of the driver's
/// flat-path calibration amortization.
struct TilePlan {
    n: usize,
    bits: u8,
    avg_fp: u32,
    params: Vec<QuantParams>,
}

/// Stateful tiled encoder: owns the calibration cache and the masked-
/// calibration scratch buffer. Decode is stateless — see [`decode_into`].
pub struct TileCodec {
    cfg: TileConfig,
    method: Method,
    calib_every: u32,
    since: u32,
    plan: Option<TilePlan>,
    scratch: Vec<f32>,
}

impl TileCodec {
    /// Tiled encoder with the given tiling config and calibration method.
    pub fn new(cfg: TileConfig, method: Method) -> Self {
        TileCodec { cfg, method, calib_every: 1, since: 0, plan: None, scratch: Vec::new() }
    }

    /// Recalibrate every `every` encodes (shape/width/budget changes
    /// always recalibrate immediately). 1 = every microbatch.
    pub fn set_calib_every(&mut self, every: u32) {
        self.calib_every = every.max(1);
    }

    /// The tiling configuration this encoder was built with.
    pub fn config(&self) -> TileConfig {
        self.cfg
    }

    /// Encode `x` as a tiled payload into `payload` (resized; every byte
    /// written). `bits` is the uniform per-tile width; when `avg_bits`
    /// is set, the budget allocator instead distributes
    /// {2,4,6,8}-bit widths across tiles so the *average* stays at or
    /// under `avg_bits`, degrading the least-sensitive tiles first.
    pub fn encode_into(
        &mut self,
        x: &[f32],
        bits: u8,
        avg_bits: Option<f32>,
        payload: &mut Vec<u8>,
    ) -> Result<()> {
        self.cfg.validate()?;
        anyhow::ensure!(SUPPORTED_BITS.contains(&bits), "unsupported tile bitwidth {bits}");
        let n = x.len();
        let te = self.cfg.tile_elems;
        let ntiles = n.div_ceil(te);
        anyhow::ensure!(ntiles <= MAX_TILES, "{ntiles} tiles exceeds MAX_TILES");
        // Fixed-point budget key: 0 = uniform, else avg_bits × 256.
        let avg_fp = avg_bits.map_or(0, |a| (a.clamp(2.0, 8.0) * 256.0).round() as u32);

        // Outliers are per-tensor data, recomputed every encode; the
        // calibration plan is amortized across `calib_every` encodes.
        let k = ((n as f64 * self.cfg.outlier_frac) as usize).min(n / 2);
        let outliers = top_abs_indices(x, k);

        let stale = match &self.plan {
            None => true,
            Some(p) => p.n != n || p.bits != bits || p.avg_fp != avg_fp,
        };
        if stale || self.since >= self.calib_every {
            self.plan = Some(self.compute_plan(x, bits, avg_fp, &outliers));
            self.since = 1;
        } else {
            self.since += 1;
        }
        // lint-free unwrap shape: the plan was just ensured above.
        let plan = self.plan.as_ref().expect("plan computed above");

        // Layout: header | param table | outliers | per-tile streams.
        let streams_len: usize = (0..ntiles)
            .map(|t| packed_len(tile_len(n, te, t), plan.params[t].bits))
            .sum();
        let total = TILE_HDR_BYTES
            + ntiles * TILE_PARAM_BYTES
            + outliers.len() * OUTLIER_BYTES
            + streams_len;
        payload.resize(total, 0);
        payload[0..4].copy_from_slice(&(ntiles as u32).to_le_bytes());
        payload[4..8].copy_from_slice(&(te as u32).to_le_bytes());
        payload[8..12].copy_from_slice(&(outliers.len() as u32).to_le_bytes());
        let mut off = TILE_HDR_BYTES;
        for p in &plan.params {
            let rec = &mut payload[off..off + TILE_PARAM_BYTES];
            rec[0..4].copy_from_slice(&p.scale.to_le_bytes());
            rec[4..8].copy_from_slice(&p.zero_point.to_le_bytes());
            rec[8..12].copy_from_slice(&p.lo.to_le_bytes());
            rec[12..16].copy_from_slice(&p.hi.to_le_bytes());
            rec[16] = p.bits;
            off += TILE_PARAM_BYTES;
        }
        for &idx in &outliers {
            let rec = &mut payload[off..off + OUTLIER_BYTES];
            rec[0..4].copy_from_slice(&idx.to_le_bytes());
            rec[4..8].copy_from_slice(&x[idx as usize].to_le_bytes());
            off += OUTLIER_BYTES;
        }
        // Streams: the original data (outliers included — they clamp to
        // the tile range harmlessly and are overwritten on decode), each
        // tile through the same fused dispatch as the flat path.
        for (t, p) in plan.params.iter().enumerate() {
            let (a, b) = (t * te, (t * te + tile_len(n, te, t)).min(n));
            let plen = packed_len(b - a, p.bits);
            fused::encode_chunk(&x[a..b], p, &mut payload[off..off + plen]);
            off += plen;
        }
        debug_assert_eq!(off, total);
        Ok(())
    }

    /// Derive the per-tile calibration plan: mask outliers to zero in a
    /// scratch copy, choose per-tile widths (uniform or budgeted), then
    /// calibrate each tile slice with the configured method.
    fn compute_plan(&mut self, x: &[f32], bits: u8, avg_fp: u32, outliers: &[u32]) -> TilePlan {
        let n = x.len();
        let te = self.cfg.tile_elems;
        let ntiles = n.div_ceil(te);
        self.scratch.clear();
        self.scratch.extend_from_slice(x);
        for &i in outliers {
            self.scratch[i as usize] = 0.0;
        }
        let tile_bits: Vec<u8> = if avg_fp == 0 {
            vec![bits; ntiles]
        } else {
            allocate_bits(&self.scratch, te, avg_fp)
        };
        let params = tile_bits
            .iter()
            .enumerate()
            .map(|(t, &b)| {
                let sl = &self.scratch[t * te..(t * te + tile_len(n, te, t)).min(n)];
                calibrate(sl, self.method, b)
            })
            .collect();
        TilePlan { n, bits, avg_fp, params }
    }
}

/// Length of tile `t` for an `n`-element tensor at `te` elements/tile.
fn tile_len(n: usize, te: usize, t: usize) -> usize {
    te.min(n - t * te)
}

/// Greedy budget allocator: every tile starts at 8 bits; while the total
/// exceeds the budget implied by `avg_fp` (= avg bits × 256), step down
/// the tile whose next ladder step costs the least quantization MSE per
/// bit saved (per-tile `hist_quant_mse` over a one-pass [`CalibScan`]
/// histogram). A bandwidth drop therefore degrades the least-sensitive
/// tiles first and touches sensitive tiles only once the flat ones are
/// exhausted. O(ntiles² · ladder) worst case — ntiles is small (wire cap
/// [`MAX_TILES`], configs typically ≤ 64 tiles).
fn allocate_bits(x: &[f32], te: usize, avg_fp: u32) -> Vec<u8> {
    let n = x.len();
    let ntiles = n.div_ceil(te);
    // Per-tile MSE at each ladder width from one calibration scan/tile.
    let mut mse = vec![[0f64; BUDGET_LADDER.len()]; ntiles];
    for (t, row) in mse.iter_mut().enumerate() {
        let sl = &x[t * te..(t * te + tile_len(n, te, t)).min(n)];
        let scan = CalibScan::compute(sl, DEFAULT_BINS);
        let alpha = if scan.stats.n == 0 { 1e-12 } else { scan.stats.abs_max().max(1e-12) };
        for (j, &w) in BUDGET_LADDER.iter().enumerate() {
            row[j] = hist_quant_mse(&scan.hist, alpha, w);
        }
    }
    let budget_bits = avg_fp as f64 / 256.0 * n as f64;
    let mut level = vec![0usize; ntiles];
    let mut total_bits: f64 = (0..ntiles)
        .map(|t| (BUDGET_LADDER[0] as usize * tile_len(n, te, t)) as f64)
        .sum();
    while total_bits > budget_bits {
        let mut best: Option<(usize, f64)> = None;
        for t in 0..ntiles {
            let l = level[t];
            if l + 1 >= BUDGET_LADDER.len() {
                continue;
            }
            let dmse = (mse[t][l + 1] - mse[t][l]).max(0.0);
            let dbits = (BUDGET_LADDER[l] - BUDGET_LADDER[l + 1]) as f64;
            let cost = dmse / dbits;
            if best.is_none_or(|(_, c)| cost < c) {
                best = Some((t, cost));
            }
        }
        let Some((t, _)) = best else {
            break; // every tile already at the 2-bit floor
        };
        let saved = (BUDGET_LADDER[level[t]] - BUDGET_LADDER[level[t] + 1]) as usize;
        total_bits -= (saved * tile_len(n, te, t)) as f64;
        level[t] += 1;
    }
    level.iter().map(|&l| BUDGET_LADDER[l]).collect()
}

/// Parsed view of a tiled payload: validated header fields, the param
/// table, and borrowed outlier/stream sections. Public so tests, benches
/// and the driver-level budget assertions can inspect per-tile widths
/// without re-implementing the layout.
#[derive(Debug)]
pub struct TileView<'a> {
    /// Number of tiles (`0` only for an empty tensor).
    pub ntiles: usize,
    /// Elements per tile (final tile may be ragged).
    pub tile_elems: usize,
    /// Per-tile quantizer parameters, wire order.
    pub params: Vec<QuantParams>,
    /// Raw outlier records (`noutliers ×` [`OUTLIER_BYTES`]).
    pub outliers: &'a [u8],
    /// Concatenated per-tile packed streams.
    pub streams: &'a [u8],
}

impl<'a> TileView<'a> {
    /// Parse and validate a tiled payload against the expected element
    /// count. Every field a hostile peer controls is checked here:
    /// tile count, tile size vs `elems`, per-tile bitwidths, outlier
    /// indices, and total stream length.
    pub fn parse(payload: &'a [u8], elems: usize) -> Result<Self> {
        anyhow::ensure!(
            payload.len() >= TILE_HDR_BYTES,
            "tiled payload truncated: {} bytes < {TILE_HDR_BYTES}-byte header",
            payload.len()
        );
        let ntiles = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
        let te = u32::from_le_bytes([payload[4], payload[5], payload[6], payload[7]]) as usize;
        let nout = u32::from_le_bytes([payload[8], payload[9], payload[10], payload[11]]) as usize;
        anyhow::ensure!(ntiles <= MAX_TILES, "tile count {ntiles} exceeds MAX_TILES");
        if elems == 0 {
            anyhow::ensure!(ntiles == 0 && nout == 0, "nonzero tiles for empty tensor");
        } else {
            anyhow::ensure!(ntiles >= 1 && te >= 1, "bad tile geometry: {ntiles} × {te}");
            let (nt, te64, n64) = (ntiles as u64, te as u64, elems as u64);
            anyhow::ensure!(
                (nt - 1) * te64 < n64 && n64 <= nt * te64,
                "tile geometry {ntiles} × {te} does not cover {elems} elements"
            );
        }
        anyhow::ensure!(nout <= elems, "{nout} outliers exceed {elems} elements");
        let ptab = TILE_HDR_BYTES + ntiles * TILE_PARAM_BYTES;
        let oend = ptab + nout * OUTLIER_BYTES;
        anyhow::ensure!(
            payload.len() >= oend,
            "tiled payload truncated: {} bytes, tables need {oend}",
            payload.len()
        );
        let mut params = Vec::with_capacity(ntiles);
        for t in 0..ntiles {
            let rec = &payload[TILE_HDR_BYTES + t * TILE_PARAM_BYTES..][..TILE_PARAM_BYTES];
            let bits = rec[16];
            // The flat path's hostile-bitwidth guard, per tile: a wire
            // width outside SUPPORTED_BITS decodes to an error, never
            // garbage (and never reaches group_elems' debug contract).
            anyhow::ensure!(
                SUPPORTED_BITS.contains(&bits),
                "unsupported wire bitwidth {bits} in tile {t}"
            );
            params.push(QuantParams {
                scale: f32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]),
                zero_point: f32::from_le_bytes([rec[4], rec[5], rec[6], rec[7]]),
                lo: f32::from_le_bytes([rec[8], rec[9], rec[10], rec[11]]),
                hi: f32::from_le_bytes([rec[12], rec[13], rec[14], rec[15]]),
                bits,
            });
        }
        let outliers = &payload[ptab..oend];
        for rec in outliers.chunks_exact(OUTLIER_BYTES) {
            let idx = u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]) as usize;
            anyhow::ensure!(idx < elems, "outlier index {idx} out of range ({elems} elements)");
        }
        let streams = &payload[oend..];
        let need: usize = params
            .iter()
            .enumerate()
            .map(|(t, p)| packed_len(tile_len(elems, te.max(1), t), p.bits))
            .sum();
        anyhow::ensure!(
            streams.len() >= need,
            "tiled bitstream truncated: streams need {need} bytes, got {}",
            streams.len()
        );
        Ok(TileView { ntiles, tile_elems: te, params, outliers, streams })
    }

    /// Decoded outlier records `(index, value)`, wire order.
    pub fn outlier_records(&self) -> impl Iterator<Item = (usize, f32)> + '_ {
        self.outliers.chunks_exact(OUTLIER_BYTES).map(|rec| {
            (
                u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]) as usize,
                f32::from_le_bytes([rec[4], rec[5], rec[6], rec[7]]),
            )
        })
    }
}

/// Decode a tiled payload into `out` (`out.len()` = element count, set by
/// the frame header exactly like the flat path). Stateless: all layout
/// and parameters come from the validated payload itself.
pub fn decode_into(payload: &[u8], out: &mut [f32]) -> Result<()> {
    let view = TileView::parse(payload, out.len())?;
    let (n, te) = (out.len(), view.tile_elems.max(1));
    let mut off = 0usize;
    for (t, p) in view.params.iter().enumerate() {
        let (a, b) = (t * te, (t * te + tile_len(n, te, t)).min(n));
        let plen = packed_len(b - a, p.bits);
        fused::decode_into(&view.streams[off..], p, &mut out[a..b])?;
        off += plen;
    }
    for (idx, val) in view.outlier_records() {
        out[idx] = val; // idx validated < elems by parse
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::quant_mse;

    /// Heavy-tailed, tile-heterogeneous fixture: per-region scales spread
    /// over two orders of magnitude plus sparse huge outliers — the
    /// regime where one scale per tensor collapses at 2-bit (paper Fig 3,
    /// TAH-QUANT's motivating case).
    fn heavy_tailed(n: usize, region: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::seed(seed);
        (0..n)
            .map(|i| {
                let scale = 0.05 * ((i / region) as f64 * 1.7 + 1.0);
                let v = rng.laplace(scale) as f32;
                if i % 211 == 0 {
                    v + 30.0 * (if i % 2 == 0 { 1.0 } else { -1.0 })
                } else {
                    v
                }
            })
            .collect()
    }

    fn roundtrip(x: &[f32], cfg: TileConfig, bits: u8, avg: Option<f32>) -> (Vec<u8>, Vec<f32>) {
        let mut tc = TileCodec::new(cfg, Method::Pda);
        let mut payload = Vec::new();
        tc.encode_into(x, bits, avg, &mut payload).unwrap();
        let mut out = vec![0f32; x.len()];
        decode_into(&payload, &mut out).unwrap();
        (payload, out)
    }

    #[test]
    fn one_tile_stream_is_byte_identical_to_flat_fused() {
        let x = heavy_tailed(1000, 250, 5);
        let te = 1024; // one tile covers everything
        let (payload, _) = roundtrip(&x, TileConfig { tile_elems: te, outlier_frac: 0.0 }, 4, None);
        let view = TileView::parse(&payload, x.len()).unwrap();
        assert_eq!(view.ntiles, 1);
        assert!(view.outliers.is_empty());
        // The stream section is exactly the flat fused payload under the
        // same params — the backward-compatibility pin for the format.
        let mut flat = Vec::new();
        fused::encode_into(&x, &view.params[0], &mut flat);
        assert_eq!(view.streams, &flat[..]);
        assert_eq!(
            payload.len(),
            TILE_HDR_BYTES + TILE_PARAM_BYTES + flat.len(),
            "1-tile/no-outlier payload = header + one param record + flat stream"
        );
    }

    #[test]
    fn roundtrip_reconstruction_bounded_per_tile() {
        let x = heavy_tailed(4096, 512, 7);
        let cfg = TileConfig { tile_elems: 512, outlier_frac: 0.0 };
        for bits in SUPPORTED_BITS {
            let (payload, out) = roundtrip(&x, cfg, bits, None);
            let view = TileView::parse(&payload, x.len()).unwrap();
            assert_eq!(view.ntiles, 8);
            for (t, p) in view.params.iter().enumerate() {
                let (a, b) = (t * 512, ((t + 1) * 512).min(x.len()));
                // Inside each tile's clip range the error is ≤ scale/2.
                let (clip_lo, clip_hi) =
                    ((p.lo - p.zero_point) * p.scale, (p.hi - p.zero_point) * p.scale);
                for i in a..b {
                    if x[i] > clip_lo && x[i] < clip_hi {
                        assert!(
                            (x[i] - out[i]).abs() <= p.scale * 0.5 + 1e-5,
                            "bits={bits} tile={t} i={i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn outliers_reconstruct_exactly() {
        let x = heavy_tailed(2048, 256, 11);
        let cfg = TileConfig { tile_elems: 256, outlier_frac: 0.01 };
        let (payload, out) = roundtrip(&x, cfg, 2, None);
        let view = TileView::parse(&payload, x.len()).unwrap();
        let k = (2048.0 * 0.01) as usize;
        assert_eq!(view.outliers.len(), k * OUTLIER_BYTES);
        let mut prev = None;
        for (idx, val) in view.outlier_records() {
            assert_eq!(val.to_bits(), x[idx].to_bits(), "outliers are raw f32");
            assert_eq!(out[idx].to_bits(), x[idx].to_bits(), "decode restores them exactly");
            if let Some(p) = prev {
                assert!(idx > p, "ascending index order");
            }
            prev = Some(idx);
        }
    }

    #[test]
    fn tiled_2bit_beats_flat_2bit_on_heavy_tailed_fixture() {
        // The paper's 2-bit headline case: per-tile scales + the outlier
        // side-channel must show a *measured* quant_mse win over one
        // scale per tensor.
        let x = heavy_tailed(8192, 1024, 13);
        let flat_p = calibrate(&x, Method::Pda, 2);
        let flat_mse = quant_mse(&x, &flat_p);
        let cfg = TileConfig { tile_elems: 1024, outlier_frac: 0.01 };
        let (_, out) = roundtrip(&x, cfg, 2, None);
        let tiled_mse: f64 = x
            .iter()
            .zip(&out)
            .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
            .sum::<f64>()
            / x.len() as f64;
        assert!(
            tiled_mse < flat_mse * 0.7,
            "tiled 2-bit must beat flat 2-bit: tiled={tiled_mse:.6} flat={flat_mse:.6}"
        );
    }

    #[test]
    fn budget_mode_allocates_nonuniform_bits() {
        // One loud region, the rest near-flat: at avg 4 bits the loud
        // tiles must keep more bits than the flat ones — degradation is
        // per-tile, not uniform.
        let mut rng = crate::util::rng::Rng::seed(17);
        let n = 8192;
        let x: Vec<f32> = (0..n)
            .map(|i| {
                let s = if i < 1024 { 2.0 } else { 0.02 };
                rng.laplace(s) as f32
            })
            .collect();
        let cfg = TileConfig { tile_elems: 1024, outlier_frac: 0.0 };
        let (payload, _) = roundtrip(&x, cfg, 4, Some(4.0));
        let view = TileView::parse(&payload, n).unwrap();
        let bits: Vec<u8> = view.params.iter().map(|p| p.bits).collect();
        let distinct: std::collections::BTreeSet<u8> = bits.iter().copied().collect();
        assert!(distinct.len() > 1, "budget must spend non-uniformly, got {bits:?}");
        assert!(bits[0] > bits[7], "loud tile keeps more bits than quiet tile: {bits:?}");
        // The budget is respected: average wire bits ≤ requested avg.
        let total_bits: usize =
            bits.iter().enumerate().map(|(t, &b)| b as usize * tile_len(n, 1024, t)).sum();
        assert!(total_bits as f64 / n as f64 <= 4.0 + 1e-9, "{bits:?}");
    }

    #[test]
    fn hostile_tile_bitwidth_is_a_decode_error() {
        let x = heavy_tailed(512, 128, 19);
        let cfg = TileConfig { tile_elems: 128, outlier_frac: 0.0 };
        let (mut payload, _) = roundtrip(&x, cfg, 4, None);
        // Corrupt tile 1's bits field to a width the wire cannot carry.
        payload[TILE_HDR_BYTES + TILE_PARAM_BYTES + 16] = 13;
        let mut out = vec![0f32; 512];
        let err = decode_into(&payload, &mut out).unwrap_err();
        assert!(err.to_string().contains("unsupported wire bitwidth 13"), "{err:#}");
    }

    #[test]
    fn hostile_headers_are_decode_errors() {
        let x = heavy_tailed(512, 128, 23);
        let cfg = TileConfig { tile_elems: 128, outlier_frac: 0.01 };
        let (payload, _) = roundtrip(&x, cfg, 4, None);
        let mut out = vec![0f32; 512];
        // Truncated header.
        assert!(decode_into(&payload[..8], &mut out).is_err());
        // Tile count that cannot cover the tensor.
        let mut bad = payload.clone();
        bad[0..4].copy_from_slice(&2u32.to_le_bytes());
        assert!(decode_into(&bad, &mut out).is_err());
        // Tile count over the hard cap.
        let mut bad = payload.clone();
        bad[0..4].copy_from_slice(&(MAX_TILES as u32 + 1).to_le_bytes());
        assert!(decode_into(&bad, &mut out).is_err());
        // Outlier index out of range.
        let mut bad = payload.clone();
        let optr = TILE_HDR_BYTES + 4 * TILE_PARAM_BYTES;
        bad[optr..optr + 4].copy_from_slice(&512u32.to_le_bytes());
        assert!(decode_into(&bad, &mut out).is_err());
        // Truncated stream section.
        let bad = &payload[..payload.len() - 1];
        assert!(decode_into(bad, &mut out).is_err());
        // The original still decodes after all that cloning.
        assert!(decode_into(&payload, &mut out).is_ok());
    }

    #[test]
    fn ragged_final_tile_and_empty_tensor() {
        let x = heavy_tailed(1000, 300, 29); // 1000 = 3×256 + 232
        let cfg = TileConfig { tile_elems: 256, outlier_frac: 0.005 };
        let (payload, out) = roundtrip(&x, cfg, 8, None);
        let view = TileView::parse(&payload, x.len()).unwrap();
        assert_eq!(view.ntiles, 4);
        assert_eq!(out.len(), 1000);
        // Empty tensor: a degenerate but valid payload.
        let (payload, out) = roundtrip(&[], cfg, 8, None);
        assert!(out.is_empty());
        let view = TileView::parse(&payload, 0).unwrap();
        assert_eq!(view.ntiles, 0);
    }

    #[test]
    fn calibration_cache_is_keyed_and_refreshed() {
        let x = heavy_tailed(2048, 512, 31);
        let cfg = TileConfig { tile_elems: 512, outlier_frac: 0.0 };
        let mut tc = TileCodec::new(cfg, Method::Pda);
        tc.set_calib_every(1000);
        let mut p1 = Vec::new();
        tc.encode_into(&x, 4, None, &mut p1).unwrap();
        // Same shape/width: the cached plan reproduces the exact bytes.
        let mut p2 = Vec::new();
        tc.encode_into(&x, 4, None, &mut p2).unwrap();
        assert_eq!(p1, p2);
        // Width change invalidates the cache (params must change).
        let mut p3 = Vec::new();
        tc.encode_into(&x, 2, None, &mut p3).unwrap();
        let v3 = TileView::parse(&p3, x.len()).unwrap();
        assert!(v3.params.iter().all(|p| p.bits == 2));
        // Budget-mode key differs from uniform.
        let mut p4 = Vec::new();
        tc.encode_into(&x, 2, Some(3.0), &mut p4).unwrap();
        assert_ne!(p3, p4);
    }

    #[test]
    fn config_validation_rejects_bad_knobs() {
        assert!(TileConfig { tile_elems: 0, outlier_frac: 0.0 }.validate().is_err());
        assert!(TileConfig { tile_elems: 100, outlier_frac: 0.0 }.validate().is_err());
        assert!(TileConfig { tile_elems: 128, outlier_frac: 0.6 }.validate().is_err());
        assert!(TileConfig { tile_elems: 128, outlier_frac: 0.02 }.validate().is_ok());
    }
}
