//! Tensor codec: calibrate → quantize → bit-pack on send, unpack →
//! dequantize on receive. This is the adaptive PDA module's data path.
//!
//! The quantize/dequantize arithmetic is pluggable via [`QuantBackend`]:
//! * [`NativeBackend`] — the pure-rust arithmetic of [`super::uniform`].
//!   Because its semantics are exactly `uniform`'s, the codec runs it
//!   through the **fused single-pass kernels** ([`super::fused`]):
//!   quantize+pack in one read of the tensor (optionally chunked across
//!   [`Codec::set_threads`] worker threads), unpack+dequantize in one
//!   pass on receive — no `i32` staging buffer anywhere.
//! * `runtime::HloQuantBackend` — the AOT-compiled Pallas kernel executed
//!   through PJRT. External arithmetic, so the codec keeps the two-pass
//!   path for it: backend quantize into `i32` codes, then
//!   [`super::pack`].
//! Both produce identical codes (cross-checked in tests/runtime_hlo.rs),
//! and the fused path is byte-identical to the two-pass path (cross-
//! checked in tests and `tests/codec_hotpath.rs`), so the choice is a
//! deployment/perf knob (`codec_backend` in the config), benchmarked in
//! benches/quant_codec.rs (`BENCH_hotpath.json`).

use super::pack;
use super::tile::{self, TileCodec};
use super::{calibrate, fused, Method, QuantParams, BITS_NONE};
use crate::Result;

/// Pluggable quantize/dequantize arithmetic.
pub trait QuantBackend: Send {
    /// Quantize `x` into integer codes under `p`.
    fn quantize(&mut self, x: &[f32], p: &QuantParams, out: &mut [i32]) -> Result<()>;
    /// Dequantize `codes` back to f32 under `p`.
    fn dequantize(&mut self, codes: &[i32], p: &QuantParams, out: &mut [f32]) -> Result<()>;
    /// Backend name for logs/reports.
    fn name(&self) -> &'static str;
    /// Whether this backend's arithmetic is exactly [`super::uniform`]'s,
    /// allowing the codec to run the fused quantize+pack / unpack+
    /// dequantize kernels ([`super::fused`]) instead of staging `i32`
    /// codes through the backend. Default `false`: an external backend
    /// (e.g. the AOT Pallas HLO executable) keeps the two-pass path.
    fn fused_ok(&self) -> bool {
        false
    }
}

/// Pure-rust backend (no PJRT involvement).
#[derive(Default)]
pub struct NativeBackend;

impl QuantBackend for NativeBackend {
    fn quantize(&mut self, x: &[f32], p: &QuantParams, out: &mut [i32]) -> Result<()> {
        super::uniform::quantize_into(x, p, out);
        Ok(())
    }

    fn dequantize(&mut self, codes: &[i32], p: &QuantParams, out: &mut [f32]) -> Result<()> {
        super::uniform::dequantize_into(codes, p, out);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn fused_ok(&self) -> bool {
        true
    }
}

/// An encoded activation ready for framing onto the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct Encoded {
    /// `None` ⇒ raw f32 passthrough (bits = 32, the nominal state) *or*
    /// a tiled payload (per-tile params live inside the payload).
    pub params: Option<QuantParams>,
    /// Element count of the original tensor.
    pub elems: usize,
    /// Packed payload bytes.
    pub payload: Vec<u8>,
    /// `true` ⇒ `payload` is a tiled payload (`quant::tile` layout, frame
    /// kind 2): per-tile param table + outlier side-channel + streams.
    pub tiled: bool,
}

impl Encoded {
    /// Wire bitwidth (32 = raw f32). A tiled payload has no single
    /// width — this reports 32 there; use [`Encoded::avg_wire_bits`].
    pub fn bits(&self) -> u8 {
        self.params.map_or(BITS_NONE, |p| p.bits)
    }

    /// Average wire bits per element, derived from the payload size —
    /// the telemetry-facing width for tiled (mixed-width) payloads.
    pub fn avg_wire_bits(&self) -> f64 {
        if self.elems == 0 {
            return 0.0;
        }
        (self.payload.len() * 8) as f64 / self.elems as f64
    }

    /// Wire bytes (payload only; the frame header adds a fixed few bytes).
    pub fn wire_len(&self) -> usize {
        self.payload.len()
    }

    /// Compression factor vs f32.
    pub fn compression(&self) -> f64 {
        (self.elems * 4) as f64 / self.payload.len().max(1) as f64
    }
}

/// Stateful encoder/decoder with reusable scratch buffers. `encode*`
/// draws its payload storage from a recycled buffer ([`Codec::recycle`]
/// returns a consumed frame's payload to the codec), so a stage that
/// recycles what it receives encodes with zero allocation in steady
/// state *when its output payloads fit the recycled capacity* (equal or
/// lower bitwidth than the input link). When the output link runs at a
/// wider bitwidth than the input, each encode grows the recycled buffer
/// — one copy-free allocation per frame (the buffer is empty when it
/// grows), which is the unavoidable cost of shipping the larger buffer
/// away with the frame.
pub struct Codec {
    backend: Box<dyn QuantBackend>,
    /// `i32` staging for the two-pass (non-fused backend) path only.
    codes: Vec<i32>,
    /// Recycled payload storage for the next `encode*` call.
    spare: Vec<u8>,
    /// Worker threads for large fused encodes (the `codec_threads` config
    /// knob). 1 = serial, never spawns.
    threads: usize,
    /// Tiled-encode state (`pipeline.tile_elems` > 0); `None` = flat.
    tile: Option<TileCodec>,
}

impl Default for Codec {
    fn default() -> Self {
        Codec::new(Box::new(NativeBackend))
    }
}

impl Codec {
    /// Codec over the given arithmetic backend.
    pub fn new(backend: Box<dyn QuantBackend>) -> Self {
        Codec { backend, codes: Vec::new(), spare: Vec::new(), threads: 1, tile: None }
    }

    /// Name of the arithmetic backend ("native" / "hlo").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Worker threads for large fused encodes (`codec_threads` in the
    /// config). Only the fused native path parallelizes; 1 disables.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Current worker-thread setting.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enable tile-wise encoding ([`super::tile`]): subsequent
    /// [`Codec::encode_tiled`] calls produce tiled payloads. `None`
    /// disables (the default; [`Codec::encode`] stays flat either way).
    pub fn set_tiling(&mut self, tile: Option<TileCodec>) {
        self.tile = tile;
    }

    /// Whether a tiled encoder is configured.
    pub fn tiling_enabled(&self) -> bool {
        self.tile.is_some()
    }

    /// Hand a consumed [`Encoded`]'s payload buffer back for reuse by the
    /// next `encode*` call. Callers that can't return buffers just drop
    /// them (correct, one allocation per encode).
    pub fn recycle(&mut self, enc: Encoded) {
        if enc.payload.capacity() > self.spare.capacity() {
            self.spare = enc.payload;
        }
    }

    /// NOT cleared: every consumer fully overwrites it (`pack::pack`
    /// clears internally; the fused kernels and `raw_f32_into` resize
    /// and write every byte), and skipping the clear means a recycled
    /// same-size buffer costs zero memset on the resize.
    fn take_payload(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.spare)
    }

    /// Calibrate on `x` and encode it at `bits` using `method`.
    /// `bits == 32` bypasses quantization entirely (raw f32 LE payload).
    pub fn encode(&mut self, x: &[f32], method: Method, bits: u8) -> Result<Encoded> {
        if bits >= BITS_NONE {
            let mut payload = self.take_payload();
            fused::raw_f32_into(x, &mut payload);
            return Ok(Encoded { params: None, elems: x.len(), payload, tiled: false });
        }
        let params = calibrate(x, method, bits);
        self.encode_with_params(x, params)
    }

    /// Encode as a tiled payload (per-tile scales, outlier side-channel,
    /// optionally budget-allocated widths — see [`super::tile`]).
    /// Requires [`Codec::set_tiling`]; `bits == 32` falls back to the raw
    /// passthrough (tiling a raw stream buys nothing). When `avg_bits` is
    /// set, per-tile widths are budget-allocated around that average
    /// instead of uniformly `bits`.
    pub fn encode_tiled(&mut self, x: &[f32], bits: u8, avg_bits: Option<f32>) -> Result<Encoded> {
        if bits >= BITS_NONE {
            let mut payload = self.take_payload();
            fused::raw_f32_into(x, &mut payload);
            return Ok(Encoded { params: None, elems: x.len(), payload, tiled: false });
        }
        let tc = self.tile.as_mut().ok_or_else(|| anyhow::anyhow!("tiling not configured"))?;
        let mut payload = self.take_payload();
        tc.encode_into(x, bits, avg_bits, &mut payload)?;
        Ok(Encoded { params: None, elems: x.len(), payload, tiled: true })
    }

    /// Encode with pre-derived params (used when calibration is amortized
    /// across a window rather than per-microbatch). Native-arithmetic
    /// backends run the fused single-pass quantize+pack kernel (chunked
    /// over [`Codec::set_threads`] workers for large tensors); external
    /// backends stage `i32` codes through [`QuantBackend::quantize`].
    pub fn encode_with_params(&mut self, x: &[f32], params: QuantParams) -> Result<Encoded> {
        let mut payload = self.take_payload();
        if self.backend.fused_ok() {
            fused::encode_into_mt(x, &params, self.threads, &mut payload);
        } else {
            self.codes.resize(x.len(), 0);
            self.backend.quantize(x, &params, &mut self.codes)?;
            pack::pack(&self.codes, params.bits, params.pack_offset(), &mut payload);
        }
        Ok(Encoded { params: Some(params), elems: x.len(), payload, tiled: false })
    }

    /// Decode into `out` (resized to the tensor's element count).
    /// Truncated payloads are errors (see [`pack::unpack`]), never panics.
    /// Tiled payloads decode through [`tile::decode_into`] regardless of
    /// backend — the tile layer is defined over the fused (native)
    /// arithmetic, which is byte-identical to the reference.
    pub fn decode(&mut self, enc: &Encoded, out: &mut Vec<f32>) -> Result<()> {
        out.resize(enc.elems, 0.0);
        if enc.tiled {
            return tile::decode_into(&enc.payload, out);
        }
        match enc.params {
            None => {
                anyhow::ensure!(
                    enc.payload.len() == enc.elems * 4,
                    "raw payload length mismatch: {} != {}",
                    enc.payload.len(),
                    enc.elems * 4
                );
                for (o, ch) in out.iter_mut().zip(enc.payload.chunks_exact(4)) {
                    *o = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
                }
            }
            Some(p) => {
                if self.backend.fused_ok() {
                    fused::decode_into(&enc.payload, &p, out)?;
                } else {
                    pack::unpack(
                        &enc.payload,
                        enc.elems,
                        p.bits,
                        p.pack_offset(),
                        &mut self.codes,
                    )?;
                    self.backend.dequantize(&self.codes, &p, out)?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::SUPPORTED_BITS;

    fn test_tensor(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let t = i as f32 * 0.618;
                (t.sin() * 2.0) + if i % 97 == 0 { 8.0 } else { 0.0 }
            })
            .collect()
    }

    #[test]
    fn passthrough_is_lossless() {
        let x = test_tensor(1000);
        let mut c = Codec::default();
        let enc = c.encode(&x, Method::Pda, 32).unwrap();
        assert!(enc.params.is_none());
        assert_eq!(enc.wire_len(), 4000);
        let mut out = Vec::new();
        c.decode(&enc, &mut out).unwrap();
        assert_eq!(out, x);
    }

    #[test]
    fn encode_decode_reconstruction_error_bounded() {
        let x = test_tensor(2048);
        let mut c = Codec::default();
        for m in Method::ALL {
            for bits in SUPPORTED_BITS {
                let enc = c.encode(&x, m, bits).unwrap();
                let p = enc.params.unwrap();
                let mut out = Vec::new();
                c.decode(&enc, &mut out).unwrap();
                // Inside the clip range the error is <= scale/2.
                let clip_hi = (p.hi - p.zero_point) * p.scale;
                let clip_lo = (p.lo - p.zero_point) * p.scale;
                for (a, b) in x.iter().zip(&out) {
                    if *a > clip_lo && *a < clip_hi {
                        assert!((a - b).abs() <= p.scale * 0.5 + 1e-5, "{m:?}/{bits}");
                    }
                }
            }
        }
    }

    #[test]
    fn wire_sizes_match_bitwidth() {
        let x = test_tensor(4096);
        let mut c = Codec::default();
        for bits in SUPPORTED_BITS {
            let enc = c.encode(&x, Method::Aciq, bits).unwrap();
            assert_eq!(enc.wire_len(), 4096 * bits as usize / 8);
            assert!((enc.compression() - 32.0 / bits as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn amortized_params_reuse() {
        let x = test_tensor(512);
        let mut c = Codec::default();
        let p = crate::quant::calibrate(&x, Method::Aciq, 8);
        let e1 = c.encode_with_params(&x, p).unwrap();
        let e2 = c.encode(&x, Method::Aciq, 8).unwrap();
        assert_eq!(e1, e2);
    }

    #[test]
    fn decode_rejects_truncated_payload() {
        let x = test_tensor(128);
        let mut c = Codec::default();
        let mut enc = c.encode(&x, Method::Aciq, 8).unwrap();
        enc.payload.truncate(10);
        let mut out = Vec::new();
        assert!(c.decode(&enc, &mut out).is_err());
        // Sub-byte widths too (this path used to panic in pack::unpack).
        let mut enc = c.encode(&x, Method::Aciq, 4).unwrap();
        enc.payload.truncate(enc.payload.len() - 1);
        assert!(c.decode(&enc, &mut out).is_err());
    }

    /// Native arithmetic behind a `fused_ok = false` flag: forces the
    /// two-pass i32-staging path with identical math, so fused-vs-legacy
    /// equality can be checked through the public `Codec` API alone.
    struct TwoPassNative(NativeBackend);

    impl QuantBackend for TwoPassNative {
        fn quantize(&mut self, x: &[f32], p: &QuantParams, out: &mut [i32]) -> crate::Result<()> {
            self.0.quantize(x, p, out)
        }
        fn dequantize(
            &mut self,
            codes: &[i32],
            p: &QuantParams,
            out: &mut [f32],
        ) -> crate::Result<()> {
            self.0.dequantize(codes, p, out)
        }
        fn name(&self) -> &'static str {
            "two-pass-native"
        }
    }

    #[test]
    fn fused_and_two_pass_codecs_agree_exactly() {
        let x = test_tensor(1537); // odd: exercises sub-byte tails
        let mut fused_c = Codec::default();
        assert!(fused_c.backend.fused_ok());
        let mut legacy_c = Codec::new(Box::new(TwoPassNative(NativeBackend)));
        assert!(!legacy_c.backend.fused_ok());
        for m in Method::ALL {
            for bits in SUPPORTED_BITS {
                let a = fused_c.encode(&x, m, bits).unwrap();
                let b = legacy_c.encode(&x, m, bits).unwrap();
                assert_eq!(a, b, "{m:?}/{bits}: fused payload must be byte-identical");
                let (mut da, mut db) = (Vec::new(), Vec::new());
                fused_c.decode(&a, &mut da).unwrap();
                legacy_c.decode(&b, &mut db).unwrap();
                assert_eq!(da, db, "{m:?}/{bits}: fused decode must be bit-identical");
            }
        }
    }

    #[test]
    fn threads_knob_does_not_change_bytes() {
        let x = test_tensor(crate::quant::fused::MT_MIN_CHUNK_ELEMS * 2 + 17);
        let mut serial = Codec::default();
        let mut parallel = Codec::default();
        parallel.set_threads(4);
        assert_eq!(parallel.threads(), 4);
        for bits in SUPPORTED_BITS {
            let a = serial.encode(&x, Method::Aciq, bits).unwrap();
            let b = parallel.encode(&x, Method::Aciq, bits).unwrap();
            assert_eq!(a, b, "bits={bits}: parallel encode must be byte-identical");
        }
        // 0 clamps to 1 (serial) rather than panicking or spawning nothing.
        parallel.set_threads(0);
        assert_eq!(parallel.threads(), 1);
    }

    #[test]
    fn tiled_encode_roundtrips_and_recycles() {
        use crate::quant::tile::TileConfig;
        let x = test_tensor(4096);
        let mut c = Codec::default();
        // Without set_tiling, encode_tiled is an error, not a panic.
        assert!(c.encode_tiled(&x, 4, None).is_err());
        let cfg = TileConfig { tile_elems: 512, outlier_frac: 0.01 };
        c.set_tiling(Some(TileCodec::new(cfg, Method::Pda)));
        assert!(c.tiling_enabled());
        let enc = c.encode_tiled(&x, 4, None).unwrap();
        assert!(enc.tiled && enc.params.is_none());
        // Tables + outliers cost a little over the 4 stream bits/elem.
        assert!(enc.avg_wire_bits() > 4.0 && enc.avg_wire_bits() < 6.0);
        let mut out = Vec::new();
        c.decode(&enc, &mut out).unwrap();
        assert_eq!(out.len(), 4096);
        // The recycled-buffer discipline holds on the tiled path too.
        let ptr = enc.payload.as_ptr();
        c.recycle(enc);
        let e2 = c.encode_tiled(&x, 4, None).unwrap();
        assert_eq!(e2.payload.as_ptr(), ptr);
        // bits == 32 falls back to the raw passthrough.
        let raw = c.encode_tiled(&x, 32, None).unwrap();
        assert!(!raw.tiled && raw.params.is_none());
        let mut back = Vec::new();
        c.decode(&raw, &mut back).unwrap();
        assert_eq!(back, x);
    }

    #[test]
    fn recycle_reuses_payload_allocation() {
        // The "zero allocation in steady state" claim, verified: after
        // recycling, the next encode writes into the same buffer.
        let x = test_tensor(1024);
        let mut c = Codec::default();
        let e1 = c.encode(&x, Method::Aciq, 8).unwrap();
        let ptr = e1.payload.as_ptr();
        let cap = e1.payload.capacity();
        c.recycle(e1);
        let e2 = c.encode(&x, Method::Aciq, 8).unwrap();
        assert_eq!(e2.payload.as_ptr(), ptr);
        assert_eq!(e2.payload.capacity(), cap);
        // Raw passthrough reuses it as well (after growing once).
        c.recycle(e2);
        let e3 = c.encode(&x, Method::Pda, 32).unwrap();
        c.recycle(e3);
        let e4 = c.encode(&x, Method::Pda, 32).unwrap();
        let p4 = e4.payload.as_ptr();
        c.recycle(e4);
        let e5 = c.encode(&x, Method::Pda, 32).unwrap();
        assert_eq!(e5.payload.as_ptr(), p4);
    }
}
