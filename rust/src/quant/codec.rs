//! Tensor codec: calibrate → quantize → bit-pack on send, unpack →
//! dequantize on receive. This is the adaptive PDA module's data path.
//!
//! The quantize/dequantize arithmetic is pluggable via [`QuantBackend`]:
//! * [`NativeBackend`] — the pure-rust loop in [`super::uniform`];
//! * `runtime::HloQuantBackend` — the AOT-compiled Pallas kernel executed
//!   through PJRT (the architecture's L1 hot path).
//! Both produce identical codes (cross-checked in tests/runtime_hlo.rs),
//! so the choice is a deployment/perf knob (`codec_backend` in the config),
//! benchmarked as an ablation.

use super::pack;
use super::{calibrate, Method, QuantParams, BITS_NONE};
use crate::Result;

/// Pluggable quantize/dequantize arithmetic.
pub trait QuantBackend: Send {
    fn quantize(&mut self, x: &[f32], p: &QuantParams, out: &mut [i32]) -> Result<()>;
    fn dequantize(&mut self, codes: &[i32], p: &QuantParams, out: &mut [f32]) -> Result<()>;
    fn name(&self) -> &'static str;
}

/// Pure-rust backend (no PJRT involvement).
#[derive(Default)]
pub struct NativeBackend;

impl QuantBackend for NativeBackend {
    fn quantize(&mut self, x: &[f32], p: &QuantParams, out: &mut [i32]) -> Result<()> {
        super::uniform::quantize_into(x, p, out);
        Ok(())
    }

    fn dequantize(&mut self, codes: &[i32], p: &QuantParams, out: &mut [f32]) -> Result<()> {
        super::uniform::dequantize_into(codes, p, out);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// An encoded activation ready for framing onto the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct Encoded {
    /// `None` ⇒ raw f32 passthrough (bits = 32, the nominal state).
    pub params: Option<QuantParams>,
    /// Element count of the original tensor.
    pub elems: usize,
    /// Packed payload bytes.
    pub payload: Vec<u8>,
}

impl Encoded {
    pub fn bits(&self) -> u8 {
        self.params.map_or(BITS_NONE, |p| p.bits)
    }

    /// Wire bytes (payload only; the frame header adds a fixed few bytes).
    pub fn wire_len(&self) -> usize {
        self.payload.len()
    }

    /// Compression factor vs f32.
    pub fn compression(&self) -> f64 {
        (self.elems * 4) as f64 / self.payload.len().max(1) as f64
    }
}

/// Stateful encoder/decoder with reusable scratch buffers. `encode*`
/// draws its payload storage from a recycled buffer ([`Codec::recycle`]
/// returns a consumed frame's payload to the codec), so a stage that
/// recycles what it receives encodes with zero allocation in steady
/// state *when its output payloads fit the recycled capacity* (equal or
/// lower bitwidth than the input link). When the output link runs at a
/// wider bitwidth than the input, each encode grows the recycled buffer
/// — one copy-free allocation per frame (the buffer is empty when it
/// grows), which is the unavoidable cost of shipping the larger buffer
/// away with the frame.
pub struct Codec {
    backend: Box<dyn QuantBackend>,
    codes: Vec<i32>,
    /// Recycled payload storage for the next `encode*` call.
    spare: Vec<u8>,
}

impl Default for Codec {
    fn default() -> Self {
        Codec::new(Box::new(NativeBackend))
    }
}

impl Codec {
    pub fn new(backend: Box<dyn QuantBackend>) -> Self {
        Codec { backend, codes: Vec::new(), spare: Vec::new() }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Hand a consumed [`Encoded`]'s payload buffer back for reuse by the
    /// next `encode*` call. Callers that can't return buffers just drop
    /// them (correct, one allocation per encode).
    pub fn recycle(&mut self, enc: Encoded) {
        if enc.payload.capacity() > self.spare.capacity() {
            self.spare = enc.payload;
        }
    }

    fn take_payload(&mut self) -> Vec<u8> {
        let mut p = std::mem::take(&mut self.spare);
        p.clear();
        p
    }

    /// Calibrate on `x` and encode it at `bits` using `method`.
    /// `bits == 32` bypasses quantization entirely (raw f32 LE payload).
    pub fn encode(&mut self, x: &[f32], method: Method, bits: u8) -> Result<Encoded> {
        if bits >= BITS_NONE {
            let mut payload = self.take_payload();
            payload.reserve(x.len() * 4);
            for v in x {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            return Ok(Encoded { params: None, elems: x.len(), payload });
        }
        let params = calibrate(x, method, bits);
        self.encode_with_params(x, params)
    }

    /// Encode with pre-derived params (used when calibration is amortized
    /// across a window rather than per-microbatch).
    pub fn encode_with_params(&mut self, x: &[f32], params: QuantParams) -> Result<Encoded> {
        self.codes.resize(x.len(), 0);
        self.backend.quantize(x, &params, &mut self.codes)?;
        let mut payload = self.take_payload();
        pack::pack(&self.codes, params.bits, params.pack_offset(), &mut payload);
        Ok(Encoded { params: Some(params), elems: x.len(), payload })
    }

    /// Decode into `out` (resized to the tensor's element count).
    /// Truncated payloads are errors (see [`pack::unpack`]), never panics.
    pub fn decode(&mut self, enc: &Encoded, out: &mut Vec<f32>) -> Result<()> {
        out.resize(enc.elems, 0.0);
        match enc.params {
            None => {
                anyhow::ensure!(
                    enc.payload.len() == enc.elems * 4,
                    "raw payload length mismatch: {} != {}",
                    enc.payload.len(),
                    enc.elems * 4
                );
                for (o, ch) in out.iter_mut().zip(enc.payload.chunks_exact(4)) {
                    *o = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
                }
            }
            Some(p) => {
                pack::unpack(&enc.payload, enc.elems, p.bits, p.pack_offset(), &mut self.codes)?;
                self.backend.dequantize(&self.codes, &p, out)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::SUPPORTED_BITS;

    fn test_tensor(n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let t = i as f32 * 0.618;
                (t.sin() * 2.0) + if i % 97 == 0 { 8.0 } else { 0.0 }
            })
            .collect()
    }

    #[test]
    fn passthrough_is_lossless() {
        let x = test_tensor(1000);
        let mut c = Codec::default();
        let enc = c.encode(&x, Method::Pda, 32).unwrap();
        assert!(enc.params.is_none());
        assert_eq!(enc.wire_len(), 4000);
        let mut out = Vec::new();
        c.decode(&enc, &mut out).unwrap();
        assert_eq!(out, x);
    }

    #[test]
    fn encode_decode_reconstruction_error_bounded() {
        let x = test_tensor(2048);
        let mut c = Codec::default();
        for m in Method::ALL {
            for bits in SUPPORTED_BITS {
                let enc = c.encode(&x, m, bits).unwrap();
                let p = enc.params.unwrap();
                let mut out = Vec::new();
                c.decode(&enc, &mut out).unwrap();
                // Inside the clip range the error is <= scale/2.
                let clip_hi = (p.hi - p.zero_point) * p.scale;
                let clip_lo = (p.lo - p.zero_point) * p.scale;
                for (a, b) in x.iter().zip(&out) {
                    if *a > clip_lo && *a < clip_hi {
                        assert!((a - b).abs() <= p.scale * 0.5 + 1e-5, "{m:?}/{bits}");
                    }
                }
            }
        }
    }

    #[test]
    fn wire_sizes_match_bitwidth() {
        let x = test_tensor(4096);
        let mut c = Codec::default();
        for bits in SUPPORTED_BITS {
            let enc = c.encode(&x, Method::Aciq, bits).unwrap();
            assert_eq!(enc.wire_len(), 4096 * bits as usize / 8);
            assert!((enc.compression() - 32.0 / bits as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn amortized_params_reuse() {
        let x = test_tensor(512);
        let mut c = Codec::default();
        let p = crate::quant::calibrate(&x, Method::Aciq, 8);
        let e1 = c.encode_with_params(&x, p).unwrap();
        let e2 = c.encode(&x, Method::Aciq, 8).unwrap();
        assert_eq!(e1, e2);
    }

    #[test]
    fn decode_rejects_truncated_payload() {
        let x = test_tensor(128);
        let mut c = Codec::default();
        let mut enc = c.encode(&x, Method::Aciq, 8).unwrap();
        enc.payload.truncate(10);
        let mut out = Vec::new();
        assert!(c.decode(&enc, &mut out).is_err());
        // Sub-byte widths too (this path used to panic in pack::unpack).
        let mut enc = c.encode(&x, Method::Aciq, 4).unwrap();
        enc.payload.truncate(enc.payload.len() - 1);
        assert!(c.decode(&enc, &mut out).is_err());
    }

    #[test]
    fn recycle_reuses_payload_allocation() {
        // The "zero allocation in steady state" claim, verified: after
        // recycling, the next encode writes into the same buffer.
        let x = test_tensor(1024);
        let mut c = Codec::default();
        let e1 = c.encode(&x, Method::Aciq, 8).unwrap();
        let ptr = e1.payload.as_ptr();
        let cap = e1.payload.capacity();
        c.recycle(e1);
        let e2 = c.encode(&x, Method::Aciq, 8).unwrap();
        assert_eq!(e2.payload.as_ptr(), ptr);
        assert_eq!(e2.payload.capacity(), cap);
        // Raw passthrough reuses it as well (after growing once).
        c.recycle(e2);
        let e3 = c.encode(&x, Method::Pda, 32).unwrap();
        c.recycle(e3);
        let e4 = c.encode(&x, Method::Pda, 32).unwrap();
        let p4 = e4.payload.as_ptr();
        c.recycle(e4);
        let e5 = c.encode(&x, Method::Pda, 32).unwrap();
        assert_eq!(e5.payload.as_ptr(), p4);
    }
}
