//! Dense bit-packing of quantization codes for the wire.
//!
//! This is where the bandwidth saving actually materializes: the Pallas /
//! native quantizer emits i32 codes, and the sender packs them into a dense
//! little-endian bitstream of `q` bits per element (so 2-bit quantization
//! really is a 16x byte reduction vs f32, matching the paper's "compressed
//! by 4x using 8-bit quantization" arithmetic).
//!
//! Codes are offset by `-lo` before packing so the packed fields are
//! unsigned; the receiver adds `lo` back. Layout: element `i` occupies bits
//! `[i*q, (i+1)*q)` of the stream, bit `k` of the stream is bit `k % 8` of
//! byte `k / 8`. 8- and 16-bit widths take byte-aligned fast paths.
//!
//! [`unpack`] validates the input length up front: a truncated wire
//! payload is an error, never a panic or a silently-short output.

use crate::Result;

/// Packed size in bytes for `n` codes at `bits` per code.
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize + 7) / 8
}

/// Pack `codes` (each in `[lo, lo + 2^bits)`) into a dense bitstream.
pub fn pack(codes: &[i32], bits: u8, lo: i32, out: &mut Vec<u8>) {
    out.clear();
    out.reserve(packed_len(codes.len(), bits));
    match bits {
        8 => {
            for &c in codes {
                out.push((c - lo) as u8);
            }
        }
        16 => {
            for &c in codes {
                let u = (c - lo) as u16;
                out.extend_from_slice(&u.to_le_bytes());
            }
        }
        _ => {
            debug_assert!(bits < 8);
            let mask = (1u32 << bits) - 1;
            let mut acc: u32 = 0;
            let mut nbits: u32 = 0;
            for &c in codes {
                let u = (c - lo) as u32 & mask;
                acc |= u << nbits;
                nbits += bits as u32;
                while nbits >= 8 {
                    out.push((acc & 0xff) as u8);
                    acc >>= 8;
                    nbits -= 8;
                }
            }
            if nbits > 0 {
                out.push((acc & 0xff) as u8);
            }
        }
    }
}

/// Unpack `n` codes from a bitstream produced by [`pack`].
///
/// Errors when `bytes` is too short to hold `n` codes at `bits` each —
/// truncated payloads (a cut TCP stream, a corrupt frame) must surface as
/// decode failures the driver can report, not as panics or as fewer than
/// `n` codes.
pub fn unpack(bytes: &[u8], n: usize, bits: u8, lo: i32, out: &mut Vec<i32>) -> Result<()> {
    let need = packed_len(n, bits);
    anyhow::ensure!(
        bytes.len() >= need,
        "bitstream truncated: {n} codes at {bits} bits need {need} bytes, got {}",
        bytes.len()
    );
    out.clear();
    out.reserve(n);
    match bits {
        8 => {
            for &b in bytes.iter().take(n) {
                out.push(b as i32 + lo);
            }
        }
        16 => {
            for ch in bytes.chunks_exact(2).take(n) {
                out.push(u16::from_le_bytes([ch[0], ch[1]]) as i32 + lo);
            }
        }
        _ => {
            debug_assert!(bits < 8);
            let mask = (1u32 << bits) - 1;
            let mut acc: u32 = 0;
            let mut nbits: u32 = 0;
            let mut iter = bytes.iter();
            for _ in 0..n {
                while nbits < bits as u32 {
                    // Cannot run dry: the length check above guarantees
                    // `packed_len(n, bits)` bytes are present.
                    acc |= (*iter.next().expect("unpack length invariant") as u32) << nbits;
                    nbits += 8;
                }
                out.push((acc & mask) as i32 + lo);
                acc >>= bits;
                nbits -= bits as u32;
            }
        }
    }
    Ok(())
}

/// Allocating wrappers (tests / non-hot-path callers).
pub fn pack_vec(codes: &[i32], bits: u8, lo: i32) -> Vec<u8> {
    let mut out = Vec::new();
    pack(codes, bits, lo, &mut out);
    out
}

/// Allocating unpack wrapper (tests / non-hot-path callers).
pub fn unpack_vec(bytes: &[u8], n: usize, bits: u8, lo: i32) -> Result<Vec<i32>> {
    let mut out = Vec::new();
    unpack(bytes, n, bits, lo, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_case(bits: u8, lo: i32, n: usize, seed: u64) {
        let mut rng = crate::util::rng::Rng::seed(seed);
        let span = 1usize << bits;
        let codes: Vec<i32> = (0..n).map(|_| lo + rng.usize(0, span) as i32).collect();
        let bytes = pack_vec(&codes, bits, lo);
        assert_eq!(bytes.len(), packed_len(n, bits));
        let back = unpack_vec(&bytes, n, bits, lo).unwrap();
        assert_eq!(back, codes, "bits={bits} lo={lo} n={n}");
    }

    #[test]
    fn roundtrip_all_widths() {
        for bits in crate::quant::SUPPORTED_BITS {
            let lo_sym = -(1i32 << (bits - 1));
            for n in [0usize, 1, 3, 7, 8, 63, 64, 1000] {
                roundtrip_case(bits, lo_sym, n, 42 + n as u64);
                roundtrip_case(bits, 0, n, 137 + n as u64); // naive (unsigned)
            }
        }
    }

    #[test]
    fn packed_sizes_exact() {
        assert_eq!(packed_len(1024, 2), 256);
        assert_eq!(packed_len(1024, 4), 512);
        assert_eq!(packed_len(1024, 6), 768);
        assert_eq!(packed_len(1024, 8), 1024);
        assert_eq!(packed_len(1024, 16), 2048);
        assert_eq!(packed_len(3, 6), 3); // 18 bits -> 3 bytes
    }

    #[test]
    fn compression_ratio_vs_f32() {
        // The paper's headline arithmetic: 8-bit => 4x, 2-bit => 16x.
        let n = 4096;
        assert_eq!(n * 4 / packed_len(n, 8), 4);
        assert_eq!(n * 4 / packed_len(n, 2), 16);
    }

    #[test]
    fn six_bit_cross_byte_boundaries() {
        // 6-bit fields straddle bytes; check a hand-computed pattern.
        let codes = vec![0b000001, 0b000010, 0b000011, 0b000100]; // lo = 0
        let bytes = pack_vec(&codes, 6, 0);
        // stream bits: 000001 | 000010 | 000011 | 000100 (LSB-first)
        // byte0 = 10_000001, byte1 = 0011_0000, byte2 = 000100_00
        assert_eq!(bytes, vec![0b1000_0001, 0b0011_0000, 0b0001_0000]);
        assert_eq!(unpack_vec(&bytes, 4, 6, 0).unwrap(), codes);
    }

    #[test]
    fn extreme_codes_survive() {
        for bits in crate::quant::SUPPORTED_BITS {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let codes = vec![lo, hi, lo, hi, 0];
            assert_eq!(unpack_vec(&pack_vec(&codes, bits, lo), 5, bits, lo).unwrap(), codes);
        }
    }

    #[test]
    fn truncated_subbyte_bitstream_is_error() {
        // Used to panic via expect("bitstream truncated").
        let codes: Vec<i32> = (0..10).map(|i| i % 4).collect();
        for bits in [2u8, 4, 6] {
            let bytes = pack_vec(&codes, bits, 0);
            let mut out = Vec::new();
            let err = unpack(&bytes[..bytes.len() - 1], 10, bits, 0, &mut out).unwrap_err();
            assert!(err.to_string().contains("truncated"), "bits={bits}: {err:#}");
        }
    }

    #[test]
    fn short_byte_aligned_payloads_are_errors_not_short_outputs() {
        let codes: Vec<i32> = (0..10).collect();
        // 8-bit: 5 of 10 bytes used to silently yield 5 codes.
        let bytes = pack_vec(&codes, 8, 0);
        let mut out = Vec::new();
        assert!(unpack(&bytes[..5], 10, 8, 0, &mut out).is_err());
        // 16-bit: 6 of 20 bytes used to silently yield 3 codes.
        let bytes = pack_vec(&codes, 16, 0);
        assert!(unpack(&bytes[..6], 10, 16, 0, &mut out).is_err());
        // Exact length decodes all n codes.
        unpack(&bytes, 10, 16, 0, &mut out).unwrap();
        assert_eq!(out.len(), 10);
        assert_eq!(out, codes);
    }
}
