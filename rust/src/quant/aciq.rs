//! ACIQ: analytical clipping for integer quantization (Banner et al. [16]).
//!
//! For `X ~ Laplace(0, b)` the quantization MSE of a symmetric clipped
//! uniform quantizer decomposes into a clipping term and a rounding term:
//!
//! ```text
//! E[(X - Q(X))^2] / b^2  ≈  2 e^{-r} + r^2 / (3 · 4^q),   r = alpha / b
//! ```
//!
//! The minimizing ratio `F(q) = argmin_r` depends only on the bitwidth; the
//! optimal clip is `alpha* = F(q) · b` with the moment estimate
//! `b_E = mean(|x|)`. Known constants from [16]: F(2) ≈ 2.83, F(3) ≈ 3.89,
//! F(4) ≈ 5.03 — asserted in tests and in the cross-language goldens.

/// Analytic Laplace quantization MSE, normalized by `b^2`.
pub fn laplace_quant_mse(alpha_over_b: f64, bits: u8) -> f64 {
    let r = alpha_over_b;
    2.0 * (-r).exp() + r * r / (3.0 * 4f64.powi(bits as i32))
}

/// `F(q)`: solve `d/dr [2 e^{-r} + r^2 / (3·4^q)] = 0` by Newton iteration.
pub fn ratio(bits: u8) -> f32 {
    let c = 2.0 / (3.0 * 4f64.powi(bits as i32));
    let mut r = 2.0 + bits as f64; // grows roughly linearly in q
    for _ in 0..200 {
        let g = -2.0 * (-r).exp() + c * r;
        let dg = 2.0 * (-r).exp() + c;
        let step = g / dg;
        r -= step;
        if step.abs() < 1e-12 {
            break;
        }
    }
    r as f32
}

/// Moment estimate of the Laplace scale: `b_E = sum(|x_i|) / N` (paper §3).
pub fn laplace_b(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    let sum: f64 = x.iter().map(|v| v.abs() as f64).sum();
    (sum / x.len() as f64) as f32
}

/// ACIQ's optimal clip for tensor `x` at `bits`: `alpha = F(q) · b_E`.
pub fn aciq_alpha(x: &[f32], bits: u8) -> f32 {
    ratio(bits) * laplace_b(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_match_banner_constants() {
        assert!((ratio(2) - 2.83).abs() < 0.02, "F(2)={}", ratio(2));
        assert!((ratio(3) - 3.89).abs() < 0.02, "F(3)={}", ratio(3));
        assert!((ratio(4) - 5.03).abs() < 0.02, "F(4)={}", ratio(4));
    }

    #[test]
    fn ratio_monotone_in_bits() {
        let rs: Vec<f32> = (2..=16).map(|q| ratio(q)).collect();
        for w in rs.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn ratio_is_local_minimum() {
        for q in [2u8, 4, 8] {
            let r = ratio(q) as f64;
            let m0 = laplace_quant_mse(r, q);
            assert!(laplace_quant_mse(r - 0.05, q) >= m0);
            assert!(laplace_quant_mse(r + 0.05, q) >= m0);
        }
    }

    #[test]
    fn laplace_b_of_known_data() {
        // mean |x| of {-2, -1, 0, 1, 2} = 6/5
        let x = [-2.0f32, -1.0, 0.0, 1.0, 2.0];
        assert!((laplace_b(&x) - 1.2).abs() < 1e-6);
        assert_eq!(laplace_b(&[]), 0.0);
    }

    #[test]
    fn alpha_scales_linearly_with_data() {
        let x: Vec<f32> = (0..256).map(|i| (i as f32 - 128.0) / 32.0).collect();
        let x2: Vec<f32> = x.iter().map(|v| v * 3.0).collect();
        for q in crate::quant::SUPPORTED_BITS {
            let a1 = aciq_alpha(&x, q);
            let a2 = aciq_alpha(&x2, q);
            assert!((a2 / a1 - 3.0).abs() < 1e-4);
        }
    }
}
