//! Uniform affine quantize / dequantize — the native-rust data path.
//!
//! Semantics are identical to the Pallas kernel (kernels/quant.py) and the
//! python oracle (kernels/ref.py): `codes = clamp(round(x/scale + zp), lo,
//! hi)`, `x_hat = (codes - zp) * scale`. The codec can run this native
//! implementation or the AOT HLO executable; both are cross-checked in
//! tests.

use super::QuantParams;

/// Naive PTQ calibration: asymmetric affine range from the tensor min/max
/// (§3: "determines the quantization range based on the minimum and maximum
/// tensor values"). Codes are unsigned in `[0, 2^q - 1]`.
pub fn naive_params(x: &[f32], bits: u8) -> QuantParams {
    let (mut min, mut max) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in x {
        min = min.min(v);
        max = max.max(v);
    }
    if !min.is_finite() || !max.is_finite() {
        (min, max) = (0.0, 1.0);
    }
    // Standard min/max PTQ extends the range to include zero so the
    // zero-point is exactly representable (TFLite convention; ref.py does
    // the same).
    min = min.min(0.0);
    max = max.max(0.0);
    if max <= min {
        max = min + 1e-8;
    }
    let n = ((1u32 << bits) - 1) as f32;
    let scale = (max - min) / n;
    let zp = (-min / scale).round().clamp(0.0, n);
    QuantParams { scale, zero_point: zp, lo: 0.0, hi: n, bits }
}

/// Symmetric clipped calibration over `[-alpha, alpha]`, signed codes in
/// `[-(2^{q-1}), 2^{q-1} - 1]` (used by ACIQ / DS-ACIQ).
pub fn symmetric_params(alpha: f32, bits: u8) -> QuantParams {
    let half = 1i64 << (bits - 1);
    let scale = (alpha / half as f32).max(1e-12);
    QuantParams {
        scale,
        zero_point: 0.0,
        lo: -(half as f32),
        hi: (half - 1) as f32,
        bits,
    }
}

/// Quantize into the caller-provided code buffer (no allocation). The
/// codec's native hot path is the fused quantize+pack kernel in
/// [`super::fused`], which replicates this arithmetic **exactly** (same
/// ops, same order — change one, change both); this two-pass form remains
/// the reference and the staging path for external backends.
pub fn quantize_into(x: &[f32], p: &QuantParams, out: &mut [i32]) {
    debug_assert_eq!(x.len(), out.len());
    let inv = 1.0 / p.scale;
    let (zp, lo, hi) = (p.zero_point, p.lo, p.hi);
    // `round` is round-half-away-from-zero, matching numpy's float32
    // rounding of continuous data to within one code (ties on exact .5 are
    // measure-zero for real activations; the golden tests tolerate <=1
    // code on synthetic ties). max/min instead of clamp lets LLVM emit
    // vector min/max (clamp's NaN ordering blocks it) — §Perf: 537µs →
    // ~190µs on the 131k-element boundary activation.
    for (o, &v) in out.iter_mut().zip(x) {
        let c = (v * inv + zp).round();
        *o = c.max(lo).min(hi) as i32;
    }
}

/// Allocating convenience wrapper over [`quantize_into`].
pub fn quantize(x: &[f32], p: &QuantParams) -> Vec<i32> {
    let mut out = vec![0i32; x.len()];
    quantize_into(x, p, &mut out);
    out
}

/// Dequantize into the caller-provided buffer (hot path: no allocation).
pub fn dequantize_into(codes: &[i32], p: &QuantParams, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    let (s, zp) = (p.scale, p.zero_point);
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = (c as f32 - zp) * s;
    }
}

/// Allocating convenience wrapper over [`dequantize_into`].
pub fn dequantize(codes: &[i32], p: &QuantParams) -> Vec<f32> {
    let mut out = vec![0f32; codes.len()];
    dequantize_into(codes, p, &mut out);
    out
}

/// Quantize-dequantize round trip (what the receiving stage actually sees).
pub fn roundtrip(x: &[f32], p: &QuantParams) -> Vec<f32> {
    dequantize(&quantize(x, p), p)
}

/// Mean squared reconstruction error of quantizing `x` under `p`.
pub fn quant_mse(x: &[f32], p: &QuantParams) -> f64 {
    let inv = 1.0 / p.scale;
    let (zp, lo, hi) = (p.zero_point, p.lo, p.hi);
    let mut acc = 0f64;
    for &v in x {
        // Same max/min idiom as `quantize_into` (clamp's NaN ordering
        // blocks LLVM's vector min/max); identical result for lo <= hi.
        let c = (v * inv + zp).round().max(lo).min(hi);
        let xh = (c - zp) * p.scale;
        let e = (v - xh) as f64;
        acc += e * e;
    }
    acc / x.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_covers_minmax() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32) * 0.13 - 5.0).collect();
        for bits in crate::quant::SUPPORTED_BITS {
            let p = naive_params(&x, bits);
            let codes = quantize(&x, &p);
            assert!(codes.iter().all(|&c| c >= 0 && c < (1 << bits)));
            // Extremes map near the code range ends.
            assert!(codes[0] <= 1);
            assert!(codes[99] >= (1 << bits) - 2);
        }
    }

    #[test]
    fn symmetric_range_signed() {
        let p = symmetric_params(1.0, 4);
        assert_eq!(p.lo, -8.0);
        assert_eq!(p.hi, 7.0);
        assert!((p.scale - 0.125).abs() < 1e-7);
    }

    #[test]
    fn roundtrip_error_bounded_inside_clip() {
        let x: Vec<f32> = (0..1000).map(|i| ((i as f32) / 500.0 - 1.0) * 0.99).collect();
        for bits in crate::quant::SUPPORTED_BITS {
            let p = symmetric_params(1.0, bits);
            let xh = roundtrip(&x, &p);
            // The half-step bound holds on the representable range
            // [lo*scale, hi*scale]; beyond it values clamp to the edge.
            let (rep_lo, rep_hi) = (p.lo * p.scale, p.hi * p.scale);
            for (a, b) in x.iter().zip(&xh) {
                if *a >= rep_lo && *a <= rep_hi {
                    assert!((a - b).abs() <= p.scale / 2.0 + 1e-6, "bits={bits} a={a} b={b}");
                } else {
                    assert!((*b - rep_hi).abs() < 1e-6 || (*b - rep_lo).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn clipping_clamps_outliers() {
        let x = vec![-100.0f32, 0.0, 100.0];
        let p = symmetric_params(1.0, 8);
        let xh = roundtrip(&x, &p);
        assert!(xh[0] >= -1.0 - 1e-6 && xh[2] <= 1.0);
        assert_eq!(xh[1], 0.0);
    }

    #[test]
    fn degenerate_constant_tensor() {
        let x = vec![3.2f32; 64];
        for bits in crate::quant::SUPPORTED_BITS {
            let p = naive_params(&x, bits);
            assert!(p.scale > 0.0 && p.scale.is_finite());
            let xh = roundtrip(&x, &p);
            assert!(xh.iter().all(|v| (v - 3.2).abs() < 1e-2));
        }
    }

    #[test]
    fn mse_matches_roundtrip() {
        let x: Vec<f32> = (0..512).map(|i| ((i * 2654435761u64 as usize) % 1000) as f32 / 100.0 - 5.0).collect();
        let p = symmetric_params(2.0, 4);
        let xh = roundtrip(&x, &p);
        let direct: f64 = x.iter().zip(&xh).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / x.len() as f64;
        assert!((quant_mse(&x, &p) - direct).abs() < 1e-12);
    }
}
