//! Streaming tensor statistics and the |x| histogram used by DS-ACIQ and
//! the Fig 3/4 analyses.

/// Single-pass min / max / mean|x| / mean / variance over a tensor.
#[derive(Debug, Clone, Copy)]
pub struct TensorStats {
    pub min: f32,
    pub max: f32,
    pub mean: f64,
    pub mean_abs: f64,
    pub var: f64,
    pub n: usize,
}

impl TensorStats {
    pub fn compute(x: &[f32]) -> Self {
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        let (mut s, mut sa, mut s2) = (0f64, 0f64, 0f64);
        for &v in x {
            min = min.min(v);
            max = max.max(v);
            let d = v as f64;
            s += d;
            sa += d.abs();
            s2 += d * d;
        }
        let n = x.len().max(1) as f64;
        let mean = s / n;
        TensorStats {
            min,
            max,
            mean,
            mean_abs: sa / n,
            var: (s2 / n - mean * mean).max(0.0),
            n: x.len(),
        }
    }

    pub fn std(&self) -> f64 {
        self.var.sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.min.abs().max(self.max.abs())
    }

    /// Excess kurtosis (needs a second pass; used by analyses, not hot path).
    pub fn excess_kurtosis(&self, x: &[f32]) -> f64 {
        if self.var <= 0.0 || x.is_empty() {
            return 0.0;
        }
        let m4: f64 = x
            .iter()
            .map(|&v| {
                let d = v as f64 - self.mean;
                d * d * d * d
            })
            .sum::<f64>()
            / x.len() as f64;
        m4 / (self.var * self.var) - 3.0
    }
}

/// |x| histogram: fixed bin count over `[0, max|x|]`, matching ref.py's
/// `histogram` so the DS search sees identical bins in both languages.
#[derive(Debug, Clone)]
pub struct AbsHistogram {
    pub counts: Vec<u64>,
    pub width: f64,
    pub total: u64,
}

pub const DEFAULT_BINS: usize = 2048;

impl AbsHistogram {
    pub fn compute(x: &[f32], bins: usize) -> Self {
        let mut top = 0f32;
        for &v in x {
            top = top.max(v.abs());
        }
        let top = if top > 0.0 { top as f64 } else { 1e-12 };
        let width = top / bins as f64;
        let mut counts = vec![0u64; bins];
        let inv = bins as f64 / top;
        for &v in x {
            // numpy's histogram places x == top in the last bin.
            let mut idx = (v.abs() as f64 * inv) as usize;
            if idx >= bins {
                idx = bins - 1;
            }
            counts[idx] += 1;
        }
        AbsHistogram { counts, width, total: x.len() as u64 }
    }

    /// Bin center of bin `i` (matches numpy's edge midpoints).
    pub fn center(&self, i: usize) -> f64 {
        (i as f64 + 0.5) * self.width
    }

    /// Real signed-axis density `D_R` at bin `i` (÷2 unfolds |x| symmetry).
    pub fn density(&self, i: usize) -> f64 {
        self.counts[i] as f64 / (self.total.max(1) as f64 * self.width) / 2.0
    }

    /// `max(D_R)` — the real density peak used for the search direction and
    /// boundary in DS-ACIQ.
    pub fn peak_density(&self) -> f64 {
        let max_count = self.counts.iter().copied().max().unwrap_or(0);
        max_count as f64 / (self.total.max(1) as f64 * self.width) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_vector() {
        let x = [-2.0f32, -1.0, 0.0, 1.0, 2.0];
        let s = TensorStats::compute(&x);
        assert_eq!(s.min, -2.0);
        assert_eq!(s.max, 2.0);
        assert!((s.mean).abs() < 1e-12);
        assert!((s.mean_abs - 1.2).abs() < 1e-12);
        assert!((s.var - 2.0).abs() < 1e-12);
        assert_eq!(s.abs_max(), 2.0);
    }

    #[test]
    fn histogram_mass_conserved() {
        let x: Vec<f32> = (0..10000).map(|i| ((i % 97) as f32 - 48.0) * 0.11).collect();
        let h = AbsHistogram::compute(&x, DEFAULT_BINS);
        assert_eq!(h.counts.iter().sum::<u64>(), 10000);
        assert_eq!(h.total, 10000);
    }

    #[test]
    fn histogram_density_integrates_to_half() {
        // sum(density * width) over |x| bins = 1/2 (the other half is x<0).
        let x: Vec<f32> = (0..5000).map(|i| i as f32 / 500.0 - 5.0).collect();
        let h = AbsHistogram::compute(&x, 256);
        let integral: f64 = (0..256).map(|i| h.density(i) * h.width).sum();
        assert!((integral - 0.5).abs() < 1e-9);
    }

    #[test]
    fn uniform_density_flat() {
        // |x| of symmetric uniform data is uniform on [0, top].
        let x: Vec<f32> = (0..100000).map(|i| (i as f32 / 50000.0) - 1.0).collect();
        let h = AbsHistogram::compute(&x, 64);
        let d0 = h.density(1);
        for i in 2..63 {
            assert!((h.density(i) - d0).abs() / d0 < 0.05, "bin {i}");
        }
    }

    #[test]
    fn kurtosis_sign() {
        let mut rng = crate::util::rng::Rng::seed(9);
        let gauss = rng.gaussian_vec(20000, 1.0);
        let s = TensorStats::compute(&gauss);
        assert!(s.excess_kurtosis(&gauss).abs() < 0.2, "{}", s.excess_kurtosis(&gauss));
        // Laplace has excess kurtosis 3.
        let lap = rng.laplace_vec(20000, 1.0);
        let s2 = TensorStats::compute(&lap);
        let k = s2.excess_kurtosis(&lap);
        assert!(k > 1.5 && k < 4.5, "{k}");
    }
}
