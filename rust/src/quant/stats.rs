//! Streaming tensor statistics and the |x| histogram used by DS-ACIQ and
//! the Fig 3/4 analyses — plus [`CalibScan`], the fused calibration scan
//! that derives everything PDA/ACIQ/DS-ACIQ calibration needs from one
//! stats pass over the data (the histogram reuses the scan's `abs_max`
//! as its `top`, so the old separate mean|x| and max|x| passes are gone).

/// Single-pass min / max / mean|x| / mean / variance over a tensor.
#[derive(Debug, Clone, Copy)]
pub struct TensorStats {
    /// Smallest element.
    pub min: f32,
    /// Largest element.
    pub max: f32,
    /// Arithmetic mean.
    pub mean: f64,
    /// Mean of |x| (the Laplace moment estimate feeds on this).
    pub mean_abs: f64,
    /// Population variance.
    pub var: f64,
    /// Element count.
    pub n: usize,
}

impl TensorStats {
    /// One pass over `x`.
    pub fn compute(x: &[f32]) -> Self {
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        let (mut s, mut sa, mut s2) = (0f64, 0f64, 0f64);
        for &v in x {
            min = min.min(v);
            max = max.max(v);
            let d = v as f64;
            s += d;
            sa += d.abs();
            s2 += d * d;
        }
        let n = x.len().max(1) as f64;
        let mean = s / n;
        TensorStats {
            min,
            max,
            mean,
            mean_abs: sa / n,
            var: (s2 / n - mean * mean).max(0.0),
            n: x.len(),
        }
    }

    /// Standard deviation.
    pub fn std(&self) -> f64 {
        self.var.sqrt()
    }

    /// max(|min|, |max|).
    pub fn abs_max(&self) -> f32 {
        self.min.abs().max(self.max.abs())
    }

    /// Excess kurtosis (needs a second pass; used by analyses, not hot path).
    pub fn excess_kurtosis(&self, x: &[f32]) -> f64 {
        if self.var <= 0.0 || x.is_empty() {
            return 0.0;
        }
        let m4: f64 = x
            .iter()
            .map(|&v| {
                let d = v as f64 - self.mean;
                d * d * d * d
            })
            .sum::<f64>()
            / x.len() as f64;
        m4 / (self.var * self.var) - 3.0
    }
}

/// |x| histogram: fixed bin count over `[0, max|x|]`, matching ref.py's
/// `histogram` so the DS search sees identical bins in both languages.
#[derive(Debug, Clone)]
pub struct AbsHistogram {
    /// Bin occupancy.
    pub counts: Vec<u64>,
    /// Bin width in |x| units.
    pub width: f64,
    /// Elements binned.
    pub total: u64,
}

/// Default histogram resolution.
pub const DEFAULT_BINS: usize = 2048;

impl AbsHistogram {
    /// Two passes over `x`: max scan + binning.
    pub fn compute(x: &[f32], bins: usize) -> Self {
        let mut top = 0f32;
        for &v in x {
            top = top.max(v.abs());
        }
        Self::compute_with_top(x, bins, top)
    }

    /// Binning pass with a precomputed `top = max|x|` — e.g. from a
    /// [`TensorStats`] scan (`abs_max()`), which is how [`CalibScan`]
    /// eliminates the separate |x|-max pass. `top <= 0` falls back to the
    /// same degenerate width [`AbsHistogram::compute`] uses, so the two
    /// constructors produce identical histograms for identical `top`.
    pub fn compute_with_top(x: &[f32], bins: usize, top: f32) -> Self {
        let top = if top > 0.0 { top as f64 } else { 1e-12 };
        let width = top / bins as f64;
        let mut counts = vec![0u64; bins];
        let inv = bins as f64 / top;
        for &v in x {
            // numpy's histogram places x == top in the last bin.
            let mut idx = (v.abs() as f64 * inv) as usize;
            if idx >= bins {
                idx = bins - 1;
            }
            counts[idx] += 1;
        }
        AbsHistogram { counts, width, total: x.len() as u64 }
    }

    /// Bin center of bin `i` (matches numpy's edge midpoints).
    pub fn center(&self, i: usize) -> f64 {
        (i as f64 + 0.5) * self.width
    }

    /// Real signed-axis density `D_R` at bin `i` (÷2 unfolds |x| symmetry).
    pub fn density(&self, i: usize) -> f64 {
        self.counts[i] as f64 / (self.total.max(1) as f64 * self.width) / 2.0
    }

    /// `max(D_R)` — the real density peak used for the search direction and
    /// boundary in DS-ACIQ.
    pub fn peak_density(&self) -> f64 {
        let max_count = self.counts.iter().copied().max().unwrap_or(0);
        max_count as f64 / (self.total.max(1) as f64 * self.width) / 2.0
    }
}

/// Indices of the `k` largest-|x| elements of `x`, ascending index order.
///
/// O(n) selection (`select_nth_unstable_by`) rather than a full sort —
/// this runs per encode on the tiled hot path, where `k` is a small
/// fraction of `n` (the outlier side-channel). NaN ranks above every
/// finite value (`total_cmp` on |x|), so poisoned elements land in the
/// raw side-channel instead of poisoning a tile's calibration.
pub fn top_abs_indices(x: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(x.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..x.len() as u32).collect();
    let key = |i: &u32| x[*i as usize].abs();
    idx.select_nth_unstable_by(k - 1, |a, b| key(b).total_cmp(&key(a)));
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// Fused calibration scan: [`TensorStats`] and the |x| histogram from a
/// single stats pass plus one binning pass.
///
/// The unfused DS-ACIQ calibration read the tensor three times: the
/// mean|x| pass (`aciq::laplace_b`), the histogram's own max|x| pass, and
/// the binning pass. The stats pass already yields both the moment
/// estimate (`mean_abs`) *and* the histogram's top (`abs_max()` — max|x|
/// of any real-valued tensor is `max(|min|, |max|)`), so only the binning
/// pass remains. On the deployed hot path (`ds_aciq_b_sampled`) the
/// binned data is the ≤16k-element subsample, which is cache-resident by
/// the time binning runs — full-tensor memory traffic is one read.
///
/// Exactness: `b_e()` performs the same f64 accumulation in the same
/// order as `aciq::laplace_b`, and the histogram is built by the same
/// binning code as [`AbsHistogram::compute`] with an identical `top`, so
/// the fused scan is bit-for-bit the unfused calibration (golden-pinned
/// via tests/golden.rs through `ds_aciq_b`).
#[derive(Debug, Clone)]
pub struct CalibScan {
    /// Moment statistics from the fused pass.
    pub stats: TensorStats,
    /// |x| histogram from the binning pass.
    pub hist: AbsHistogram,
}

impl CalibScan {
    /// Fused calibration scan: one stats pass + one binning pass.
    pub fn compute(x: &[f32], bins: usize) -> Self {
        let stats = TensorStats::compute(x);
        // Empty input: ±inf min/max would give an infinite abs_max;
        // compute()'s max-fold yields 0 there, so mirror that.
        let top = if stats.n == 0 { 0.0 } else { stats.abs_max() };
        let hist = AbsHistogram::compute_with_top(x, bins, top);
        CalibScan { stats, hist }
    }

    /// The Laplace moment estimate `b_E = mean|x|` — numerically identical
    /// to [`crate::quant::aciq::laplace_b`] over the same data.
    pub fn b_e(&self) -> f32 {
        self.stats.mean_abs as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_vector() {
        let x = [-2.0f32, -1.0, 0.0, 1.0, 2.0];
        let s = TensorStats::compute(&x);
        assert_eq!(s.min, -2.0);
        assert_eq!(s.max, 2.0);
        assert!((s.mean).abs() < 1e-12);
        assert!((s.mean_abs - 1.2).abs() < 1e-12);
        assert!((s.var - 2.0).abs() < 1e-12);
        assert_eq!(s.abs_max(), 2.0);
    }

    #[test]
    fn histogram_mass_conserved() {
        let x: Vec<f32> = (0..10000).map(|i| ((i % 97) as f32 - 48.0) * 0.11).collect();
        let h = AbsHistogram::compute(&x, DEFAULT_BINS);
        assert_eq!(h.counts.iter().sum::<u64>(), 10000);
        assert_eq!(h.total, 10000);
    }

    #[test]
    fn histogram_density_integrates_to_half() {
        // sum(density * width) over |x| bins = 1/2 (the other half is x<0).
        let x: Vec<f32> = (0..5000).map(|i| i as f32 / 500.0 - 5.0).collect();
        let h = AbsHistogram::compute(&x, 256);
        let integral: f64 = (0..256).map(|i| h.density(i) * h.width).sum();
        assert!((integral - 0.5).abs() < 1e-9);
    }

    #[test]
    fn uniform_density_flat() {
        // |x| of symmetric uniform data is uniform on [0, top].
        let x: Vec<f32> = (0..100000).map(|i| (i as f32 / 50000.0) - 1.0).collect();
        let h = AbsHistogram::compute(&x, 64);
        let d0 = h.density(1);
        for i in 2..63 {
            assert!((h.density(i) - d0).abs() / d0 < 0.05, "bin {i}");
        }
    }

    #[test]
    fn calib_scan_matches_unfused_exactly() {
        let mut rng = crate::util::rng::Rng::seed(21);
        let x = rng.laplace_vec(30000, 0.7);
        let scan = CalibScan::compute(&x, DEFAULT_BINS);
        // b_E: identical accumulation to aciq::laplace_b.
        assert_eq!(
            scan.b_e().to_bits(),
            crate::quant::aciq::laplace_b(&x).to_bits()
        );
        // Histogram: identical top → identical width and counts.
        let unfused = AbsHistogram::compute(&x, DEFAULT_BINS);
        assert_eq!(scan.hist.width.to_bits(), unfused.width.to_bits());
        assert_eq!(scan.hist.counts, unfused.counts);
        assert_eq!(scan.hist.total, unfused.total);
    }

    #[test]
    fn calib_scan_degenerate_inputs() {
        // Empty and all-zero inputs take the same 1e-12 degenerate width
        // as the unfused constructor.
        for x in [vec![], vec![0.0f32; 64]] {
            let scan = CalibScan::compute(&x, 32);
            let unfused = AbsHistogram::compute(&x, 32);
            assert_eq!(scan.hist.width.to_bits(), unfused.width.to_bits());
            assert_eq!(scan.hist.counts, unfused.counts);
        }
    }

    #[test]
    fn compute_with_top_matches_compute() {
        let x: Vec<f32> = (0..5000).map(|i| ((i as f32) * 0.37).sin() * 2.5).collect();
        let mut top = 0f32;
        for &v in &x {
            top = top.max(v.abs());
        }
        let a = AbsHistogram::compute(&x, 128);
        let b = AbsHistogram::compute_with_top(&x, 128, top);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.width.to_bits(), b.width.to_bits());
    }

    #[test]
    fn top_abs_indices_finds_the_spikes() {
        let mut x = vec![0.1f32; 1000];
        x[3] = -50.0;
        x[997] = 40.0;
        x[500] = f32::NAN;
        assert_eq!(top_abs_indices(&x, 3), vec![3, 500, 997]);
        assert_eq!(top_abs_indices(&x, 0), Vec::<u32>::new());
        assert_eq!(top_abs_indices(&[1.0, 2.0], 5), vec![0, 1]);
    }

    #[test]
    fn kurtosis_sign() {
        let mut rng = crate::util::rng::Rng::seed(9);
        let gauss = rng.gaussian_vec(20000, 1.0);
        let s = TensorStats::compute(&gauss);
        assert!(s.excess_kurtosis(&gauss).abs() < 0.2, "{}", s.excess_kurtosis(&gauss));
        // Laplace has excess kurtosis 3.
        let lap = rng.laplace_vec(20000, 1.0);
        let s2 = TensorStats::compute(&lap);
        let k = s2.excess_kurtosis(&lap);
        assert!(k > 1.5 && k < 4.5, "{k}");
    }
}
