//! Post-training quantization (paper §3).
//!
//! Three methods, matching Table 1's rows:
//! * **naive PTQ** ([`uniform::naive_params`]) — asymmetric affine range from
//!   the tensor min/max; collapses at small bitwidths because outliers blow
//!   up the quantization interval (Fig 3).
//! * **ACIQ** ([`aciq`]) — Banner et al.'s analytically-optimal symmetric
//!   clip `alpha = F(q) * b` under a Laplace(0, b) assumption with the
//!   moment estimate `b_E = mean(|x|)`.
//! * **DS-ACIQ** ([`ds_aciq`]) — the paper's contribution: a directed
//!   numerical search for a scale `b*` whose Laplace density better fits the
//!   *real* activation histogram (Eq. 1), bridging the estimated-vs-real
//!   distribution gap that wrecks 2-bit ACIQ.
//!
//! **PDA** (= PTQ with DS-ACIQ) dispatches: DS-ACIQ at 2/4-bit, plain ACIQ
//! otherwise (§3: "the DS-ACIQ approach is only activated under 4- and
//! 2-bit quantization").
//!
//! The numerical semantics of every function here are pinned to the python
//! oracle `python/compile/kernels/ref.py` via `artifacts/golden.json`
//! (tests/golden.rs) and to the Pallas kernel via the runtime tests.
//!
//! **Hot-path layout** (see ROADMAP "Codec hot path"): the deployed data
//! path is [`fused`] — single-pass quantize+pack / unpack+dequantize
//! kernels (optionally multicore on encode) that are byte-identical to
//! the reference two-pass [`uniform`]+[`pack`] route; calibration runs
//! through [`stats::CalibScan`], one fused stats+histogram scan. The
//! two-pass modules remain the numerical reference and the staging path
//! for external backends (the AOT Pallas kernel). [`tile`] layers
//! tile-wise hybrid quantization on top of [`fused`]: per-tile scales, a
//! raw-f32 outlier side-channel, and a budgeted non-uniform bit
//! allocation across tiles.

pub mod aciq;
pub mod codec;
pub mod ds_aciq;
pub mod fused;
pub mod pack;
pub mod stats;
pub mod tile;
pub mod uniform;

/// Bitwidths supported on the wire. 32 means "no quantization" (raw f32).
pub const SUPPORTED_BITS: [u8; 5] = [2, 4, 6, 8, 16];

/// `q = 32`: pass-through (no quantization), the pipeline's nominal state.
pub const BITS_NONE: u8 = 32;

/// Quantization method selector (Table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Method {
    /// Asymmetric affine min/max PTQ.
    Naive,
    /// Laplace-optimal symmetric clip (moment-estimated scale).
    Aciq,
    /// Directed-search ACIQ (always on).
    DsAciq,
    /// The paper's deployed config: DS-ACIQ at 2/4-bit, ACIQ elsewhere.
    #[default]
    Pda,
}

impl Method {
    /// Every method, in Table 1 order.
    pub const ALL: [Method; 4] = [Method::Naive, Method::Aciq, Method::DsAciq, Method::Pda];

    /// Lowercase CLI/config name.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Naive => "naive",
            Method::Aciq => "aciq",
            Method::DsAciq => "ds_aciq",
            Method::Pda => "pda",
        }
    }
}

/// Affine quantizer parameters: `codes = clamp(round(x/scale + zp), lo, hi)`.
///
/// The single affine form covers naive (zp != 0, unsigned range) and
/// symmetric-clipped (zp = 0, signed range) quantization, and is exactly the
/// runtime-input signature of the AOT Pallas kernel — so a `QuantParams` is
/// both the native-path and the HLO-path parameterization.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Code step size.
    pub scale: f32,
    /// Code-space offset (0 for symmetric).
    pub zero_point: f32,
    /// Smallest representable code.
    pub lo: f32,
    /// Largest representable code.
    pub hi: f32,
    /// Bitwidth these params were derived for (2..=16).
    pub bits: u8,
}

impl QuantParams {
    /// Number of representable codes; always `2^bits`.
    pub fn levels(&self) -> u32 {
        (self.hi - self.lo) as u32 + 1
    }

    /// Offset applied before bit-packing so codes are non-negative.
    pub fn pack_offset(&self) -> i32 {
        self.lo as i32
    }
}

/// Derive quantizer params for `x` under `method` at `bits`.
///
/// This is the calibration step of the PDA module: stats (+ histogram and
/// directed search when DS is active) -> clip range -> affine params. It is
/// control-path work; the data-path quantize/dequantize runs either through
/// the AOT Pallas kernel or [`uniform`]'s native implementation.
pub fn calibrate(x: &[f32], method: Method, bits: u8) -> QuantParams {
    debug_assert!(SUPPORTED_BITS.contains(&bits), "unsupported bitwidth {bits}");
    match method {
        Method::Naive => uniform::naive_params(x, bits),
        Method::Aciq => {
            let alpha = aciq::aciq_alpha(x, bits);
            uniform::symmetric_params(alpha, bits)
        }
        Method::DsAciq => {
            let b = ds_aciq::ds_aciq_b_sampled(
                x,
                bits,
                ds_aciq::DEFAULT_STEPS,
                ds_aciq::CALIB_MAX_SAMPLES,
            )
            .b_star;
            uniform::symmetric_params(aciq::ratio(bits) * b, bits)
        }
        Method::Pda => {
            if bits <= 4 {
                calibrate(x, Method::DsAciq, bits)
            } else {
                calibrate(x, Method::Aciq, bits)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn laplace(n: usize, b: f32, seed: u64) -> Vec<f32> {
        crate::util::rng::Rng::seed(seed).laplace_vec(n, b)
    }

    #[test]
    fn pda_dispatches_to_ds_at_low_bits() {
        let x = laplace(4096, 1.0, 7);
        for bits in [2u8, 4] {
            assert_eq!(
                calibrate(&x, Method::Pda, bits),
                calibrate(&x, Method::DsAciq, bits)
            );
        }
        for bits in [6u8, 8, 16] {
            assert_eq!(
                calibrate(&x, Method::Pda, bits),
                calibrate(&x, Method::Aciq, bits)
            );
        }
    }

    #[test]
    fn levels_match_bits() {
        let x = laplace(1024, 0.5, 3);
        for m in Method::ALL {
            for bits in SUPPORTED_BITS {
                let p = calibrate(&x, m, bits);
                assert_eq!(p.levels(), 1u32 << bits, "{m:?} {bits}");
                assert_eq!(p.bits, bits);
            }
        }
    }

    #[test]
    fn symmetric_methods_have_zero_zp() {
        let x = laplace(1024, 1.0, 9);
        for m in [Method::Aciq, Method::DsAciq, Method::Pda] {
            for bits in SUPPORTED_BITS {
                assert_eq!(calibrate(&x, m, bits).zero_point, 0.0);
            }
        }
    }
}
