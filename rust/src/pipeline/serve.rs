//! Multi-stream serving plane: admission, fair interleaving, backpressure.
//!
//! The paper's coordinator pushes ONE microbatch stream end to end. The
//! serving plane turns it into an ingest front-end: N concurrent client
//! sessions each get a **stream ID** (carried in the frame header, see
//! `net::frame` v2), a bounded ingress queue, and a seat in a weighted
//! round-robin rotation that interleaves their microbatches through the
//! one shared stage chain.
//!
//! Design rules:
//!
//! * **Per-stream backpressure.** A stream whose queue is full gets
//!   [`Admission::Backpressured`] — that client stalls; everyone else's
//!   admission is untouched. The stall is counted per stream, so the
//!   report can show *who* absorbed the pressure.
//! * **Fairness guard.** Dispatch is deficit round-robin with the quantum
//!   equal to the stream's weight, and weights are clamped to
//!   [`MAX_WEIGHT`]. A backlogged stream is therefore served again after
//!   at most `Σ other-weights` dispatches, no matter how much load a
//!   heavy client offers: starvation is structurally impossible.
//! * **Per-stream FIFO, exactly once.** Each lane is a `VecDeque`; items
//!   leave in arrival order and exactly one `next()` returns each one.
//! * **Streams are routing, not reliability.** The scheduler hands out
//!   interleaved items; the caller assigns *global* sequence numbers as
//!   it sends. The session layer (replay/ACK/HELLO) never sees streams.
//!
//! [`ServeScheduler`] is the pure, single-threaded state machine — the
//! property tests drive it directly. [`ServeFrontend`] wraps it for the
//! live coordinator: blocking `submit` for client threads, `pop` for the
//! dispatch thread, wakeups via the missed-notification-proof
//! [`crate::util::sync::Notify`].

use crate::util::sync::{Notify, TrackedMutex};
use crate::Result;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Hard cap on a stream's WRR weight (= its dispatch quantum). The cap
/// is the fairness guard: it bounds how long any one stream can hold the
/// rotation, so a heavy client cannot configure itself into starving
/// the rest.
pub const MAX_WEIGHT: u32 = 16;

/// Admission verdict for one offered microbatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission<T> {
    /// Queued; the item will be dispatched in per-stream FIFO order.
    Admitted,
    /// This stream's ingress queue is full; the item comes back to the
    /// caller untouched. Only this client stalls — retry after a
    /// dispatch frees a slot.
    Backpressured(T),
}

/// Scheduler shape, from the `pipeline` config section.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Maximum concurrent client streams (`pipeline.max_streams`).
    pub max_streams: usize,
    /// Bounded ingress-queue depth per stream
    /// (`pipeline.stream_queue_depth`).
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_streams: 1, queue_depth: 4 }
    }
}

/// A point-in-time, per-stream view of the scheduler's counters —
/// the raw material for the report's per-stream rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamStats {
    /// Stream ID (frame-header tag).
    pub stream: u32,
    /// Effective (clamped) WRR weight.
    pub weight: u32,
    /// Items accepted into the ingress queue so far.
    pub admitted: u64,
    /// Items handed out by `next()` so far.
    pub dispatched: u64,
    /// Backpressure events: offers that found the queue full.
    pub stalls: u64,
    /// Current queue occupancy (≤ `queue_depth` always).
    pub queued: usize,
}

struct Lane<T> {
    id: u32,
    weight: u32,
    deficit: u32,
    queue: VecDeque<T>,
    admitted: u64,
    dispatched: u64,
    stalls: u64,
}

/// Weighted round-robin scheduler over bounded per-stream ingress
/// queues. Pure and deterministic: no threads, no clocks, no sockets —
/// see [`ServeFrontend`] for the concurrent wrapper.
pub struct ServeScheduler<T> {
    cfg: ServeConfig,
    lanes: Vec<Lane<T>>,
    cursor: usize,
}

impl<T> ServeScheduler<T> {
    /// An empty scheduler. Errors on a zero-sized config — both knobs
    /// are "at least one" quantities.
    pub fn new(cfg: ServeConfig) -> Result<Self> {
        anyhow::ensure!(cfg.max_streams >= 1, "serve: max_streams must be >= 1");
        anyhow::ensure!(cfg.queue_depth >= 1, "serve: stream_queue_depth must be >= 1");
        Ok(ServeScheduler { cfg, lanes: Vec::new(), cursor: 0 })
    }

    /// Open a client stream with the given WRR weight (clamped to
    /// `1..=MAX_WEIGHT`); returns its stream ID. Errors once
    /// `max_streams` sessions are open.
    pub fn open_stream(&mut self, weight: u32) -> Result<u32> {
        anyhow::ensure!(
            self.lanes.len() < self.cfg.max_streams,
            "serve: admission refused, max_streams = {} already open",
            self.cfg.max_streams
        );
        let id = self.lanes.len() as u32;
        self.lanes.push(Lane {
            id,
            weight: weight.clamp(1, MAX_WEIGHT),
            deficit: 0,
            queue: VecDeque::new(),
            admitted: 0,
            dispatched: 0,
            stalls: 0,
        });
        Ok(id)
    }

    fn lane_mut(&mut self, stream: u32) -> Result<&mut Lane<T>> {
        self.lanes
            .get_mut(stream as usize)
            .ok_or_else(|| anyhow::anyhow!("serve: unknown stream {stream}"))
    }

    /// Offer one item to `stream`'s ingress queue. A full queue returns
    /// [`Admission::Backpressured`] with the item (so the caller can
    /// retry) and bumps that stream's stall counter; no other stream is
    /// affected.
    pub fn offer(&mut self, stream: u32, item: T) -> Result<Admission<T>> {
        let depth = self.cfg.queue_depth;
        let lane = self.lane_mut(stream)?;
        if lane.queue.len() >= depth {
            lane.stalls += 1;
            return Ok(Admission::Backpressured(item));
        }
        lane.queue.push_back(item);
        lane.admitted += 1;
        Ok(Admission::Admitted)
    }

    /// Dispatch the next item under deficit round-robin: a lane earns
    /// `weight` credits when the rotation reaches it and keeps the turn
    /// until the credits — or its queue — run dry. `None` iff every
    /// queue is empty.
    pub fn next(&mut self) -> Option<(u32, T)> {
        let n = self.lanes.len();
        if n == 0 {
            return None;
        }
        let mut empties = 0;
        while empties <= n {
            let lane = &mut self.lanes[self.cursor];
            let Some(item) = (if lane.queue.is_empty() { None } else { lane.queue.pop_front() })
            else {
                // An idle lane forfeits its credits: deficits never
                // accumulate into a later burst past the quantum.
                lane.deficit = 0;
                self.cursor = (self.cursor + 1) % n;
                empties += 1;
                continue;
            };
            if lane.deficit == 0 {
                lane.deficit = lane.weight;
            }
            lane.deficit -= 1;
            lane.dispatched += 1;
            let id = lane.id;
            if lane.deficit == 0 || lane.queue.is_empty() {
                if lane.queue.is_empty() {
                    lane.deficit = 0;
                }
                self.cursor = (self.cursor + 1) % n;
            }
            return Some((id, item));
        }
        None
    }

    /// Total queued items across all streams.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.queue.len()).sum()
    }

    /// True when every ingress queue is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of open streams.
    pub fn streams(&self) -> usize {
        self.lanes.len()
    }

    /// Counter snapshot for every open stream, in stream-ID order.
    pub fn stats(&self) -> Vec<StreamStats> {
        self.lanes
            .iter()
            .map(|l| StreamStats {
                stream: l.id,
                weight: l.weight,
                admitted: l.admitted,
                dispatched: l.dispatched,
                stalls: l.stalls,
                queued: l.queue.len(),
            })
            .collect()
    }
}

/// Thread-safe wrapper for the live coordinator: client threads block in
/// [`ServeFrontend::submit`] while their lane is full (per-stream
/// backpressure made real), the dispatch thread drains via
/// [`ServeFrontend::pop`]. All waiting rides [`Notify`] epochs, so a
/// wakeup between check and wait is observed, never lost.
pub struct ServeFrontend<T> {
    sched: TrackedMutex<ServeScheduler<T>>,
    /// Bumped on every dispatch (queue space freed).
    space: Notify,
    /// Bumped on every admission (work available).
    work: Notify,
}

impl<T> ServeFrontend<T> {
    /// Wrap a configured scheduler (open its streams first).
    pub fn new(sched: ServeScheduler<T>) -> Arc<Self> {
        Arc::new(ServeFrontend {
            sched: TrackedMutex::new("serve.sched", sched),
            space: Notify::new(),
            work: Notify::new(),
        })
    }

    /// Blocking admission: retries until the item is queued, waiting on
    /// the dispatch signal between attempts. Returns how many
    /// backpressure stalls this submission absorbed — the caller's
    /// measure of "this client was the one held back".
    pub fn submit(&self, stream: u32, mut item: T) -> Result<u64> {
        let mut stalls = 0u64;
        loop {
            // Epoch BEFORE the offer: a dispatch that lands between the
            // failed offer and the wait bumps past `seen`, so the wait
            // returns immediately instead of sleeping on freed space.
            let seen = self.space.epoch();
            // Bind the verdict so the scheduler guard (a scrutinee
            // temporary) drops HERE — waiting below while holding it
            // would deadlock the dispatch thread.
            let verdict = self.sched.guard().offer(stream, item)?;
            match verdict {
                Admission::Admitted => {
                    self.work.notify();
                    return Ok(stalls);
                }
                Admission::Backpressured(back) => {
                    stalls += 1;
                    item = back;
                    self.space.wait_past(seen, Duration::from_millis(50));
                }
            }
        }
    }

    /// Dispatch one item, waiting up to `timeout` for work. `None`
    /// means the timeout elapsed with every queue empty — the caller
    /// decides whether that is "all clients done" or "keep waiting".
    pub fn pop(&self, timeout: Duration) -> Option<(u32, T)> {
        let deadline = Instant::now() + timeout;
        loop {
            let seen = self.work.epoch();
            let dispatched = self.sched.guard().next();
            if let Some(out) = dispatched {
                self.space.notify();
                return Some(out);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.work.wait_past(seen, deadline - now);
        }
    }

    /// Counter snapshot for every open stream, in stream-ID order.
    pub fn stats(&self) -> Vec<StreamStats> {
        self.sched.guard().stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(max_streams: usize, depth: usize) -> ServeScheduler<u64> {
        ServeScheduler::new(ServeConfig { max_streams, queue_depth: depth }).unwrap()
    }

    #[test]
    fn zero_sized_configs_are_rejected() {
        assert!(ServeScheduler::<u64>::new(ServeConfig { max_streams: 0, queue_depth: 4 }).is_err());
        assert!(ServeScheduler::<u64>::new(ServeConfig { max_streams: 2, queue_depth: 0 }).is_err());
    }

    #[test]
    fn admission_is_capped_at_max_streams() {
        let mut s = sched(2, 4);
        assert_eq!(s.open_stream(1).unwrap(), 0);
        assert_eq!(s.open_stream(1).unwrap(), 1);
        assert!(s.open_stream(1).is_err(), "third session must be refused");
        assert!(s.offer(7, 0).is_err(), "unknown stream must be an error");
    }

    #[test]
    fn equal_weights_interleave_round_robin() {
        let mut s = sched(3, 8);
        for _ in 0..3 {
            s.open_stream(1).unwrap();
        }
        for i in 0..4u64 {
            for st in 0..3u32 {
                assert_eq!(s.offer(st, i).unwrap(), Admission::Admitted);
            }
        }
        let order: Vec<u32> = std::iter::from_fn(|| s.next()).map(|(st, _)| st).collect();
        assert_eq!(order, vec![0, 1, 2, 0, 1, 2, 0, 1, 2, 0, 1, 2]);
        assert!(s.is_empty());
    }

    #[test]
    fn weights_shape_the_rotation_but_bound_the_burst() {
        // Stream 0 at weight 3, stream 1 at weight 1, both backlogged:
        // the DRR pattern is 0,0,0,1 repeating — stream 1 is served at
        // least once every `weight0 + weight1` dispatches.
        let mut s = sched(2, 16);
        s.open_stream(3).unwrap();
        s.open_stream(1).unwrap();
        for i in 0..12u64 {
            s.offer(0, i).unwrap();
        }
        for i in 0..4u64 {
            s.offer(1, i).unwrap();
        }
        let order: Vec<u32> = std::iter::from_fn(|| s.next()).map(|(st, _)| st).collect();
        assert_eq!(order[..8], [0, 0, 0, 1, 0, 0, 0, 1]);
        // Fairness guard: the gap between stream-1 services is bounded.
        let gaps: Vec<usize> = order
            .iter()
            .enumerate()
            .filter(|(_, &st)| st == 1)
            .map(|(i, _)| i)
            .collect();
        for w in gaps.windows(2) {
            assert!(w[1] - w[0] <= 4, "stream 1 starved: services at {gaps:?}");
        }
    }

    #[test]
    fn weight_is_clamped_to_the_fairness_cap() {
        let mut s = sched(2, 4);
        s.open_stream(1_000_000).unwrap();
        assert_eq!(s.stats()[0].weight, MAX_WEIGHT);
        s.open_stream(0).unwrap();
        assert_eq!(s.stats()[1].weight, 1, "weight 0 would never be scheduled");
    }

    #[test]
    fn full_queue_backpressures_only_that_stream() {
        let mut s = sched(2, 2);
        s.open_stream(1).unwrap();
        s.open_stream(1).unwrap();
        assert_eq!(s.offer(0, 10).unwrap(), Admission::Admitted);
        assert_eq!(s.offer(0, 11).unwrap(), Admission::Admitted);
        // Stream 0 is full: the item comes back, the stall is counted.
        assert_eq!(s.offer(0, 12).unwrap(), Admission::Backpressured(12));
        // Stream 1 is untouched by its neighbour's pressure.
        assert_eq!(s.offer(1, 20).unwrap(), Admission::Admitted);
        let st = s.stats();
        assert_eq!((st[0].stalls, st[0].queued), (1, 2));
        assert_eq!((st[1].stalls, st[1].queued), (0, 1));
        // A dispatch frees a slot and the retry lands.
        assert!(s.next().is_some());
        assert_eq!(s.offer(0, 12).unwrap(), Admission::Admitted);
    }

    #[test]
    fn per_stream_fifo_and_exactly_once() {
        let mut s = sched(2, 8);
        s.open_stream(2).unwrap();
        s.open_stream(1).unwrap();
        for i in 0..6u64 {
            s.offer((i % 2) as u32, i).unwrap();
        }
        let mut seen: Vec<Vec<u64>> = vec![Vec::new(), Vec::new()];
        while let Some((st, item)) = s.next() {
            seen[st as usize].push(item);
        }
        assert_eq!(seen[0], vec![0, 2, 4], "stream 0 FIFO, each item once");
        assert_eq!(seen[1], vec![1, 3, 5], "stream 1 FIFO, each item once");
    }

    #[test]
    fn frontend_blocks_the_full_stream_and_reports_its_stalls() {
        let mut s = sched(2, 1);
        s.open_stream(1).unwrap();
        s.open_stream(1).unwrap();
        let fe = ServeFrontend::new(s);
        assert_eq!(fe.submit(0, 1u64).unwrap(), 0, "first item admits clean");
        let heavy = {
            let fe = fe.clone();
            std::thread::spawn(move || fe.submit(0, 2u64).unwrap())
        };
        // Wait until the heavy client has actually hit the full queue —
        // popping earlier would let it slip in with zero stalls and turn
        // the assertion below into a race.
        let deadline = Instant::now() + Duration::from_secs(5);
        while fe.stats()[0].stalls == 0 {
            assert!(Instant::now() < deadline, "heavy submit never stalled");
            std::thread::yield_now();
        }
        // The light stream admits immediately even while stream 0's
        // client is parked in submit().
        assert_eq!(fe.submit(1, 9u64).unwrap(), 0);
        // Dispatching stream 0's head frees the slot and unblocks it.
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(fe.pop(Duration::from_secs(5)).expect("queued work"));
        }
        let stalls = heavy.join().unwrap();
        assert!(stalls >= 1, "the blocked submit must report its stalls");
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (0, 2), (1, 9)]);
        assert!(fe.pop(Duration::from_millis(10)).is_none(), "drained");
        let st = fe.stats();
        assert!(st[0].stalls >= 1);
        assert_eq!(st[1].stalls, 0);
    }
}
