//! Multi-process deployment endpoints (the paper's actual testbed shape:
//! stages on separate processes/devices, the coordinator feeding images
//! and collecting logits).
//!
//! A **worker** owns exactly one stage. It decodes frames from its
//! upstream transport, runs the shard, re-encodes at the bitwidth its own
//! adaptive controller currently publishes, and ships downstream through
//! a sender thread — the same `sender_thread` the in-process driver
//! uses, so the WindowMonitor/AdaptivePda loop is byte-for-byte the same
//! code over TCP. In TCP mode the bandwidth signal is measured
//! write-stall time under real socket backpressure; no `SimLink` exists
//! anywhere in the process.
//!
//! The **coordinator** is source + sink: it streams raw-f32 frames into
//! stage 0 and scores the logits frames returning from the last stage.
//! TCP's own flow control is the in-flight bound between processes.
//!
//! Wiring (CLI: `quantpipe worker` / `quantpipe coordinate`):
//!
//! ```text
//! coordinator ──connect──▶ worker 0 ──connect──▶ … ──▶ worker n-1
//!      ▲                                                   │
//!      └────────────── sink listener ◀──────connect────────┘
//! ```

use crate::adapt::AdaptConfig;
use crate::data::{AccuracyMeter, EvalSet};
use crate::metrics::telemetry::{CoordinatorSummary, PipelineReport, StreamSummary, TelemetryRelay};
use crate::metrics::{LatencyHisto, ResilienceSummary, StripeSummary, Timeline};
use crate::net::frame::Frame;
use crate::net::transport::{FrameRx, FrameTx, PreparedFrame};
use crate::pipeline::driver::{
    encode_at_current_bits, sender_thread, LinkCounters, LinkQuant, StageTelemetryShared,
    TelemetryTap, WirePool, Workload,
};
use crate::pipeline::serve::{ServeConfig, ServeFrontend, ServeScheduler};
use crate::pipeline::stage::StageFactory;
use crate::quant::codec::{Codec, Encoded};
use crate::quant::{Method, QuantParams, BITS_NONE};
use crate::tensor::Tensor;
use crate::util::json::Value;
use crate::util::sync::TrackedMutex;
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One worker's role in the pipeline.
#[derive(Debug, Clone, Copy)]
pub struct WorkerConfig {
    /// Stage index (for logs/timeline labelling).
    pub stage: usize,
    /// Output-link quantization behaviour.
    pub quant: LinkQuant,
    /// Adaptive controller for the output link; `None` pins
    /// `quant.initial_bits`. Ignored when `quantize_output` is false.
    pub adapt: Option<AdaptConfig>,
    /// Monitor window in microbatches.
    pub window: u64,
    /// Images per microbatch (the monitor's rate track).
    pub microbatch: usize,
    /// Quantize the output link. The last stage sets this false: logits
    /// return to the coordinator raw.
    pub quantize_output: bool,
    /// Frames buffered between compute and the transport writer.
    pub inflight: usize,
    /// Stream window snapshots forward to the coordinator (and relay
    /// upstream stages') as telemetry records — see
    /// [`crate::metrics::telemetry`]. Costs a few hundred wire bytes per
    /// window; off = this stage is a hole in the `PipelineReport`.
    pub telemetry: bool,
}

/// What a worker measured over its lifetime.
#[derive(Debug)]
pub struct WorkerReport {
    /// Microbatches processed.
    pub frames: u64,
    /// Window-by-window monitor/controller track for the output link.
    pub timeline: Timeline,
    /// Mean compute seconds per microbatch.
    pub mean_compute_s: f64,
    /// Mean wire bytes per frame on the output link.
    pub out_mean_bytes: f64,
    /// Transport failures observed (empty on a clean run).
    pub errors: Vec<String>,
    /// Reconnect/replay/dedup counters from resilient transports (both
    /// the upstream rx and the downstream tx; zero otherwise).
    pub resilience: ResilienceSummary,
    /// Per-stripe wire counters when the output link is striped (empty
    /// otherwise).
    pub stripes: Vec<StripeSummary>,
}

impl WorkerReport {
    /// Machine-readable report (`quantpipe worker --report-json`): the
    /// same measurements the stage streams to the coordinator as
    /// telemetry, persisted locally. Non-finite values map to `null`.
    pub fn to_json(&self) -> Value {
        let num = Value::num_or_null;
        let mut m = std::collections::BTreeMap::new();
        m.insert("frames".into(), Value::Num(self.frames as f64));
        m.insert("mean_compute_s".into(), num(self.mean_compute_s));
        m.insert("out_mean_bytes".into(), num(self.out_mean_bytes));
        m.insert("timeline".into(), self.timeline.to_json());
        m.insert("resilience".into(), self.resilience.to_json());
        m.insert("stripes".into(), StripeSummary::list_to_json(&self.stripes));
        m.insert(
            "errors".into(),
            Value::Arr(self.errors.iter().map(|e| Value::Str(e.clone())).collect()),
        );
        Value::Obj(m)
    }
}

/// Run one stage over arbitrary transports until the upstream closes.
/// Blocking; the calling thread is the stage's compute thread (PJRT is
/// thread-pinned), a spawned sender thread owns the output transport.
pub fn run_worker(
    factory: StageFactory,
    cfg: WorkerConfig,
    mut rx: Box<dyn FrameRx>,
    tx: Box<dyn FrameTx>,
) -> Result<WorkerReport> {
    let start = Instant::now();
    // Counter handles outlive the endpoints, which move into threads.
    let resilience_handles: Vec<_> =
        rx.resilience().into_iter().chain(tx.resilience()).collect();
    let stripe_handles: Vec<_> = tx.stripes().into_iter().flatten().collect();
    let initial_bits = if cfg.quantize_output { cfg.quant.initial_bits } else { BITS_NONE };
    let bits = Arc::new(AtomicU8::new(initial_bits));
    let avg_fp = Arc::new(AtomicU32::new(0));
    let timeline = Timeline::shared();
    let counters = Arc::new(LinkCounters::default());
    let errors: Arc<TrackedMutex<Vec<String>>> =
        Arc::new(TrackedMutex::new("worker.errors", Vec::new()));
    let (frame_tx, frame_rx) = sync_channel::<PreparedFrame>(cfg.inflight.max(1));
    let pool = WirePool::new();
    // Telemetry plumbing: the stage loop updates the shared counters and
    // relays upstream snapshots into `relay`; the sender thread's tap
    // snapshots both forward along the data path (toward the
    // coordinator's sink — the only connection still alive at the end).
    let shared = Arc::new(StageTelemetryShared::default());
    let relay = Arc::new(TrackedMutex::new("worker.relay", TelemetryRelay::default()));
    // The tap always exists so upstream stages' records keep flowing
    // through this hop; `cfg.telemetry` only gates this stage's OWN
    // snapshots (off = this stage is a hole in the report, nothing more).
    let tap = Some(TelemetryTap::new(
        cfg.stage,
        cfg.telemetry,
        shared.clone(),
        relay.clone(),
        resilience_handles.clone(),
        stripe_handles.clone(),
        errors.clone(),
    ));

    let sender = {
        let adapt = if cfg.quantize_output { cfg.adapt } else { None };
        let bits = bits.clone();
        let avg_fp = avg_fp.clone();
        let tl = timeline.clone();
        let counters = counters.clone();
        let errs = errors.clone();
        let (stage, window, batch) = (cfg.stage, cfg.window, cfg.microbatch);
        let pool = pool.clone();
        std::thread::Builder::new()
            .name(format!("qp-worker-send-{stage}"))
            .spawn(move || {
                sender_thread(
                    stage, frame_rx, tx, window, batch, adapt, initial_bits,
                    bits, avg_fp, tl, counters, errs, start, tap, pool,
                )
            })?
    };

    let (loop_result, frames, compute_secs) =
        worker_stage_loop(cfg, &mut rx, frame_tx, bits, avg_fp, factory, &shared, &relay, &pool);
    // frame_tx was moved into the loop and is dropped by now, so the
    // sender drains its channel, runs the downstream drain, and exits.
    let _ = sender.join();

    let mut errors = std::mem::take(&mut *errors.guard());
    if let Err(e) = loop_result {
        // Keep the progress counters: "stopped with an error after frame
        // 500" is what lets an operator correlate the shortfall.
        errors.push(format!("worker stage {}: {e:#}", cfg.stage));
    }

    Ok(WorkerReport {
        frames,
        // take_shared, not Arc::try_unwrap: a sender thread that leaked
        // its clone must not erase the timeline.
        timeline: Timeline::take_shared(&timeline),
        mean_compute_s: if frames > 0 { compute_secs / frames as f64 } else { 0.0 },
        out_mean_bytes: counters.mean_frame_bytes(),
        errors,
        resilience: ResilienceSummary::collect(&resilience_handles),
        stripes: StripeSummary::collect(&stripe_handles),
    })
}

/// Returns the loop outcome WITH the progress counters — a failure after
/// frame 500 still reports 500 frames of progress.
#[allow(clippy::too_many_arguments)]
fn worker_stage_loop(
    cfg: WorkerConfig,
    rx: &mut Box<dyn FrameRx>,
    frame_tx: SyncSender<PreparedFrame>,
    bits: Arc<AtomicU8>,
    avg_fp: Arc<AtomicU32>,
    factory: StageFactory,
    shared: &StageTelemetryShared,
    relay: &TrackedMutex<TelemetryRelay>,
    pool: &WirePool,
) -> (Result<()>, u64, f64) {
    let mut frames = 0u64;
    let mut compute_secs = 0f64;
    let result = (|| -> Result<()> {
        let bundle = factory()?;
        let mut compute = bundle.compute;
        let mut codec = Codec::new(bundle.quant_backend);
        codec.set_threads(cfg.quant.codec_threads);
        codec.set_tiling(cfg.quant.tile_codec());
        // One-slot decoded-activation pool (see the driver's stage loop):
        // decode into it, move it through the Tensor, reclaim after
        // compute — no per-microbatch clone.
        let mut decode_pool: Vec<f32> = Vec::new();
        let mut cached: Option<QuantParams> = None;
        let mut since_calib: u32 = 0;

        loop {
            let frame = match rx.recv() {
                Ok(Some(f)) => f,
                Ok(None) => return Ok(()), // clean upstream shutdown
                Err(e) => return Err(e.context("upstream link failed")),
            };
            // Upstream stages' telemetry relays through us toward the
            // coordinator; the sender thread forwards what lands here.
            for payload in rx.poll_telemetry() {
                relay.guard().offer(payload);
            }
            let t0 = Instant::now();
            let mut data = std::mem::take(&mut decode_pool);
            codec.decode(&frame.enc, &mut data)?;
            shared.decode_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            let Frame { seq, stream, shape, enc } = frame;
            codec.recycle(enc);
            let tensor = Tensor::new(data, shape);

            let t0 = Instant::now();
            let out = compute.run(&tensor)?;
            let dt = t0.elapsed();
            compute_secs += dt.as_secs_f64();
            shared.compute_ns.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
            decode_pool = tensor.into_data();

            let t0 = Instant::now();
            let enc = encode_at_current_bits(
                &mut codec, &out.data, &cfg.quant, &bits, &avg_fp, &mut cached,
                &mut since_calib,
            )?;
            shared.encode_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            // Serialize ONCE into a pooled wire buffer; the sender thread
            // ships the same bytes and the transport keeps them for replay
            // — no further copies (see the driver's stage loop). The
            // stream tag rides through unchanged: workers route payloads,
            // they never own streams.
            let out_frame = Frame::for_stream(stream, seq, out.shape.clone(), enc);
            let mut wire = pool.take();
            out_frame.write_into(&mut wire);
            let Frame { enc, .. } = out_frame;
            codec.recycle(enc);
            if frame_tx.send(PreparedFrame { seq, wire }).is_err() {
                // Sender died (downstream link failure, already recorded).
                return Ok(());
            }
            shared.enqueued.fetch_add(1, Ordering::Relaxed);
            frames += 1;
            shared.frames.fetch_add(1, Ordering::Relaxed);
        }
    })();
    // Hand the last inbound telemetry to the relay NOW — `frame_tx` is
    // still alive here, so the sender thread cannot have started its
    // final flush yet and is guaranteed to forward these.
    for payload in rx.poll_telemetry() {
        relay.guard().offer(payload);
    }
    (result, frames, compute_secs)
}

// -----------------------------------------------------------------------------
// Coordinator: source + sink over real transports
// -----------------------------------------------------------------------------

/// What the coordinator measured end-to-end.
#[derive(Debug)]
pub struct CoordinatorReport {
    /// Images scored.
    pub images: u64,
    /// Microbatches completed end to end.
    pub microbatches: u64,
    /// Wall-clock run seconds.
    pub wall_secs: f64,
    /// End-to-end images/sec.
    pub throughput: f64,
    /// Top-1 accuracy over all returned microbatches.
    pub accuracy: f64,
    /// End-to-end microbatch latency (feed → logits return).
    pub latency: LatencyHisto,
    /// Transport failures observed (empty on a clean run).
    pub errors: Vec<String>,
    /// Reconnect/replay/dedup counters from resilient transports (feed
    /// and return links; zero otherwise).
    pub resilience: ResilienceSummary,
    /// Per-stripe wire counters when the feed link is striped (empty
    /// otherwise).
    pub stripes: Vec<StripeSummary>,
    /// The whole pipeline's merged telemetry: every stage's window
    /// timeline plus this coordinator's end-to-end summary — the one
    /// artifact a multi-process run produces (see
    /// [`crate::metrics::telemetry`]). Stages whose workers ran with
    /// telemetry off (or never connected) are simply absent.
    pub pipeline: PipelineReport,
}

/// Feed the workload into stage 0 (`feed`) and score logits returning
/// from the last stage (`ret`). Blocking; a spawned thread feeds while
/// the calling thread sinks, so TCP flow control — not lockstep — paces
/// the pipeline.
pub fn run_coordinator(
    workload: Workload,
    feed: Box<dyn FrameTx>,
    mut ret: Box<dyn FrameRx>,
) -> Result<CoordinatorReport> {
    let start = Instant::now();
    let label_map: Arc<TrackedMutex<HashMap<u64, Vec<u32>>>> =
        Arc::new(TrackedMutex::new("coord.label_map", HashMap::new()));
    let send_times: Arc<TrackedMutex<HashMap<u64, Instant>>> =
        Arc::new(TrackedMutex::new("coord.send_times", HashMap::new()));
    let errors: Arc<TrackedMutex<Vec<String>>> =
        Arc::new(TrackedMutex::new("coord.errors", Vec::new()));
    let resilience_handles: Vec<_> =
        feed.resilience().into_iter().chain(ret.resilience()).collect();
    let stripe_handles: Vec<_> = feed.stripes().into_iter().flatten().collect();
    // Feed-failure propagation into the sink/drain path: how many
    // microbatches actually went out, and whether the feeder is done.
    // Without this the sink would keep waiting for `total` returns that
    // can never come after a hard feed failure.
    let fed = Arc::new(AtomicU64::new(0));
    let feed_done = Arc::new(AtomicBool::new(false));

    let feeder = {
        let eval = workload.eval.clone();
        let s = workload.microbatch;
        let total = workload.total;
        let labels = label_map.clone();
        let times = send_times.clone();
        let errs = errors.clone();
        let fed = fed.clone();
        let feed_done = feed_done.clone();
        std::thread::Builder::new()
            .name("qp-coord-feed".into())
            .spawn(move || {
                let mut feed = feed;
                let mut codec = Codec::default();
                let per_pass = eval.microbatches(s).max(1);
                let mut failed = false;
                for seq in 0..total {
                    let i = (seq as usize) % per_pass;
                    let tensor = eval.microbatch(i, s);
                    labels.guard().insert(seq, eval.labels_for(i, s).to_vec());
                    times.guard().insert(seq, Instant::now());
                    let enc = match codec.encode(&tensor.data, Method::Pda, BITS_NONE) {
                        Ok(e) => e,
                        Err(e) => {
                            errs.guard().push(format!("coordinator: encode failed: {e:#}"));
                            failed = true;
                            break;
                        }
                    };
                    // The FIRST hard send error ends the feed: every later
                    // microbatch would fail the same way, and one error per
                    // remaining microbatch only buries the root cause.
                    // (Resilient links absorb transient failures internally;
                    // an error here means the reconnect budget is gone.)
                    if let Err(e) = feed.send(Frame::new(seq, tensor.shape.clone(), enc)) {
                        errs.guard().push(format!("coordinator: feed link failed: {e:#}"));
                        failed = true;
                        break;
                    }
                    fed.fetch_add(1, Ordering::Release);
                }
                if !failed {
                    // Clean drain (FIN/FIN_ACK on resilient links) so
                    // stage 0 sees an explicit shutdown, not an EOF it
                    // might mistake for a failure.
                    if let Err(e) = feed.finish() {
                        errs.guard().push(format!("coordinator: feed drain failed: {e:#}"));
                    }
                }
                feed_done.store(true, Ordering::Release);
                // `feed` drops here; on plain TCP that half-closes the
                // socket and stage 0 sees a clean EOF after draining.
            })?
    };

    let mut acc = AccuracyMeter::default();
    let mut latency = LatencyHisto::default();
    let mut codec = Codec::default();
    // Every stage's telemetry funnels down the chain into the return
    // link; merge it as it arrives.
    let mut pipeline = PipelineReport::new();
    // One-slot logits-buffer pool, same shape as the stage loops'.
    let mut logits_pool: Vec<f32> = Vec::new();
    let mut done = 0u64;
    let mut images = 0u64;
    while done < workload.total {
        // A failed feed caps what can ever return: stop once everything
        // that was actually sent is accounted for.
        if feed_done.load(Ordering::Acquire) && done >= fed.load(Ordering::Acquire) {
            break;
        }
        let step = ret.recv();
        for payload in ret.poll_telemetry() {
            pipeline.ingest(&payload);
        }
        match step {
            Ok(Some(frame)) => {
                let mut data = std::mem::take(&mut logits_pool);
                if let Err(e) = codec.decode(&frame.enc, &mut data) {
                    errors.guard().push(format!("coordinator: logits decode failed: {e:#}"));
                    logits_pool = data;
                    continue;
                }
                let logits = Tensor::new(data, frame.shape.clone());
                if let Some(labels) = label_map.guard().remove(&frame.seq) {
                    images += labels.len() as u64;
                    acc.add(&logits, &labels);
                }
                if let Some(t0) = send_times.guard().remove(&frame.seq) {
                    latency.record(t0.elapsed());
                }
                done += 1;
                logits_pool = logits.into_data();
            }
            Ok(None) => break, // pipeline closed early
            Err(e) => {
                errors.guard().push(format!("coordinator: return link failed: {e:#}"));
                break;
            }
        }
    }
    if done >= workload.total {
        // Workload complete: consume the return link's end-of-stream.
        // On a resilient link this reads the last worker's FIN and sends
        // the FIN_ACK its drain is blocked on — stopping at `total` and
        // dropping the receiver would strand that worker in its drain
        // until the timeout and report a spurious failure. On plain TCP
        // this is a prompt EOF. Skipped on the error paths above: there
        // the link may never close and this would block. The final
        // telemetry snapshots (every stage's drain-time flush) ride just
        // ahead of that end-of-stream, so this drain is also what
        // completes the PipelineReport.
        while let Ok(Some(_)) = ret.recv() {}
    }
    for payload in ret.poll_telemetry() {
        pipeline.ingest(&payload);
    }
    let _ = feeder.join();
    let wall = start.elapsed().as_secs_f64().max(1e-9);
    let errors = std::mem::take(&mut *errors.guard());

    pipeline.coordinator = Some(CoordinatorSummary {
        images,
        microbatches: done,
        wall_secs: wall,
        throughput: images as f64 / wall,
        accuracy: acc.value(),
        p50_latency_s: latency.quantile(0.5).as_secs_f64(),
        p99_latency_s: latency.quantile(0.99).as_secs_f64(),
        // The classic coordinator is the single-stream special case: no
        // admission, no per-stream rows.
        streams: Vec::new(),
        errors: errors.clone(),
    });

    Ok(CoordinatorReport {
        images,
        microbatches: done,
        wall_secs: wall,
        throughput: images as f64 / wall,
        accuracy: acc.value(),
        latency,
        errors,
        resilience: ResilienceSummary::collect(&resilience_handles),
        stripes: StripeSummary::collect(&stripe_handles),
        pipeline,
    })
}

// -----------------------------------------------------------------------------
// Serving coordinator: N client streams through one stage chain
// -----------------------------------------------------------------------------

/// One client session's offered load and scheduling seat.
#[derive(Debug, Clone, Copy)]
pub struct StreamSpec {
    /// Weighted-round-robin weight (clamped to
    /// [`crate::pipeline::serve::MAX_WEIGHT`] — the fairness guard).
    pub weight: u32,
    /// Microbatches this client submits over its lifetime.
    pub microbatches: u64,
}

/// A multi-stream serving workload: N concurrent client sessions drawing
/// microbatches from one shared eval set, interleaved through the one
/// stage chain by [`run_serving_coordinator`].
pub struct ServeWorkload {
    /// Eval set every client cycles over.
    pub eval: Arc<EvalSet>,
    /// Images per microbatch.
    pub microbatch: usize,
    /// One entry per client stream; the entry's index is its stream ID.
    pub streams: Vec<StreamSpec>,
    /// Admission shape (`pipeline.max_streams`,
    /// `pipeline.stream_queue_depth`).
    pub serve: ServeConfig,
}

impl ServeWorkload {
    /// Total microbatches across every stream.
    pub fn total(&self) -> u64 {
        self.streams.iter().map(|s| s.microbatches).sum()
    }
}

/// One encoded microbatch parked in a stream's ingress queue: everything
/// the dispatcher needs to build the wire frame, plus the scoring state
/// the sink needs when the logits come back.
struct QueuedBatch {
    /// Per-stream submission index (the sink's FIFO check).
    idx: u64,
    shape: Vec<usize>,
    enc: Encoded,
    labels: Vec<u32>,
    /// Set when the client *offered* the microbatch — so completion
    /// latency includes time spent backpressured in submit().
    t0: Instant,
}

/// What the sink needs to score and account a returning frame.
struct Pending {
    stream: u32,
    idx: u64,
    labels: Vec<u32>,
    t0: Instant,
}

/// Per-stream sink-side accounting.
struct StreamAgg {
    frames: u64,
    next_idx: u64,
    latency: LatencyHisto,
}

/// Run N concurrent client sessions through one pipeline: each stream
/// gets a client thread that encodes and submits into the bounded-queue
/// WRR front-end ([`crate::pipeline::serve`]); a dispatch thread
/// interleaves the admitted microbatches in fair order, assigns **global**
/// sequence numbers (the session layer stays stream-oblivious) and tags
/// each frame with its stream ID; the calling thread sinks returning
/// logits, demuxing by the frame's stream tag. Blocking until every
/// stream completes or the pipeline fails.
///
/// The returned report's `pipeline.coordinator.streams` carries one row
/// per stream: frames completed, backpressure stalls absorbed, and
/// completion-latency percentiles measured from *offer* (so a
/// backpressured client's queueing delay is visible).
pub fn run_serving_coordinator(
    workload: ServeWorkload,
    feed: Box<dyn FrameTx>,
    mut ret: Box<dyn FrameRx>,
) -> Result<CoordinatorReport> {
    anyhow::ensure!(!workload.streams.is_empty(), "serving workload needs at least one stream");
    anyhow::ensure!(
        workload.streams.len() <= workload.serve.max_streams,
        "{} streams offered but pipeline.max_streams = {}",
        workload.streams.len(),
        workload.serve.max_streams
    );
    let start = Instant::now();
    let total = workload.total();
    let n_streams = workload.streams.len();

    let mut sched: ServeScheduler<QueuedBatch> = ServeScheduler::new(workload.serve)?;
    for spec in &workload.streams {
        sched.open_stream(spec.weight)?;
    }
    let frontend = ServeFrontend::new(sched);

    let pending: Arc<TrackedMutex<HashMap<u64, Pending>>> =
        Arc::new(TrackedMutex::new("serve.pending", HashMap::new()));
    let errors: Arc<TrackedMutex<Vec<String>>> =
        Arc::new(TrackedMutex::new("serve.errors", Vec::new()));
    let resilience_handles: Vec<_> =
        feed.resilience().into_iter().chain(ret.resilience()).collect();
    let stripe_handles: Vec<_> = feed.stripes().into_iter().flatten().collect();
    // `expected` is the number of microbatches that will actually reach
    // the dispatcher: a client that aborts early subtracts its unsent
    // remainder, so the dispatcher's drain loop always terminates.
    let expected = Arc::new(AtomicU64::new(total));
    // Set on feed failure: clients stop offering, the dispatcher keeps
    // draining (and discarding) so no client blocks in submit() forever.
    let abort = Arc::new(AtomicBool::new(false));
    let fed = Arc::new(AtomicU64::new(0));
    let feed_done = Arc::new(AtomicBool::new(false));

    // One client thread per stream: encode at full precision (the
    // coordinator feeds raw activations; stage links do the quantizing)
    // and submit. A full lane blocks HERE — per-stream backpressure.
    let mut clients = Vec::with_capacity(n_streams);
    for (stream, spec) in workload.streams.iter().copied().enumerate() {
        let stream = stream as u32;
        let eval = workload.eval.clone();
        let s = workload.microbatch;
        let fe = frontend.clone();
        let errs = errors.clone();
        let expected = expected.clone();
        let abort = abort.clone();
        clients.push(
            std::thread::Builder::new()
                .name(format!("qp-serve-client-{stream}"))
                .spawn(move || {
                    let mut codec = Codec::default();
                    let per_pass = eval.microbatches(s).max(1);
                    for i in 0..spec.microbatches {
                        if abort.load(Ordering::Acquire) {
                            expected.fetch_sub(spec.microbatches - i, Ordering::AcqRel);
                            return;
                        }
                        let mb = (i as usize) % per_pass;
                        let tensor = eval.microbatch(mb, s);
                        let labels = eval.labels_for(mb, s).to_vec();
                        let enc = match codec.encode(&tensor.data, Method::Pda, BITS_NONE) {
                            Ok(e) => e,
                            Err(e) => {
                                errs.guard()
                                    .push(format!("stream {stream}: encode failed: {e:#}"));
                                expected.fetch_sub(spec.microbatches - i, Ordering::AcqRel);
                                return;
                            }
                        };
                        let batch = QueuedBatch {
                            idx: i,
                            shape: tensor.shape.clone(),
                            enc,
                            labels,
                            t0: Instant::now(),
                        };
                        if let Err(e) = fe.submit(stream, batch) {
                            errs.guard().push(format!("stream {stream}: submit failed: {e:#}"));
                            expected.fetch_sub(spec.microbatches - i, Ordering::AcqRel);
                            return;
                        }
                    }
                })?,
        );
    }

    // Dispatch thread: the ONLY writer on the feed link. Pops in DRR
    // order, assigns the global seq, tags the frame with its stream.
    let dispatcher = {
        let fe = frontend.clone();
        let pending = pending.clone();
        let errs = errors.clone();
        let expected = expected.clone();
        let abort = abort.clone();
        let fed = fed.clone();
        let feed_done = feed_done.clone();
        std::thread::Builder::new().name("qp-serve-dispatch".into()).spawn(move || {
            let mut feed = feed;
            let mut seq = 0u64;
            let mut popped = 0u64;
            let mut failed = false;
            while popped < expected.load(Ordering::Acquire) {
                let Some((stream, batch)) = fe.pop(Duration::from_millis(100)) else {
                    continue;
                };
                popped += 1;
                if failed {
                    // Drain-and-discard: frees queue slots so blocked
                    // clients observe the abort instead of hanging.
                    continue;
                }
                pending.guard().insert(
                    seq,
                    Pending { stream, idx: batch.idx, labels: batch.labels, t0: batch.t0 },
                );
                let frame = Frame::for_stream(stream, seq, batch.shape, batch.enc);
                // First hard send error ends the feed (see run_coordinator);
                // resilient links only surface it once reconnects are spent.
                if let Err(e) = feed.send(frame) {
                    errs.guard().push(format!("serving coordinator: feed link failed: {e:#}"));
                    pending.guard().remove(&seq);
                    failed = true;
                    abort.store(true, Ordering::Release);
                    continue;
                }
                fed.fetch_add(1, Ordering::Release);
                seq += 1;
            }
            if !failed {
                if let Err(e) = feed.finish() {
                    errs.guard().push(format!("serving coordinator: feed drain failed: {e:#}"));
                }
            }
            feed_done.store(true, Ordering::Release);
        })?
    };

    // Sink: demux returning logits by the frame's stream tag, check
    // per-stream FIFO, and account latency from the client's offer time.
    let mut acc = AccuracyMeter::default();
    let mut latency = LatencyHisto::default();
    let mut codec = Codec::default();
    let mut pipeline = PipelineReport::new();
    let mut aggs: Vec<StreamAgg> = (0..n_streams)
        .map(|_| StreamAgg { frames: 0, next_idx: 0, latency: LatencyHisto::default() })
        .collect();
    let mut logits_pool: Vec<f32> = Vec::new();
    let mut done = 0u64;
    let mut images = 0u64;
    while done < total {
        if feed_done.load(Ordering::Acquire) && done >= fed.load(Ordering::Acquire) {
            break;
        }
        let step = ret.recv();
        for payload in ret.poll_telemetry() {
            pipeline.ingest(&payload);
        }
        match step {
            Ok(Some(frame)) => {
                let mut data = std::mem::take(&mut logits_pool);
                if let Err(e) = codec.decode(&frame.enc, &mut data) {
                    errors
                        .guard()
                        .push(format!("serving coordinator: logits decode failed: {e:#}"));
                    logits_pool = data;
                    continue;
                }
                let logits = Tensor::new(data, frame.shape.clone());
                if let Some(p) = pending.guard().remove(&frame.seq) {
                    if p.stream != frame.stream {
                        errors.guard().push(format!(
                            "stream demux violation: seq {} fed on stream {} returned tagged {}",
                            frame.seq, p.stream, frame.stream
                        ));
                    }
                    if let Some(agg) = aggs.get_mut(p.stream as usize) {
                        if p.idx != agg.next_idx {
                            errors.guard().push(format!(
                                "stream {} FIFO violation: completed idx {} while expecting {}",
                                p.stream, p.idx, agg.next_idx
                            ));
                        }
                        agg.next_idx = p.idx + 1;
                        agg.frames += 1;
                        let dt = p.t0.elapsed();
                        agg.latency.record(dt);
                        latency.record(dt);
                    }
                    images += p.labels.len() as u64;
                    acc.add(&logits, &p.labels);
                }
                done += 1;
                logits_pool = logits.into_data();
            }
            Ok(None) => break,
            Err(e) => {
                errors
                    .guard()
                    .push(format!("serving coordinator: return link failed: {e:#}"));
                break;
            }
        }
    }
    if done >= total {
        // Consume the return link's end-of-stream (FIN_ACK on resilient
        // links) — see run_coordinator for why skipping this strands the
        // last worker's drain and loses the final telemetry flush.
        while let Ok(Some(_)) = ret.recv() {}
    }
    for payload in ret.poll_telemetry() {
        pipeline.ingest(&payload);
    }
    for c in clients {
        let _ = c.join();
    }
    let _ = dispatcher.join();
    let wall = start.elapsed().as_secs_f64().max(1e-9);
    let errors = std::mem::take(&mut *errors.guard());

    // Per-stream rows: admission counters from the scheduler, frame
    // counts and completion percentiles from the sink-side accounting.
    let streams: Vec<StreamSummary> = frontend
        .stats()
        .iter()
        .map(|st| {
            let agg = &aggs[st.stream as usize];
            StreamSummary {
                stream: st.stream,
                weight: st.weight,
                frames: agg.frames,
                stalls: st.stalls,
                p50_latency_s: agg.latency.quantile(0.5).as_secs_f64(),
                p99_latency_s: agg.latency.quantile(0.99).as_secs_f64(),
            }
        })
        .collect();

    pipeline.coordinator = Some(CoordinatorSummary {
        images,
        microbatches: done,
        wall_secs: wall,
        throughput: images as f64 / wall,
        accuracy: acc.value(),
        p50_latency_s: latency.quantile(0.5).as_secs_f64(),
        p99_latency_s: latency.quantile(0.99).as_secs_f64(),
        streams,
        errors: errors.clone(),
    });

    Ok(CoordinatorReport {
        images,
        microbatches: done,
        wall_secs: wall,
        throughput: images as f64 / wall,
        accuracy: acc.value(),
        latency,
        errors,
        resilience: ResilienceSummary::collect(&resilience_handles),
        stripes: StripeSummary::collect(&stripe_handles),
        pipeline,
    })
}
