//! Multi-process deployment endpoints (the paper's actual testbed shape:
//! stages on separate processes/devices, the coordinator feeding images
//! and collecting logits).
//!
//! A **worker** owns exactly one stage. It decodes frames from its
//! upstream transport, runs the shard, re-encodes at the bitwidth its own
//! adaptive controller currently publishes, and ships downstream through
//! a sender thread — the same [`sender_thread`] the in-process driver
//! uses, so the WindowMonitor/AdaptivePda loop is byte-for-byte the same
//! code over TCP. In TCP mode the bandwidth signal is measured
//! write-stall time under real socket backpressure; no `SimLink` exists
//! anywhere in the process.
//!
//! The **coordinator** is source + sink: it streams raw-f32 frames into
//! stage 0 and scores the logits frames returning from the last stage.
//! TCP's own flow control is the in-flight bound between processes.
//!
//! Wiring (CLI: `quantpipe worker` / `quantpipe coordinate`):
//!
//! ```text
//! coordinator ──connect──▶ worker 0 ──connect──▶ … ──▶ worker n-1
//!      ▲                                                   │
//!      └────────────── sink listener ◀──────connect────────┘
//! ```

use crate::adapt::AdaptConfig;
use crate::data::AccuracyMeter;
use crate::metrics::{LatencyHisto, Timeline};
use crate::net::frame::Frame;
use crate::net::transport::{FrameRx, FrameTx};
use crate::pipeline::driver::{
    encode_at_current_bits, sender_thread, LinkCounters, LinkQuant, Workload,
};
use crate::pipeline::stage::StageFactory;
use crate::quant::codec::Codec;
use crate::quant::{Method, QuantParams, BITS_NONE};
use crate::tensor::Tensor;
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::AtomicU8;
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One worker's role in the pipeline.
#[derive(Debug, Clone, Copy)]
pub struct WorkerConfig {
    /// Stage index (for logs/timeline labelling).
    pub stage: usize,
    /// Output-link quantization behaviour.
    pub quant: LinkQuant,
    /// Adaptive controller for the output link; `None` pins
    /// `quant.initial_bits`. Ignored when `quantize_output` is false.
    pub adapt: Option<AdaptConfig>,
    /// Monitor window in microbatches.
    pub window: u64,
    /// Images per microbatch (the monitor's rate track).
    pub microbatch: usize,
    /// Quantize the output link. The last stage sets this false: logits
    /// return to the coordinator raw.
    pub quantize_output: bool,
    /// Frames buffered between compute and the transport writer.
    pub inflight: usize,
}

/// What a worker measured over its lifetime.
#[derive(Debug)]
pub struct WorkerReport {
    /// Microbatches processed.
    pub frames: u64,
    /// Window-by-window monitor/controller track for the output link.
    pub timeline: Timeline,
    /// Mean compute seconds per microbatch.
    pub mean_compute_s: f64,
    /// Mean wire bytes per frame on the output link.
    pub out_mean_bytes: f64,
    /// Transport failures observed (empty on a clean run).
    pub errors: Vec<String>,
}

/// Run one stage over arbitrary transports until the upstream closes.
/// Blocking; the calling thread is the stage's compute thread (PJRT is
/// thread-pinned), a spawned sender thread owns the output transport.
pub fn run_worker(
    factory: StageFactory,
    cfg: WorkerConfig,
    rx: Box<dyn FrameRx>,
    tx: Box<dyn FrameTx>,
) -> Result<WorkerReport> {
    let start = Instant::now();
    let initial_bits = if cfg.quantize_output { cfg.quant.initial_bits } else { BITS_NONE };
    let bits = Arc::new(AtomicU8::new(initial_bits));
    let timeline = Arc::new(Mutex::new(Timeline::default()));
    let counters = Arc::new(LinkCounters::default());
    let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let (frame_tx, frame_rx) = sync_channel::<Frame>(cfg.inflight.max(1));

    let sender = {
        let adapt = if cfg.quantize_output { cfg.adapt } else { None };
        let bits = bits.clone();
        let tl = timeline.clone();
        let counters = counters.clone();
        let errs = errors.clone();
        let (stage, window, batch) = (cfg.stage, cfg.window, cfg.microbatch);
        std::thread::Builder::new()
            .name(format!("qp-worker-send-{stage}"))
            .spawn(move || {
                sender_thread(
                    stage, frame_rx, tx, window, batch, adapt, initial_bits,
                    bits, tl, counters, errs, start,
                )
            })?
    };

    let (loop_result, frames, compute_secs) = worker_stage_loop(cfg, rx, frame_tx, bits, factory);
    // frame_tx was moved into the loop and is dropped by now, so the
    // sender drains its channel and exits.
    let _ = sender.join();

    let mut errors = std::mem::take(&mut *errors.lock().unwrap());
    if let Err(e) = loop_result {
        // Keep the progress counters: "stopped with an error after frame
        // 500" is what lets an operator correlate the shortfall.
        errors.push(format!("worker stage {}: {e:#}", cfg.stage));
    }

    Ok(WorkerReport {
        frames,
        timeline: take_timeline(timeline),
        mean_compute_s: if frames > 0 { compute_secs / frames as f64 } else { 0.0 },
        out_mean_bytes: counters.mean_frame_bytes(),
        errors,
    })
}

fn take_timeline(timeline: Arc<Mutex<Timeline>>) -> Timeline {
    Arc::try_unwrap(timeline)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default()
}

/// Returns the loop outcome WITH the progress counters — a failure after
/// frame 500 still reports 500 frames of progress.
fn worker_stage_loop(
    cfg: WorkerConfig,
    mut rx: Box<dyn FrameRx>,
    frame_tx: SyncSender<Frame>,
    bits: Arc<AtomicU8>,
    factory: StageFactory,
) -> (Result<()>, u64, f64) {
    let mut frames = 0u64;
    let mut compute_secs = 0f64;
    let result = (|| -> Result<()> {
        let bundle = factory()?;
        let mut compute = bundle.compute;
        let mut codec = Codec::new(bundle.quant_backend);
        let mut decode_buf: Vec<f32> = Vec::new();
        let mut cached: Option<QuantParams> = None;
        let mut since_calib: u32 = 0;

        loop {
            let frame = match rx.recv() {
                Ok(Some(f)) => f,
                Ok(None) => return Ok(()), // clean upstream shutdown
                Err(e) => return Err(e.context("upstream link failed")),
            };
            codec.decode(&frame.enc, &mut decode_buf)?;
            let Frame { seq, shape, enc } = frame;
            codec.recycle(enc);
            let tensor = Tensor::new(decode_buf.clone(), shape);

            let t0 = Instant::now();
            let out = compute.run(&tensor)?;
            compute_secs += t0.elapsed().as_secs_f64();

            let enc = encode_at_current_bits(
                &mut codec, &out.data, &cfg.quant, &bits, &mut cached, &mut since_calib,
            )?;
            if frame_tx.send(Frame::new(seq, out.shape.clone(), enc)).is_err() {
                // Sender died (downstream link failure, already recorded).
                return Ok(());
            }
            frames += 1;
        }
    })();
    (result, frames, compute_secs)
}

// -----------------------------------------------------------------------------
// Coordinator: source + sink over real transports
// -----------------------------------------------------------------------------

/// What the coordinator measured end-to-end.
#[derive(Debug)]
pub struct CoordinatorReport {
    pub images: u64,
    pub microbatches: u64,
    pub wall_secs: f64,
    /// End-to-end images/sec.
    pub throughput: f64,
    /// Top-1 accuracy over all returned microbatches.
    pub accuracy: f64,
    /// End-to-end microbatch latency (feed → logits return).
    pub latency: LatencyHisto,
    /// Transport failures observed (empty on a clean run).
    pub errors: Vec<String>,
}

/// Feed the workload into stage 0 (`feed`) and score logits returning
/// from the last stage (`ret`). Blocking; a spawned thread feeds while
/// the calling thread sinks, so TCP flow control — not lockstep — paces
/// the pipeline.
pub fn run_coordinator(
    workload: Workload,
    feed: Box<dyn FrameTx>,
    mut ret: Box<dyn FrameRx>,
) -> Result<CoordinatorReport> {
    let start = Instant::now();
    let label_map: Arc<Mutex<HashMap<u64, Vec<u32>>>> = Arc::new(Mutex::new(HashMap::new()));
    let send_times: Arc<Mutex<HashMap<u64, Instant>>> = Arc::new(Mutex::new(HashMap::new()));
    let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));

    let feeder = {
        let eval = workload.eval.clone();
        let s = workload.microbatch;
        let total = workload.total;
        let labels = label_map.clone();
        let times = send_times.clone();
        let errs = errors.clone();
        std::thread::Builder::new()
            .name("qp-coord-feed".into())
            .spawn(move || {
                let mut feed = feed;
                let mut codec = Codec::default();
                let per_pass = eval.microbatches(s).max(1);
                for seq in 0..total {
                    let i = (seq as usize) % per_pass;
                    let tensor = eval.microbatch(i, s);
                    labels.lock().unwrap().insert(seq, eval.labels_for(i, s).to_vec());
                    times.lock().unwrap().insert(seq, Instant::now());
                    let enc = match codec.encode(&tensor.data, Method::Pda, BITS_NONE) {
                        Ok(e) => e,
                        Err(e) => {
                            errs.lock().unwrap().push(format!("coordinator: encode failed: {e:#}"));
                            break;
                        }
                    };
                    if let Err(e) = feed.send(Frame::new(seq, tensor.shape.clone(), enc)) {
                        errs.lock().unwrap().push(format!("coordinator: feed link failed: {e:#}"));
                        break;
                    }
                }
                // `feed` drops here; on TCP that half-closes the socket and
                // stage 0 sees a clean EOF after draining.
            })?
    };

    let mut acc = AccuracyMeter::default();
    let mut latency = LatencyHisto::default();
    let mut codec = Codec::default();
    let mut logits_buf: Vec<f32> = Vec::new();
    let mut done = 0u64;
    let mut images = 0u64;
    while done < workload.total {
        match ret.recv() {
            Ok(Some(frame)) => {
                if let Err(e) = codec.decode(&frame.enc, &mut logits_buf) {
                    errors
                        .lock()
                        .unwrap()
                        .push(format!("coordinator: logits decode failed: {e:#}"));
                    continue;
                }
                let logits = Tensor::new(logits_buf.clone(), frame.shape.clone());
                if let Some(labels) = label_map.lock().unwrap().remove(&frame.seq) {
                    images += labels.len() as u64;
                    acc.add(&logits, &labels);
                }
                if let Some(t0) = send_times.lock().unwrap().remove(&frame.seq) {
                    latency.record(t0.elapsed());
                }
                done += 1;
            }
            Ok(None) => break, // pipeline closed early
            Err(e) => {
                errors
                    .lock()
                    .unwrap()
                    .push(format!("coordinator: return link failed: {e:#}"));
                break;
            }
        }
    }
    let _ = feeder.join();
    let wall = start.elapsed().as_secs_f64().max(1e-9);
    let errors = std::mem::take(&mut *errors.lock().unwrap());

    Ok(CoordinatorReport {
        images,
        microbatches: done,
        wall_secs: wall,
        throughput: images as f64 / wall,
        accuracy: acc.value(),
        latency,
        errors,
    })
}
