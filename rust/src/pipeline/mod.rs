//! Distributed pipeline runtime (the paper's Fig 2 system).
//!
//! * [`stage`] — stage compute (AOT HLO shard via PJRT, or mocks) and the
//!   per-thread construction discipline PJRT requires.
//! * [`driver`] — the event loop: source → stage threads → transports
//!   ([`crate::net::transport::LinkSpec`]: shaped in-proc channels or real
//!   TCP sockets) with monitors + adaptive PDA controllers → sink;
//!   produces a [`driver::RunReport`] with the Fig 5 timeline, accuracy,
//!   throughput and latency.
//! * [`remote`] — multi-process endpoints: [`remote::run_worker`] runs one
//!   stage over arbitrary transports, [`remote::run_coordinator`] is the
//!   source+sink process (CLI: `quantpipe worker` / `quantpipe coordinate`).
//! * [`serve`] — the multi-stream serving plane: weighted round-robin
//!   admission over bounded per-stream ingress queues, per-stream
//!   backpressure and a fairness guard; [`remote::run_serving_coordinator`]
//!   interleaves N client sessions through the one stage chain.

pub mod driver;
pub mod remote;
pub mod serve;
pub mod stage;

pub use crate::net::transport::LinkSpec;
pub use driver::{run, LinkCounters, LinkQuant, PipelineSpec, RunReport, Workload};
pub use remote::{
    run_coordinator, run_serving_coordinator, run_worker, CoordinatorReport, ServeWorkload,
    StreamSpec, WorkerConfig, WorkerReport,
};
pub use serve::{Admission, ServeConfig, ServeFrontend, ServeScheduler, StreamStats};
pub use stage::{hlo_stage_factory, mock_stage_factory, StageBundle, StageCompute, StageFactory};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::AdaptConfig;
    use crate::data::EvalSet;
    use crate::net::link::SimLink;
    use crate::net::trace::BandwidthTrace;
    use crate::quant::Method;
    use std::sync::Arc;
    use std::time::Duration;

    /// Tiny synthetic eval set: one-hot "images" so passthrough logits'
    /// argmax equals the label exactly.
    fn tiny_eval(count: usize, classes: usize) -> Arc<EvalSet> {
        Arc::new(EvalSet::synthetic_onehot(count, classes))
    }

    fn spec_with_links(
        n_stages: usize,
        classes: usize,
        s: usize,
        trace: BandwidthTrace,
        quant: LinkQuant,
        adapt: Option<AdaptConfig>,
        window: u64,
    ) -> PipelineSpec {
        let stages = (0..n_stages)
            .map(|_| mock_stage_factory(1.0, 0.0, vec![s, classes], Duration::ZERO))
            .collect();
        let links = (0..n_stages - 1)
            .map(|_| LinkSpec::Sim(Arc::new(SimLink::new(trace.clone()))))
            .collect();
        PipelineSpec { stages, links, quant, adapt, window, inflight: 2 }
    }

    #[test]
    fn two_stage_passthrough_accuracy() {
        let eval = tiny_eval(64, 4);
        let spec = spec_with_links(2, 4, 8, BandwidthTrace::unlimited(), LinkQuant::default(), None, 4);
        let report = run(spec, Workload::one_pass(eval, 8)).unwrap();
        assert_eq!(report.microbatches, 8);
        assert_eq!(report.images, 64);
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        // Passthrough at 32-bit: logits == one-hot images, so accuracy = 1.
        assert!((report.accuracy - 1.0).abs() < 1e-12, "{report:?}");
    }

    #[test]
    fn quantized_passthrough_still_classifies() {
        // 8-bit ACIQ quantization of one-hot rows keeps argmax intact.
        let eval = tiny_eval(64, 4);
        let quant = LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() };
        let spec = spec_with_links(3, 4, 8, BandwidthTrace::unlimited(), quant, None, 4);
        let report = run(spec, Workload::one_pass(eval, 8)).unwrap();
        assert!((report.accuracy - 1.0).abs() < 1e-12, "{report:?}");
        // Wire volume reflects 8-bit compression (payload 32 B + header).
        assert!(report.link0_mean_bytes < 8.0 * 4.0 * 4.0, "{report:?}");
    }

    #[test]
    fn single_stage_no_links() {
        let eval = tiny_eval(16, 4);
        let stages = vec![mock_stage_factory(1.0, 0.0, vec![4, 4], Duration::ZERO)];
        let spec = PipelineSpec {
            stages,
            links: vec![],
            quant: LinkQuant::default(),
            adapt: None,
            window: 2,
            inflight: 2,
        };
        let report = run(spec, Workload::one_pass(eval, 4)).unwrap();
        assert_eq!(report.microbatches, 4);
        assert!((report.accuracy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn adaptive_controller_reduces_bits_under_constraint() {
        // Frame at 32-bit ≈ 128 B payload + ~44 B header ≈ 1.4 kbit.
        // Target 800 img/s with S=8 ⇒ 10 ms budget ⇒ need ≥140 kbps for
        // 32-bit; give the link 60 kbps so the controller must compress.
        let eval = tiny_eval(160, 4);
        let trace = BandwidthTrace::constant(60e3);
        let quant = LinkQuant { method: Method::Aciq, initial_bits: 32, ..Default::default() };
        let adapt = AdaptConfig {
            target_rate: 800.0,
            microbatch: 8,
            policy: crate::adapt::Policy::Ladder,
            raise_margin: 1.0,
        };
        let spec = spec_with_links(2, 4, 8, trace, quant, Some(adapt), 5);
        let report = run(spec, Workload::repeat(eval, 8, 40)).unwrap();
        let final_bits = report.timeline.final_bits(0).expect("windows must complete");
        assert!(final_bits < 32, "controller should have compressed: {report:?}");
        assert_eq!(report.microbatches, 40);
    }

    #[test]
    fn throughput_tracks_bandwidth() {
        // Comm-bound two-stage pipeline: throughput ≈ capacity / frame bits.
        let eval = tiny_eval(64, 4);
        let s = 8usize;
        let trace = BandwidthTrace::constant(100e3); // 100 kbps
        let quant = LinkQuant { method: Method::Aciq, initial_bits: 32, ..Default::default() };
        let spec = spec_with_links(2, 4, s, trace, quant, None, 4);
        let report = run(spec, Workload::repeat(eval, s, 20)).unwrap();
        // Frame ≈ 128 B payload + 44 B header = 1376 bits ⇒ ~72 fps ⇒
        // ~580 img/s. Allow generous slack for pipeline fill + timers.
        assert!(
            report.throughput > 300.0 && report.throughput < 800.0,
            "{}",
            report.throughput
        );
    }

    #[test]
    fn latency_recorded_per_microbatch() {
        let eval = tiny_eval(32, 4);
        let spec = spec_with_links(
            2, 4, 8,
            BandwidthTrace::constant(1e6),
            LinkQuant::default(),
            None,
            4,
        );
        let report = run(spec, Workload::one_pass(eval, 8)).unwrap();
        assert_eq!(report.latency.count(), 4);
        assert!(report.latency.mean() > Duration::from_micros(100));
        assert_eq!(report.stage_compute_s.len(), 2);
    }

    #[test]
    fn mock_compute_time_measured() {
        let eval = tiny_eval(16, 4);
        let stages = vec![
            mock_stage_factory(1.0, 0.0, vec![4, 4], Duration::from_millis(5)),
            mock_stage_factory(1.0, 0.0, vec![4, 4], Duration::from_millis(1)),
        ];
        let spec = PipelineSpec {
            stages,
            links: vec![LinkSpec::unlimited()],
            quant: LinkQuant::default(),
            adapt: None,
            window: 2,
            inflight: 2,
        };
        let report = run(spec, Workload::one_pass(eval, 4)).unwrap();
        assert!(report.stage_compute_s[0] > report.stage_compute_s[1]);
        assert!(report.stage_compute_s[0] >= 0.004, "{:?}", report.stage_compute_s);
    }

    #[test]
    fn run_report_json_is_parseable() {
        // Including the infinite-bandwidth windows an unconstrained link
        // produces: the JSON must stay valid (non-finite → null/omitted).
        let eval = tiny_eval(64, 4);
        let quant = LinkQuant { method: Method::Aciq, initial_bits: 8, ..Default::default() };
        let spec = spec_with_links(2, 4, 8, BandwidthTrace::unlimited(), quant, None, 2);
        let report = run(spec, Workload::one_pass(eval, 8)).unwrap();
        let text = report.to_json().to_string_pretty();
        let back = crate::util::json::Value::parse(&text).unwrap();
        assert_eq!(back.at("microbatches").unwrap().as_u64().unwrap(), 8);
        assert!(back.at("timeline").unwrap().as_arr().is_ok());
    }
}
