//! Stage compute abstraction.
//!
//! A stage is whatever turns an input activation into an output activation
//! — in production the AOT-compiled ViT shard run through PJRT, in tests a
//! mock. `PjRtClient` is thread-pinned (Rc), so stages are built *inside*
//! their owning thread by a `Send` factory.

use crate::runtime::{Engine, Executable, HloQuantBackend, Manifest};
use crate::quant::codec::{NativeBackend, QuantBackend};
use crate::tensor::Tensor;
use crate::Result;
use std::path::PathBuf;
use std::time::Duration;

/// Stage compute: input activation → output activation.
pub trait StageCompute {
    /// Run the stage on one activation.
    fn run(&mut self, input: &Tensor) -> Result<Tensor>;
    /// Output activation shape.
    fn out_shape(&self) -> &[usize];
}

/// Everything a stage thread owns: the shard and its codec arithmetic.
pub struct StageBundle {
    /// The stage's compute (PJRT shard or mock).
    pub compute: Box<dyn StageCompute>,
    /// Quantization arithmetic for this stage's codec.
    pub quant_backend: Box<dyn QuantBackend>,
}

/// Runs once inside the stage's thread to construct its bundle.
pub type StageFactory = Box<dyn FnOnce() -> Result<StageBundle> + Send>;

// ---------------------------------------------------------------------------
// Real stage: AOT HLO shard via PJRT
// ---------------------------------------------------------------------------

/// A compiled model shard.
pub struct HloStage {
    exe: Executable,
    out_shape: Vec<usize>,
}

impl StageCompute for HloStage {
    fn run(&mut self, input: &Tensor) -> Result<Tensor> {
        self.exe.run_f32(&[input], &self.out_shape)
    }

    fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }
}

/// Factory for stage `idx` of the manifest; `hlo_codec` selects the AOT
/// Pallas kernel (vs native rust) for quantize/dequantize.
pub fn hlo_stage_factory(
    dir: PathBuf,
    manifest: Manifest,
    idx: usize,
    hlo_codec: bool,
) -> StageFactory {
    Box::new(move || {
        let engine = Engine::cpu()?;
        let meta = &manifest.stages[idx];
        let exe = engine.load_hlo(dir.join(&meta.file))?;
        let quant_backend: Box<dyn QuantBackend> = if hlo_codec {
            Box::new(HloQuantBackend::load(&engine, &dir, &manifest)?)
        } else {
            Box::new(NativeBackend)
        };
        Ok(StageBundle {
            compute: Box::new(HloStage { exe, out_shape: meta.out_shape.clone() }),
            quant_backend,
        })
    })
}

// ---------------------------------------------------------------------------
// Mock stage (tests / net-only benches)
// ---------------------------------------------------------------------------

/// Deterministic mock: y = a·x + b elementwise (reshaped to `out_shape`,
/// truncating/cycling data), with optional busy-sleep to model compute.
pub struct MockStage {
    /// Multiplier.
    pub a: f32,
    /// Offset.
    pub b: f32,
    /// Output shape (input data reshaped/cycled).
    pub out_shape: Vec<usize>,
    /// Busy-sleep per microbatch modeling compute.
    pub compute: Duration,
}

impl MockStage {
    /// Identity mock with the given output shape.
    pub fn passthrough(out_shape: Vec<usize>) -> Self {
        MockStage { a: 1.0, b: 0.0, out_shape, compute: Duration::ZERO }
    }
}

impl StageCompute for MockStage {
    fn run(&mut self, input: &Tensor) -> Result<Tensor> {
        if !self.compute.is_zero() {
            std::thread::sleep(self.compute);
        }
        let n: usize = self.out_shape.iter().product();
        let data = (0..n)
            .map(|i| self.a * input.data[i % input.data.len().max(1)] + self.b)
            .collect();
        Ok(Tensor::new(data, self.out_shape.clone()))
    }

    fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }
}

/// Factory for a mock stage with a native codec backend.
pub fn mock_stage_factory(a: f32, b: f32, out_shape: Vec<usize>, compute: Duration) -> StageFactory {
    Box::new(move || {
        Ok(StageBundle {
            compute: Box::new(MockStage { a, b, out_shape, compute }),
            quant_backend: Box::new(NativeBackend),
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_stage_transform() {
        let mut s = MockStage { a: 2.0, b: 1.0, out_shape: vec![2, 2], compute: Duration::ZERO };
        let out = s.run(&Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2])).unwrap();
        assert_eq!(out.data, vec![3.0, 5.0, 7.0, 9.0]);
    }

    #[test]
    fn mock_stage_reshapes() {
        let mut s = MockStage::passthrough(vec![6]);
        let out = s.run(&Tensor::new(vec![1.0, 2.0, 3.0], vec![3])).unwrap();
        assert_eq!(out.data, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn factory_is_send() {
        fn assert_send<T: Send>(_: &T) {}
        let f = mock_stage_factory(1.0, 0.0, vec![4], Duration::ZERO);
        assert_send(&f);
    }
}
