//! The pipeline driver: wires stage threads, transports, monitors and
//! the adaptive controller into a running system (paper Fig 2).
//!
//! Topology for n stages:
//!
//! ```text
//! source thread ─sync_channel─▶ [stage0 thread] ─▶ {sender thread 0:
//!   FrameTx transport, WindowMonitor, AdaptivePda} ─▶ [stage1 thread]
//!   ─▶ … ─▶ [stage n-1 thread] ─sync_channel─▶ sink (caller thread)
//! ```
//!
//! * Stage threads own the PJRT engine (thread-pinned), the shard
//!   executable and the codec; they decode incoming frames, run the shard,
//!   then calibrate + encode outgoing frames at the bitwidth currently
//!   published by their link's controller (an `AtomicU8` — the paper's
//!   control/data split inside the adaptive PDA module).
//! * Sender threads ship frames through a [`FrameTx`] transport — a shaped
//!   `SimLink` channel or a real TCP socket ([`LinkSpec`]) — feed the
//!   [`WindowMonitor`] with the measured busy time (serialization delay
//!   in-proc, write-stall under socket backpressure on TCP), and run the
//!   Eq. 2 controller at window boundaries. The control loop is identical
//!   over either transport.
//! * Labels bypass the pipeline (eval-only) and join at the sink.
//! * Bounded `sync_channel`s give GPipe-style in-flight caps (TCP mode
//!   additionally rides the kernel's socket buffers).
//!
//! Transport failures (a TCP stream truncated mid-frame, a socket error)
//! surface in [`RunReport::errors`] instead of silently ending the run.

use crate::adapt::{AdaptConfig, AdaptivePda};
use crate::data::{AccuracyMeter, EvalSet};
use crate::metrics::telemetry::{StageSnapshot, TelemetryRelay};
use crate::metrics::{
    LatencyHisto, ResilienceStats, ResilienceSummary, StripeStats, StripeSummary, Timeline,
    TimelinePoint,
};
use crate::monitor::WindowMonitor;
use crate::net::frame::Frame;
use crate::net::transport::{FrameRx, FrameTx, LinkSpec, PreparedFrame};
use crate::pipeline::stage::StageFactory;
use crate::quant::codec::Codec;
use crate::quant::tile::{TileCodec, TileConfig};
use crate::quant::{calibrate, Method, QuantParams, BITS_NONE};
use crate::tensor::Tensor;
use crate::util::json::Value;
use crate::util::sync::TrackedMutex;
use crate::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

/// Quantization behaviour of the links.
#[derive(Debug, Clone, Copy)]
pub struct LinkQuant {
    /// Calibration method for quantized links.
    pub method: Method,
    /// Recalibrate every N microbatches (params reused in between).
    pub calib_every: u32,
    /// Initial bitwidth (the controller may change it at any window).
    pub initial_bits: u8,
    /// Worker threads for large fused encodes (`pipeline.codec_threads`
    /// in the config). 1 = serial; >1 chunks big boundary activations
    /// across scoped threads with byte-identical output.
    pub codec_threads: usize,
    /// Elements per quantization tile (`pipeline.tile_elems`). 0 = flat
    /// (one scale per tensor, today's wire format); > 0 switches
    /// sub-byte-width frames to tiled payloads (`quant::tile`): per-tile
    /// scales, the outlier side-channel, and — under the adaptive
    /// controller's `Policy::Budget` — non-uniform per-tile widths.
    pub tile_elems: usize,
    /// Fraction of elements shipped raw in the tiled outlier
    /// side-channel (`pipeline.outlier_frac`); only meaningful when
    /// `tile_elems > 0`.
    pub outlier_frac: f64,
}

impl Default for LinkQuant {
    fn default() -> Self {
        LinkQuant {
            method: Method::Pda,
            calib_every: 1,
            initial_bits: BITS_NONE,
            codec_threads: 1,
            tile_elems: 0,
            outlier_frac: 0.01,
        }
    }
}

impl LinkQuant {
    /// The tiled encoder these settings call for (`None` = flat).
    pub(crate) fn tile_codec(&self) -> Option<TileCodec> {
        (self.tile_elems > 0).then(|| {
            let cfg = TileConfig { tile_elems: self.tile_elems, outlier_frac: self.outlier_frac };
            let mut tc = TileCodec::new(cfg, self.method);
            tc.set_calib_every(self.calib_every.max(1));
            tc
        })
    }
}

/// Full pipeline specification.
pub struct PipelineSpec {
    /// Stage factories, in pipeline order.
    pub stages: Vec<StageFactory>,
    /// One transport per stage boundary (len = stages - 1): a shaped
    /// in-process channel or a pre-connected real TCP socket.
    pub links: Vec<LinkSpec>,
    /// Quantization behaviour shared by all links.
    pub quant: LinkQuant,
    /// Adaptive controller config; `None` pins `quant.initial_bits`.
    pub adapt: Option<AdaptConfig>,
    /// Monitor window in microbatches (paper: 50).
    pub window: u64,
    /// In-flight frames per channel (backpressure bound).
    pub inflight: usize,
}

/// Per-link wire counters fed by the sender thread. Transport-agnostic
/// replacement for reading `SimLink`'s internal counters (a TCP link has
/// no `SimLink`).
#[derive(Debug, Default)]
pub struct LinkCounters {
    /// Wire bytes shipped.
    pub bytes: AtomicU64,
    /// Frames shipped.
    pub frames: AtomicU64,
}

/// Counters a worker's stage loop updates and its sender thread's
/// telemetry tap snapshots: the two run on different threads, so the
/// handoff is lock-free atomics (each value is advisory — telemetry, not
/// accounting).
#[derive(Debug, Default)]
pub(crate) struct StageTelemetryShared {
    /// Microbatches the stage loop has processed.
    pub frames: AtomicU64,
    /// Cumulative stage-compute nanoseconds.
    pub compute_ns: AtomicU64,
    /// Cumulative quantize+encode nanoseconds.
    pub encode_ns: AtomicU64,
    /// Cumulative decode+dequantize nanoseconds.
    pub decode_ns: AtomicU64,
    /// Frames handed to the compute→sender channel.
    pub enqueued: AtomicU64,
    /// Frames the sender thread has taken off that channel.
    pub dequeued: AtomicU64,
}

/// The sender thread's telemetry emitter: accumulates this stage's window
/// points and seq range, snapshots the shared counters, and ships
/// [`StageSnapshot`] records forward along the data path — plus whatever
/// upstream snapshots the stage loop has relayed into `relay`. All sends
/// are best effort ([`FrameTx::send_telemetry`]); the merge downstream
/// tolerates loss.
pub(crate) struct TelemetryTap {
    stage: usize,
    /// Emit this stage's own snapshots. When false the tap still relays
    /// upstream stages' records — a worker with telemetry off is a hole
    /// in the report, not a blackhole for everyone above it.
    emit: bool,
    shared: Arc<StageTelemetryShared>,
    relay: Arc<TrackedMutex<TelemetryRelay>>,
    resilience: Vec<Arc<ResilienceStats>>,
    stripes: Vec<Arc<StripeStats>>,
    errors: Arc<TrackedMutex<Vec<String>>>,
    snap: u64,
    points: Vec<TimelinePoint>,
    seq_lo: u64,
    seq_hi: u64,
}

impl TelemetryTap {
    pub(crate) fn new(
        stage: usize,
        emit: bool,
        shared: Arc<StageTelemetryShared>,
        relay: Arc<TrackedMutex<TelemetryRelay>>,
        resilience: Vec<Arc<ResilienceStats>>,
        stripes: Vec<Arc<StripeStats>>,
        errors: Arc<TrackedMutex<Vec<String>>>,
    ) -> Self {
        TelemetryTap {
            stage,
            emit,
            shared,
            relay,
            resilience,
            stripes,
            errors,
            snap: 0,
            points: Vec::new(),
            seq_lo: u64::MAX,
            seq_hi: 0,
        }
    }

    fn note_seq(&mut self, seq: u64) {
        self.seq_lo = self.seq_lo.min(seq);
        self.seq_hi = self.seq_hi.max(seq + 1);
    }

    fn push_point(&mut self, p: TimelinePoint) {
        self.points.push(p);
    }

    /// Forward upstream snapshots the stage loop relayed (FIFO, deduped
    /// at the relay).
    fn forward_relayed(&mut self, tx: &mut dyn FrameTx) {
        let queued = self.relay.guard().drain();
        for payload in queued {
            let _ = tx.send_telemetry(&payload);
        }
    }

    /// Emit one snapshot of this stage's state. `last` marks the final
    /// flush (the sender has drained). No-op when this stage's own
    /// emission is disabled (accumulated points are dropped so they
    /// don't pile up over a long run).
    fn flush(&mut self, tx: &mut dyn FrameTx, last: bool) {
        if !self.emit {
            self.points.clear();
            self.seq_lo = u64::MAX;
            return;
        }
        let snapshot = StageSnapshot {
            stage: self.stage as u32,
            snap: self.snap,
            last,
            frames: self.shared.frames.load(Ordering::Relaxed),
            seq_lo: self.seq_lo,
            seq_hi: self.seq_hi,
            compute_ns: self.shared.compute_ns.load(Ordering::Relaxed),
            encode_ns: self.shared.encode_ns.load(Ordering::Relaxed),
            decode_ns: self.shared.decode_ns.load(Ordering::Relaxed),
            queue_depth: self
                .shared
                .enqueued
                .load(Ordering::Relaxed)
                .saturating_sub(self.shared.dequeued.load(Ordering::Relaxed))
                as u32,
            resilience: ResilienceSummary::collect(&self.resilience),
            stripes: StripeSummary::collect(&self.stripes),
            points: std::mem::take(&mut self.points),
            errors: self.errors.guard().clone(),
        };
        self.snap += 1;
        self.seq_lo = u64::MAX;
        let _ = tx.send_telemetry(&snapshot.to_bytes());
    }

    /// The drain-time flush: relay leftovers, then this stage's final
    /// snapshot — both ahead of the FIN the caller is about to send, so
    /// the records reach the coordinator before the stream closes.
    fn final_flush(&mut self, tx: &mut dyn FrameTx) {
        self.forward_relayed(tx);
        self.flush(tx, true);
    }
}

impl LinkCounters {
    /// Mean wire bytes per frame (0 before any send).
    pub fn mean_frame_bytes(&self) -> f64 {
        let frames = self.frames.load(Ordering::Relaxed);
        if frames == 0 {
            0.0
        } else {
            self.bytes.load(Ordering::Relaxed) as f64 / frames as f64
        }
    }
}

/// Shared pool of spare wire buffers circulating between a stage loop
/// (which serializes outgoing frames into them) and its sender thread
/// (which reclaims them from the transport once the bytes are written or
/// acked). Closes the copy-free loop: in steady state the same handful of
/// `Vec<u8>`s cycle codec → channel → transport → pool → codec, with zero
/// payload copies after the single serialization. Bounded so a burst
/// can't hoard memory forever.
pub(crate) struct WirePool {
    bufs: TrackedMutex<Vec<Vec<u8>>>,
}

/// Spare buffers kept per boundary; beyond this, returns are dropped.
const WIRE_POOL_CAP: usize = 8;

impl WirePool {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(WirePool { bufs: TrackedMutex::new("driver.wire_pool", Vec::new()) })
    }

    /// A spare buffer, or a fresh one when the pool is dry.
    pub(crate) fn take(&self) -> Vec<u8> {
        self.bufs.guard().pop().unwrap_or_default()
    }

    /// Return a buffer for reuse (dropped when the pool is full).
    pub(crate) fn put(&self, mut buf: Vec<u8>) {
        let mut bufs = self.bufs.guard();
        if bufs.len() < WIRE_POOL_CAP {
            buf.clear();
            bufs.push(buf);
        }
    }
}

struct SourceMsg {
    seq: u64,
    tensor: Tensor,
}

struct SinkMsg {
    seq: u64,
    logits: Tensor,
}

enum StageIn {
    Source(Receiver<SourceMsg>),
    Upstream(Box<dyn FrameRx>),
}

enum StageOut {
    Downstream {
        frame_tx: SyncSender<PreparedFrame>,
        bits: Arc<AtomicU8>,
        /// Budget-mode average bits, fixed-point ×256 (0 = uniform).
        /// Published by the sender thread beside `bits`; the two are
        /// separate relaxed atomics, so an encode may briefly pair a new
        /// width with the previous budget — both are advisory and the
        /// tile allocator clamps independently, so a torn pair costs one
        /// slightly-off microbatch, never correctness.
        avg_fp: Arc<AtomicU32>,
        quant: LinkQuant,
        pool: Arc<WirePool>,
    },
    Sink(SyncSender<SinkMsg>),
}

/// Results of a pipeline run.
#[derive(Debug)]
pub struct RunReport {
    /// Images scored at the sink.
    pub images: u64,
    /// Microbatches completed.
    pub microbatches: u64,
    /// Wall-clock run seconds.
    pub wall_secs: f64,
    /// End-to-end images/sec.
    pub throughput: f64,
    /// Top-1 accuracy over all processed microbatches.
    pub accuracy: f64,
    /// Per-window (t_secs, accuracy) samples — the Fig 5 accuracy track.
    pub window_accuracy: Vec<(f64, f64)>,
    /// Bandwidth/bitwidth/rate timeline per link — the Fig 5 tracks.
    pub timeline: Timeline,
    /// End-to-end microbatch latency.
    pub latency: LatencyHisto,
    /// Mean wire bytes per microbatch on link 0 (compression evidence).
    pub link0_mean_bytes: f64,
    /// Per-stage mean compute seconds (profiling/partitioning input).
    pub stage_compute_s: Vec<f64>,
    /// Transport/stage failures observed during the run ("link 1: stream
    /// truncated mid-frame"). Empty on a clean run; a non-empty list with
    /// `microbatches < workload.total` explains the shortfall.
    pub errors: Vec<String>,
    /// Reconnect/replay/dedup counters aggregated over the resilient
    /// links (all zero when none is resilient, or nothing failed).
    pub resilience: ResilienceSummary,
    /// Per-stripe wire counters for striped boundaries, concatenated in
    /// link order (empty when no link is striped).
    pub stripes: Vec<StripeSummary>,
}

impl RunReport {
    /// Machine-readable report. Non-finite values (an unconstrained link
    /// measures "infinite" bandwidth) are mapped to `null` — JSON has no
    /// Infinity/NaN, and downstream tooling must get a parseable document.
    pub fn to_json(&self) -> Value {
        let num = Value::num_or_null;
        let mut m = BTreeMap::new();
        m.insert("images".into(), Value::Num(self.images as f64));
        m.insert("microbatches".into(), Value::Num(self.microbatches as f64));
        m.insert("wall_secs".into(), num(self.wall_secs));
        m.insert("throughput".into(), num(self.throughput));
        m.insert("accuracy".into(), num(self.accuracy));
        m.insert("link0_mean_bytes".into(), num(self.link0_mean_bytes));
        m.insert(
            "window_accuracy".into(),
            Value::Arr(
                self.window_accuracy
                    .iter()
                    .map(|&(t, a)| Value::Arr(vec![num(t), num(a)]))
                    .collect(),
            ),
        );
        m.insert(
            "stage_compute_s".into(),
            Value::Arr(self.stage_compute_s.iter().map(|&s| num(s)).collect()),
        );
        m.insert("timeline".into(), self.timeline.to_json());
        m.insert("resilience".into(), self.resilience.to_json());
        m.insert("stripes".into(), StripeSummary::list_to_json(&self.stripes));
        m.insert(
            "errors".into(),
            Value::Arr(self.errors.iter().map(|e| Value::Str(e.clone())).collect()),
        );
        Value::Obj(m)
    }
}

/// Workload: which microbatches to feed.
pub struct Workload {
    /// Eval set to feed (cycled).
    pub eval: Arc<EvalSet>,
    /// Images per microbatch.
    pub microbatch: usize,
    /// Total microbatches to push (cycles over the eval set).
    pub total: u64,
}

impl Workload {
    /// One pass over the eval set.
    pub fn one_pass(eval: Arc<EvalSet>, microbatch: usize) -> Self {
        let total = eval.microbatches(microbatch) as u64;
        Workload { eval, microbatch, total }
    }

    /// Exactly `total` microbatches, cycling the eval set.
    pub fn repeat(eval: Arc<EvalSet>, microbatch: usize, total: u64) -> Self {
        Workload { eval, microbatch, total }
    }
}

/// Run the pipeline to completion and report. Blocking (the caller thread
/// acts as the sink).
pub fn run(spec: PipelineSpec, workload: Workload) -> Result<RunReport> {
    let PipelineSpec { stages, links, quant, adapt, window, inflight } = spec;
    let n = stages.len();
    anyhow::ensure!(n >= 1, "need at least one stage");
    anyhow::ensure!(
        links.len() + 1 == n,
        "need {} links for {} stages, got {}",
        n - 1,
        n,
        links.len()
    );

    let start = Instant::now();
    let timeline = Timeline::shared();
    let send_times: Arc<TrackedMutex<HashMap<u64, Instant>>> =
        Arc::new(TrackedMutex::new("driver.send_times", HashMap::new()));
    let label_map: Arc<TrackedMutex<HashMap<u64, Vec<u32>>>> =
        Arc::new(TrackedMutex::new("driver.label_map", HashMap::new()));
    let errors: Arc<TrackedMutex<Vec<String>>> =
        Arc::new(TrackedMutex::new("driver.errors", Vec::new()));
    let inflight = inflight.max(1);

    let (src_tx, src_rx) = sync_channel::<SourceMsg>(inflight);
    let (sink_tx, sink_rx) = sync_channel::<SinkMsg>(inflight);
    let stage_secs: Arc<TrackedMutex<Vec<(f64, u64)>>> =
        Arc::new(TrackedMutex::new("driver.stage_secs", vec![(0.0, 0); n]));

    let link_bits: Vec<Arc<AtomicU8>> = (0..n - 1)
        .map(|_| Arc::new(AtomicU8::new(quant.initial_bits)))
        .collect();
    let link_avg_fp: Vec<Arc<AtomicU32>> =
        (0..n - 1).map(|_| Arc::new(AtomicU32::new(0))).collect();
    let link_counters: Vec<Arc<LinkCounters>> = (0..n - 1)
        .map(|_| Arc::new(LinkCounters::default()))
        .collect();

    // Keep a handle on every resilient link's counters (and the striped
    // links' per-stripe blocks) before the specs are consumed into
    // thread-owned endpoints.
    let resilience_stats: Vec<Arc<ResilienceStats>> =
        links.iter().filter_map(|l| l.resilience()).collect();
    let stripe_handles: Vec<Arc<StripeStats>> = links
        .iter()
        .filter_map(|l| l.stripe_stats())
        .flatten()
        .collect();

    // --- stage + sender threads ----------------------------------------------
    let mut threads = Vec::new();
    let mut stage_input = StageIn::Source(src_rx);
    let mut link_iter = links.into_iter();

    for (i, factory) in stages.into_iter().enumerate() {
        let is_last = i == n - 1;
        let input = std::mem::replace(&mut stage_input, StageIn::Source(sync_channel(1).1));
        let secs = stage_secs.clone();
        let errs = errors.clone();

        if is_last {
            let out = StageOut::Sink(sink_tx.clone());
            threads.push(
                std::thread::Builder::new()
                    .name(format!("qp-stage-{i}"))
                    .spawn(move || stage_thread(i, factory, input, out, secs, errs))?,
            );
        } else {
            let (frame_tx, frame_rx) = sync_channel::<PreparedFrame>(inflight);
            let (link_tx, link_rx) = link_iter
                .next()
                // lint: allow(expect): links.len() + 1 == n is ensured at
                // entry, so every non-last stage has exactly one link to take.
                .expect("link count checked above")
                .into_endpoints(inflight);
            let pool = WirePool::new();
            let out = StageOut::Downstream {
                frame_tx,
                bits: link_bits[i].clone(),
                avg_fp: link_avg_fp[i].clone(),
                quant,
                pool: pool.clone(),
            };
            threads.push(
                std::thread::Builder::new()
                    .name(format!("qp-stage-{i}"))
                    .spawn(move || stage_thread(i, factory, input, out, secs, errs))?,
            );

            // Sender thread: transport + monitoring + adaptation for link i.
            let bits = link_bits[i].clone();
            let avg_fp = link_avg_fp[i].clone();
            let counters = link_counters[i].clone();
            let tl = timeline.clone();
            let errs = errors.clone();
            let batch = workload.microbatch;
            let initial_bits = quant.initial_bits;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("qp-send-{i}"))
                    .spawn(move || {
                        sender_thread(
                            i, frame_rx, link_tx, window, batch, adapt, initial_bits,
                            // In-process runs skip wire telemetry: every
                            // stage already records into the one shared
                            // timeline this RunReport returns.
                            bits, avg_fp, tl, counters, errs, start, None, pool,
                        )
                    })?,
            );
            stage_input = StageIn::Upstream(link_rx);
        }
    }
    drop(sink_tx);

    // --- source thread ----------------------------------------------------------
    {
        let eval = workload.eval.clone();
        let s = workload.microbatch;
        let total = workload.total;
        let labels = label_map.clone();
        let times = send_times.clone();
        threads.push(
            std::thread::Builder::new()
                .name("qp-source".into())
                .spawn(move || {
                    let per_pass = eval.microbatches(s).max(1);
                    for seq in 0..total {
                        let i = (seq as usize) % per_pass;
                        let tensor = eval.microbatch(i, s);
                        labels.guard().insert(seq, eval.labels_for(i, s).to_vec());
                        times.guard().insert(seq, Instant::now());
                        if src_tx.send(SourceMsg { seq, tensor }).is_err() {
                            break; // pipeline died; sink reports what completed
                        }
                    }
                })?,
        );
    }

    // --- sink (this thread) --------------------------------------------------------
    let mut acc = AccuracyMeter::default();
    let mut window_meter = AccuracyMeter::default();
    let mut window_accuracy = Vec::new();
    let mut latency = LatencyHisto::default();
    let mut done: u64 = 0;
    let mut images: u64 = 0;
    while let Ok(msg) = sink_rx.recv() {
        let labels = label_map.guard().remove(&msg.seq);
        if let Some(labels) = labels {
            images += labels.len() as u64;
            acc.add(&msg.logits, &labels);
            window_meter.add(&msg.logits, &labels);
        }
        if let Some(t0) = send_times.guard().remove(&msg.seq) {
            latency.record(t0.elapsed());
        }
        done += 1;
        if done % window == 0 {
            window_accuracy.push((start.elapsed().as_secs_f64(), window_meter.take()));
        }
        if done >= workload.total {
            break;
        }
    }
    drop(sink_rx); // unblocks a still-sending last stage
    if window_meter.total > 0 {
        window_accuracy.push((start.elapsed().as_secs_f64(), window_meter.take()));
    }

    let wall = start.elapsed().as_secs_f64().max(1e-9);
    for t in threads {
        let _ = t.join();
    }

    let link0_mean_bytes = link_counters
        .first()
        .map(|c| c.mean_frame_bytes())
        .unwrap_or(0.0);

    // NOT Arc::try_unwrap: a stage/sender thread that leaked its clone
    // (or died holding the lock) would silently erase the whole timeline.
    let timeline = Timeline::take_shared(&timeline);

    let stage_compute_s = stage_secs
        .guard()
        .iter()
        .map(|&(s, c)| if c > 0 { s / c as f64 } else { 0.0 })
        .collect();

    let errors = std::mem::take(&mut *errors.guard());

    Ok(RunReport {
        images,
        microbatches: done,
        wall_secs: wall,
        throughput: images as f64 / wall,
        accuracy: acc.value(),
        window_accuracy,
        timeline,
        latency,
        link0_mean_bytes,
        stage_compute_s,
        errors,
        resilience: ResilienceSummary::collect(&resilience_stats),
        stripes: StripeSummary::collect(&stripe_handles),
    })
}

// -----------------------------------------------------------------------------
// Stage thread body
// -----------------------------------------------------------------------------

fn stage_thread(
    idx: usize,
    factory: StageFactory,
    input: StageIn,
    output: StageOut,
    secs: Arc<TrackedMutex<Vec<(f64, u64)>>>,
    errors: Arc<TrackedMutex<Vec<String>>>,
) {
    if let Err(e) = stage_loop(idx, factory, input, output, secs) {
        // Poison-tolerant: if another thread panicked holding this lock,
        // still record the error we actually saw (the root cause must not
        // drown in a poisoned-mutex cascade).
        errors.guard().push(format!("stage {idx}: {e:#}"));
        eprintln!("[quantpipe] stage {idx} exited with error: {e:#}");
    }
}

fn stage_loop(
    idx: usize,
    factory: StageFactory,
    mut input: StageIn,
    output: StageOut,
    secs: Arc<TrackedMutex<Vec<(f64, u64)>>>,
) -> Result<()> {
    let bundle = factory()?;
    let mut compute = bundle.compute;
    let mut codec = Codec::new(bundle.quant_backend);
    if let StageOut::Downstream { quant, .. } = &output {
        codec.set_threads(quant.codec_threads);
        codec.set_tiling(quant.tile_codec());
    }
    // One-slot pool of decoded-activation storage: each frame decodes
    // into the pooled buffer, the buffer moves into the `Tensor` handed
    // to compute, and comes back after — zero per-microbatch payload
    // allocation in steady state (this used to be a full `clone()`).
    let mut decode_pool: Vec<f32> = Vec::new();
    // Calibration cache: reused until `calib_every` sends or a bits change.
    let mut cached: Option<QuantParams> = None;
    let mut since_calib: u32 = 0;

    loop {
        // The in-proc source is single-stream (stream 0); a frame arriving
        // from upstream keeps whatever stream tag the coordinator put on
        // it — stages route payloads, they never own streams.
        let (seq, stream, tensor) = match &mut input {
            StageIn::Source(rx) => match rx.recv() {
                Ok(m) => (m.seq, 0u32, m.tensor),
                Err(_) => return Ok(()),
            },
            StageIn::Upstream(rx) => match rx.recv() {
                Ok(Some(frame)) => {
                    let mut data = std::mem::take(&mut decode_pool);
                    codec.decode(&frame.enc, &mut data)?;
                    let Frame { seq, stream, shape, enc } = frame;
                    codec.recycle(enc); // reuse the payload allocation for our own encodes
                    (seq, stream, Tensor::new(data, shape))
                }
                Ok(None) => return Ok(()), // clean upstream shutdown
                Err(e) => {
                    return Err(e.context("upstream link failed (reporting, not ending quietly)"))
                }
            },
        };

        let t0 = Instant::now();
        let out = compute.run(&tensor)?;
        {
            let mut s = secs.guard();
            s[idx].0 += t0.elapsed().as_secs_f64();
            s[idx].1 += 1;
        }
        // Compute is done with the input: reclaim its buffer for the
        // next frame's decode.
        decode_pool = tensor.into_data();

        match &output {
            StageOut::Sink(tx) => {
                if tx.send(SinkMsg { seq, logits: out }).is_err() {
                    return Ok(()); // sink finished early
                }
            }
            StageOut::Downstream { frame_tx, bits, avg_fp, quant, pool } => {
                let enc = encode_at_current_bits(
                    &mut codec, &out.data, quant, bits, avg_fp, &mut cached, &mut since_calib,
                )?;
                // Serialize ONCE, into a pooled wire buffer; from here the
                // same Vec travels channel → sender thread → transport
                // (replay buffer, socket write) without another copy.
                let frame = Frame::for_stream(stream, seq, out.shape.clone(), enc);
                let mut wire = pool.take();
                frame.write_into(&mut wire);
                let Frame { enc, .. } = frame;
                codec.recycle(enc); // reuse the payload allocation next encode
                if frame_tx.send(PreparedFrame { seq, wire }).is_err() {
                    return Ok(());
                }
            }
        }
    }
}

/// Encode one activation at the bitwidth currently published by the link's
/// controller, amortizing calibration across `calib_every` sends. Shared
/// by the in-driver stage loop and the multi-process worker endpoint.
///
/// When the codec has tiling configured and the width is in the sub-byte
/// regime (≤ 8 bits), frames go out as tiled payloads; `avg_fp` (the
/// budget-mode average, fixed-point ×256, 0 = uniform) then drives the
/// per-tile width allocation. 16-bit and raw frames stay flat — tile
/// tables cost more than they buy there.
#[allow(clippy::too_many_arguments)]
pub(crate) fn encode_at_current_bits(
    codec: &mut Codec,
    data: &[f32],
    quant: &LinkQuant,
    bits: &AtomicU8,
    avg_fp: &AtomicU32,
    cached: &mut Option<QuantParams>,
    since_calib: &mut u32,
) -> Result<crate::quant::codec::Encoded> {
    let bits_now = bits.load(Ordering::Relaxed);
    if bits_now >= BITS_NONE {
        *cached = None;
        return codec.encode(data, quant.method, BITS_NONE);
    }
    if codec.tiling_enabled() && bits_now <= 8 {
        *cached = None;
        let fp = avg_fp.load(Ordering::Relaxed);
        let avg = (fp != 0).then(|| fp as f32 / 256.0);
        return codec.encode_tiled(data, bits_now, avg);
    }
    // Reuse the cached params while they are fresh (same bitwidth, within
    // the calibration interval); otherwise recalibrate. Binding the chosen
    // params here keeps the hot path `unwrap`-free by construction.
    let params = match cached {
        Some(p) if p.bits == bits_now && *since_calib < quant.calib_every => *p,
        _ => {
            let p = calibrate(data, quant.method, bits_now);
            *cached = Some(p);
            *since_calib = 0;
            p
        }
    };
    *since_calib += 1;
    codec.encode_with_params(data, params)
}

// -----------------------------------------------------------------------------
// Sender thread: transport + window monitor + Eq.2 controller
// -----------------------------------------------------------------------------

/// Ship frames through any [`FrameTx`], feeding the monitor with measured
/// busy time and running the adaptive controller at window boundaries.
/// Used by the in-process driver and the multi-process worker endpoint —
/// the control loop never knows which transport it's on.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sender_thread(
    stage: usize,
    frame_rx: Receiver<PreparedFrame>,
    mut link_tx: Box<dyn FrameTx>,
    window: u64,
    batch: usize,
    adapt: Option<AdaptConfig>,
    initial_bits: u8,
    bits: Arc<AtomicU8>,
    avg_fp: Arc<AtomicU32>,
    timeline: Arc<TrackedMutex<Timeline>>,
    counters: Arc<LinkCounters>,
    errors: Arc<TrackedMutex<Vec<String>>>,
    start: Instant,
    mut telemetry: Option<TelemetryTap>,
    pool: Arc<WirePool>,
) {
    let mut monitor = WindowMonitor::new(window, batch);
    let mut ctl = adapt.map(|cfg| {
        let mut c = AdaptivePda::new(cfg);
        c.set_bits(initial_bits);
        c
    });
    while let Ok(prepared) = frame_rx.recv() {
        let wire = prepared.wire.len();
        if let Some(t) = &mut telemetry {
            t.shared.dequeued.fetch_add(1, Ordering::Relaxed);
            t.note_seq(prepared.seq);
        }
        // On a resilient link `send_prepared` rides out transient failures
        // internally: the reconnect stall comes back as busy time, the
        // monitor turns it into collapsed measured bandwidth, and the
        // controller sheds bits for the outage. Only a hard failure
        // (reconnect budget exhausted) reaches the error path.
        let busy = match link_tx.send_prepared(prepared) {
            Ok(b) => b,
            Err(e) => {
                errors
                    .guard()
                    .push(format!("link {stage} ({}): send failed: {e:#}", link_tx.kind()));
                return;
            }
        };
        // Close the buffer loop: whatever the transport is done with
        // (acked replay entries, written-out frames) goes back to the
        // stage loop for the next serialization.
        while let Some(buf) = link_tx.reclaim_wire() {
            pool.put(buf);
        }
        counters.bytes.fetch_add(wire as u64, Ordering::Relaxed);
        counters.frames.fetch_add(1, Ordering::Relaxed);
        if let Some(stats) = monitor.record_send(wire, busy) {
            let decided = if let Some(c) = &mut ctl {
                let d = c.on_window(&stats);
                bits.store(d.bits, Ordering::Relaxed);
                // Budget-mode continuous average rides beside the
                // discrete width, fixed-point ×256 (0 = uniform).
                avg_fp.store(
                    d.avg_bits.map_or(0, |a| (a * 256.0).round() as u32),
                    Ordering::Relaxed,
                );
                d.bits
            } else {
                bits.load(Ordering::Relaxed)
            };
            let point = TimelinePoint {
                t: start.elapsed().as_secs_f64(),
                stage,
                bandwidth_bps: stats.bandwidth_bps,
                rate: stats.rate,
                bits: decided,
                util: stats.link_utilization,
            };
            timeline.guard().push(point);
            if let Some(t) = &mut telemetry {
                // One snapshot per completed window: the record carries
                // this window's point plus the cumulative counters.
                t.push_point(point);
                t.flush(&mut *link_tx, false);
            }
        }
        if let Some(t) = &mut telemetry {
            // Upstream stages' snapshots relay forward between frames.
            t.forward_relayed(&mut *link_tx);
        }
    }
    if let Some(t) = &mut telemetry {
        // Final snapshot (and relay leftovers) BEFORE the drain: FIN must
        // be the last thing on the stream.
        t.final_flush(&mut *link_tx);
    }
    // Upstream is done: negotiate the clean drain so the peer can tell
    // shutdown from failure (FIN/FIN_ACK on resilient links, no-op else).
    if let Err(e) = link_tx.finish() {
        errors
            .guard()
            .push(format!("link {stage} ({}): drain failed: {e:#}", link_tx.kind()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::Policy;
    use crate::monitor::WindowStats;
    use crate::quant::tile::TileView;

    fn mk_window(bw: f64) -> WindowStats {
        WindowStats {
            bandwidth_bps: bw,
            rate: f64::INFINITY,
            mean_bytes: 524288.0,
            microbatches: 50,
            wall_secs: 1.0,
            link_utilization: 1.0,
        }
    }

    /// Publish a decision the way `sender_thread` does.
    fn publish(d: &crate::adapt::Decision, bits: &AtomicU8, avg_fp: &AtomicU32) {
        bits.store(d.bits, Ordering::Relaxed);
        avg_fp.store(d.avg_bits.map_or(0, |a| (a * 256.0).round() as u32), Ordering::Relaxed);
    }

    #[test]
    fn bandwidth_drop_degrades_bits_per_tile_not_uniformly() {
        // The budget acceptance case, at driver level: the controller on
        // one side, the encode path on the other, linked by the same
        // atomics the sender and stage threads share.
        let quant =
            LinkQuant { tile_elems: 1024, outlier_frac: 0.0, ..LinkQuant::default() };
        let mut codec = Codec::default();
        codec.set_tiling(quant.tile_codec());
        let bits = AtomicU8::new(BITS_NONE);
        let avg_fp = AtomicU32::new(0);
        let (mut cached, mut since) = (None, 0u32);
        let mut encode = |codec: &mut Codec, x: &[f32]| {
            encode_at_current_bits(codec, x, &quant, &bits, &avg_fp, &mut cached, &mut since)
                .unwrap()
        };

        // One loud tile, seven quiet ones — heterogeneous on purpose.
        let mut rng = crate::util::rng::Rng::seed(41);
        let x: Vec<f32> = (0..8192)
            .map(|i| rng.laplace(if i < 1024 { 2.0 } else { 0.02 }) as f32)
            .collect();

        let mut ctl = AdaptivePda::new(AdaptConfig {
            target_rate: 100.0,
            microbatch: 64,
            policy: Policy::Budget,
            raise_margin: 1.0,
        });
        ctl.set_bits(BITS_NONE);

        // Healthy link: raw passthrough, nothing tiled.
        let d = ctl.on_window(&mk_window(f64::INFINITY));
        publish(&d, &bits, &avg_fp);
        let enc = encode(&mut codec, &x);
        assert!(!enc.tiled && enc.params.is_none());

        // Simulated bandwidth drop: ratio 6.55 ⇒ ladder 4-bit, budget
        // average ≈ 4.88 bits. The encode must go out tiled with
        // NON-uniform per-tile widths — the loud tile keeps more bits.
        let d = ctl.on_window(&mk_window(1e6));
        assert_eq!(d.bits, 4, "{d:?}");
        publish(&d, &bits, &avg_fp);
        let enc = encode(&mut codec, &x);
        assert!(enc.tiled);
        let view = TileView::parse(&enc.payload, x.len()).unwrap();
        let widths: Vec<u8> = view.params.iter().map(|p| p.bits).collect();
        let distinct: std::collections::BTreeSet<u8> = widths.iter().copied().collect();
        assert!(distinct.len() > 1, "drop must degrade per tile, got {widths:?}");
        let quiet_min = *widths[1..].iter().min().unwrap();
        assert!(widths[0] > quiet_min, "loud tile keeps more bits: {widths:?}");
        // The realized average respects the published budget.
        let avg = widths.iter().map(|&b| b as usize * 1024).sum::<usize>() as f64 / 8192.0;
        assert!(avg <= d.avg_bits.unwrap() as f64 + 1e-6, "avg {avg} vs {d:?}");

        // Recovery: the controller returns to raw and the encode follows.
        let d = ctl.on_window(&mk_window(f64::INFINITY));
        publish(&d, &bits, &avg_fp);
        assert!(!encode(&mut codec, &x).tiled);
    }

    #[test]
    fn flat_links_ignore_the_budget_atomic() {
        // tile_elems = 0 (today's default): even with a budget published,
        // frames stay in the flat wire format — byte-compatible with
        // pre-tiling peers.
        let quant = LinkQuant::default();
        let mut codec = Codec::default();
        codec.set_tiling(quant.tile_codec());
        assert!(!codec.tiling_enabled());
        let bits = AtomicU8::new(4);
        let avg_fp = AtomicU32::new((4.9 * 256.0) as u32);
        let (mut cached, mut since) = (None, 0u32);
        let x: Vec<f32> = (0..2048).map(|i| ((i as f32) * 0.37).sin()).collect();
        let enc =
            encode_at_current_bits(&mut codec, &x, &quant, &bits, &avg_fp, &mut cached, &mut since)
                .unwrap();
        assert!(!enc.tiled);
        assert_eq!(enc.bits(), 4);
    }
}
