//! # QuantPipe
//!
//! Reproduction of *QuantPipe: Applying Adaptive Post-Training Quantization
//! for Distributed Transformer Pipelines in Dynamic Edge Environments*
//! (Wang et al., 2022) as a three-layer rust + JAX + Pallas stack.
//!
//! The crate is the **Layer-3 coordinator**: it owns the pipeline runtime
//! (stage threads, microbatch flow, shaped links), the runtime bandwidth
//! monitor, the adaptive PDA bitwidth controller (paper Eq. 2), and the
//! quantization codec (naive PTQ / ACIQ / DS-ACIQ, bit packing, wire
//! framing). Model shards and the Pallas quantize/dequantize kernels are
//! AOT-compiled from JAX to HLO text at build time (`make artifacts`) and
//! executed through the PJRT CPU client ([`runtime`]); **python is never on
//! the request path**.
//!
//! The build environment is offline: besides `xla` (PJRT FFI) and `anyhow`,
//! everything — JSON, config, RNG, property testing, the bench harness —
//! is implemented in-tree ([`util`]).
//!
//! ## Module map
//!
//! | module | paper role |
//! |---|---|
//! | [`quant`] | §3 PTQ/ACIQ/DS-ACIQ math, bit packing, tensor codec; the deployed data path is `quant::fused` — single-pass quantize+pack / unpack+dequantize kernels (SIMD on AVX2/SSE2 with a byte-identical scalar fallback, optionally multicore via `pipeline.codec_threads`); `quant::tile` layers tile-wise hybrid quantization over it: per-tile calibration, a raw-f32 outlier side-channel, and budget-allocated non-uniform per-tile widths |
//! | [`net`] | edge network substrate: the `FrameTx`/`FrameRx` transport abstraction over shaped in-proc links *and* real TCP sockets; the layered reliability stack (`net::session` protocol state machine → `net::conduit` connections → `net::stripe` N-connection striped boundaries, with `net::resilient` as the 1-conduit case); traces, wire framing |
//! | [`monitor`] | §3 runtime monitor (windowed bandwidth / output-rate) |
//! | [`adapt`] | §3 adaptive PDA module (Eq. 2 bitwidth policy) |
//! | [`pipeline`] | transport-agnostic pipeline driver (stage threads, scheduling, backpressure) + multi-process worker/coordinator endpoints; `pipeline::serve` is the multi-stream serving plane — weighted-round-robin admission over bounded per-stream queues, feeding `run_serving_coordinator` |
//! | [`partition`] | PipeEdge [15] optimal partition DP |
//! | [`runtime`] | PJRT engine: load + execute AOT HLO artifacts |
//! | [`tensor`] | host tensors (f32 / i32) |
//! | [`data`] | eval/calibration set loaders, accuracy |
//! | [`metrics`] | throughput / latency instrumentation, Fig 5 timelines |
//! | [`config`] | JSON config + experiment presets (incl. the `transport` topology section) |
//! | [`util`] | offline-substitute utilities (JSON, RNG, prop testing, the bounded-exhaustive explorer) |
//! | [`analysis`] | self-hosted correctness tooling: lint pass, wire-spec cross-check, interleaving checker (runs as `cargo test`) |
//!
//! ## Running over real TCP
//!
//! The pipeline driver is transport-agnostic: every stage boundary is a
//! [`net::transport::LinkSpec`] — either a bandwidth-shaped in-process
//! channel (`Sim`, the measurement substrate) or a pre-connected real
//! socket (`Tcp`). In TCP mode nothing simulates bandwidth: the
//! `WindowMonitor` feeds on measured *write-stall* time (a full kernel
//! send buffer blocks the writer), so the adaptive controller reacts to
//! genuine network backpressure.
//!
//! Single process, real loopback sockets:
//! `cargo run --release --example tcp_pipeline`.
//!
//! One process per stage (the paper's testbed topology): start
//! `quantpipe coordinate` plus one `quantpipe worker --stage k` per
//! stage, in any order — connects retry. Addresses come from the config
//! `transport` section (see `configs/tcp_demo.json`) or
//! `--listen`/`--connect` flags; `--mock`/`--synthetic` run the topology
//! without AOT artifacts.
//!
//! With `transport.resilient` (or `--resilient true`) every stage
//! boundary survives transient link failures: the connecting side
//! redials with backoff + jitter, a `HELLO{next_expected_seq}` handshake
//! resyncs the two ends, the sender replays the unacked tail from its
//! replay buffer, and shutdown is an explicit FIN/FIN_ACK drain. The
//! reconnect stall feeds the `WindowMonitor` as busy time, so the
//! controller sheds bits during an outage instead of the run aborting.
//!
//! With `transport.stripes: N` (or `--stripes N`; requires resilient)
//! every boundary is additionally **striped** over N TCP connections
//! sharing one sequence space ([`net::stripe`]) — for high-BDP or
//! multi-path edge links where a single connection leaves bandwidth on
//! the table. The receiver reorders across stripes, replay/ACK resync is
//! session-scoped (any conduit can recover any gap), and a lost stripe
//! reads as partial bandwidth collapse rather than an outage.
//!
//! ## Observability
//!
//! Every worker streams per-window telemetry snapshots forward along the
//! data path ([`metrics::telemetry`]); the coordinator merges all stages
//! into one `PipelineReport` (JSON via `--report-json`, rendered by
//! `quantpipe report`) — per-stage timelines aligned on microbatch seq,
//! per-boundary bandwidth/bits tracks, and end-to-end latency
//! attribution, from a single artifact instead of N interleaved stdouts.

// Docs are part of the contract: every public item documents itself, and
// CI keeps `cargo doc` warning-free.
#![warn(missing_docs)]

pub mod adapt;
pub mod analysis;
pub mod benchkit;
pub mod config;
pub mod data;
pub mod metrics;
pub mod monitor;
pub mod net;
pub mod partition;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
