//! # QuantPipe
//!
//! Reproduction of *QuantPipe: Applying Adaptive Post-Training Quantization
//! for Distributed Transformer Pipelines in Dynamic Edge Environments*
//! (Wang et al., 2022) as a three-layer rust + JAX + Pallas stack.
//!
//! The crate is the **Layer-3 coordinator**: it owns the pipeline runtime
//! (stage threads, microbatch flow, shaped links), the runtime bandwidth
//! monitor, the adaptive PDA bitwidth controller (paper Eq. 2), and the
//! quantization codec (naive PTQ / ACIQ / DS-ACIQ, bit packing, wire
//! framing). Model shards and the Pallas quantize/dequantize kernels are
//! AOT-compiled from JAX to HLO text at build time (`make artifacts`) and
//! executed through the PJRT CPU client ([`runtime`]); **python is never on
//! the request path**.
//!
//! The build environment is offline: besides `xla` (PJRT FFI) and `anyhow`,
//! everything — JSON, config, RNG, property testing, the bench harness —
//! is implemented in-tree ([`util`]).
//!
//! ## Module map
//!
//! | module | paper role |
//! |---|---|
//! | [`quant`] | §3 PTQ/ACIQ/DS-ACIQ math, bit packing, tensor codec |
//! | [`net`] | edge network substrate: shaped links, traces, framing, transports |
//! | [`monitor`] | §3 runtime monitor (windowed bandwidth / output-rate) |
//! | [`adapt`] | §3 adaptive PDA module (Eq. 2 bitwidth policy) |
//! | [`pipeline`] | distributed pipeline driver: stage threads, scheduling, backpressure |
//! | [`partition`] | PipeEdge [15] optimal partition DP |
//! | [`runtime`] | PJRT engine: load + execute AOT HLO artifacts |
//! | [`tensor`] | host tensors (f32 / i32) |
//! | [`data`] | eval/calibration set loaders, accuracy |
//! | [`metrics`] | throughput / latency instrumentation, Fig 5 timelines |
//! | [`config`] | JSON config + experiment presets |
//! | [`util`] | offline-substitute utilities (JSON, RNG, prop testing) |

pub mod adapt;
pub mod benchkit;
pub mod config;
pub mod data;
pub mod metrics;
pub mod monitor;
pub mod net;
pub mod partition;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
