//! Artifact manifest: the contract between `make artifacts` (python AOT)
//! and the rust runtime. Mirrors python/compile/aot.py's manifest.json,
//! parsed with the in-tree JSON parser.

use crate::util::json::Value;
use crate::Result;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
/// Parsed manifest.json: the artifact bundle's table of contents.
pub struct Manifest {
    /// Manifest schema version.
    pub version: u32,
    /// Model hyperparameters.
    pub model: ModelMeta,
    /// Images per microbatch the shards were compiled for.
    pub microbatch: usize,
    /// Inter-stage activation shape.
    pub activation_shape: Vec<usize>,
    /// Per-stage shard artifacts, in pipeline order.
    pub stages: Vec<StageMeta>,
    /// Unpartitioned reference model artifact.
    pub full_model: FullModelMeta,
    /// AOT quantize/dequantize kernel artifacts.
    pub quant: QuantMeta,
    /// Eval-set artifact.
    pub eval: EvalMeta,
    /// Calibration-set artifact.
    pub calib: CalibMeta,
    /// Golden-values file name.
    pub golden: String,
}

#[derive(Debug, Clone)]
/// Model hyperparameters (ViT).
pub struct ModelMeta {
    /// Input image dims (h, w, c).
    pub img: Vec<usize>,
    /// Patch size.
    pub patch: usize,
    /// Embedding dimension.
    pub dim: usize,
    /// Transformer depth (blocks).
    pub depth: usize,
    /// Attention heads.
    pub heads: usize,
    /// Output classes.
    pub classes: usize,
    /// Sequence length (patches + cls).
    pub tokens: usize,
    /// Parameter count.
    pub params: u64,
    /// Trained weights (vs random init).
    pub trained: bool,
    /// Full-precision top-1 accuracy reference.
    pub fp32_top1: f64,
}

#[derive(Debug, Clone)]
/// One pipeline stage's shard artifact.
pub struct StageMeta {
    /// HLO text file name.
    pub file: String,
    /// Block indices this stage runs.
    pub blocks: Vec<usize>,
    /// Includes the patch-embedding front end.
    pub first: bool,
    /// Includes the classifier head.
    pub last: bool,
    /// Input activation shape.
    pub in_shape: Vec<usize>,
    /// Output activation shape.
    pub out_shape: Vec<usize>,
}

#[derive(Debug, Clone)]
/// The unpartitioned model artifact (golden reference).
pub struct FullModelMeta {
    /// HLO text file name.
    pub file: String,
    /// Input shape.
    pub in_shape: Vec<usize>,
    /// Output shape.
    pub out_shape: Vec<usize>,
}

#[derive(Debug, Clone)]
/// AOT quantize/dequantize kernel artifacts.
pub struct QuantMeta {
    /// Quantize kernel HLO file.
    pub quantize: String,
    /// Dequantize kernel HLO file.
    pub dequantize: String,
    /// Kernel tile rows.
    pub rows: usize,
    /// Kernel tile cols.
    pub cols: usize,
    /// Bitwidths the kernels were compiled for.
    pub supported_bits: Vec<u8>,
}

#[derive(Debug, Clone)]
/// Eval-set artifact pointer.
pub struct EvalMeta {
    /// eval.bin file name.
    pub file: String,
    /// Images in the set.
    pub count: usize,
}

#[derive(Debug, Clone)]
/// Calibration-set artifact pointer.
pub struct CalibMeta {
    /// calib.bin file name.
    pub file: String,
    /// Stage boundaries covered.
    pub boundaries: usize,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<(Manifest, PathBuf)> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts` first): {e}"))?;
        let m = Self::parse(&text)?;
        Ok((m, dir))
    }

    /// Parse manifest.json text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Value::parse(text)?;
        let version = v.at("version")?.as_u64()? as u32;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let mv = v.at("model")?;
        let model = ModelMeta {
            img: mv.at("img")?.usize_vec()?,
            patch: mv.at("patch")?.as_usize()?,
            dim: mv.at("dim")?.as_usize()?,
            depth: mv.at("depth")?.as_usize()?,
            heads: mv.at("heads")?.as_usize()?,
            classes: mv.at("classes")?.as_usize()?,
            tokens: mv.at("tokens")?.as_usize()?,
            params: mv.at("params")?.as_u64()?,
            trained: mv.at("trained")?.as_bool()?,
            fp32_top1: mv.at("fp32_top1")?.as_f64()?,
        };
        let stages = v
            .at("stages")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(StageMeta {
                    file: s.at("file")?.as_str()?.into(),
                    blocks: s.at("blocks")?.usize_vec()?,
                    first: s.at("first")?.as_bool()?,
                    last: s.at("last")?.as_bool()?,
                    in_shape: s.at("in_shape")?.usize_vec()?,
                    out_shape: s.at("out_shape")?.usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let fv = v.at("full_model")?;
        let qv = v.at("quant")?;
        let ev = v.at("eval")?;
        let cv = v.at("calib")?;
        Ok(Manifest {
            version,
            model,
            microbatch: v.at("microbatch")?.as_usize()?,
            activation_shape: v.at("activation_shape")?.usize_vec()?,
            stages,
            full_model: FullModelMeta {
                file: fv.at("file")?.as_str()?.into(),
                in_shape: fv.at("in_shape")?.usize_vec()?,
                out_shape: fv.at("out_shape")?.usize_vec()?,
            },
            quant: QuantMeta {
                quantize: qv.at("quantize")?.as_str()?.into(),
                dequantize: qv.at("dequantize")?.as_str()?.into(),
                rows: qv.at("rows")?.as_usize()?,
                cols: qv.at("cols")?.as_usize()?,
                supported_bits: qv
                    .at("supported_bits")?
                    .usize_vec()?
                    .into_iter()
                    .map(|b| b as u8)
                    .collect(),
            },
            eval: EvalMeta {
                file: ev.at("file")?.as_str()?.into(),
                count: ev.at("count")?.as_usize()?,
            },
            calib: CalibMeta {
                file: cv.at("file")?.as_str()?.into(),
                boundaries: cv.at("boundaries")?.as_usize()?,
            },
            golden: v.at("golden")?.as_str()?.into(),
        })
    }

    /// Default artifacts directory: `$QUANTPIPE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("QUANTPIPE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub const SAMPLE: &str = r#"{
      "version": 1,
      "model": {"img":[32,32,3],"patch":8,"dim":128,"depth":8,"heads":4,
                "classes":10,"tokens":16,"params":1000,
                "trained":true,"fp32_top1":0.93},
      "microbatch": 64,
      "activation_shape": [64,16,128],
      "stages": [{"file":"stage_0.hlo.txt","blocks":[0,2],"first":true,
                  "last":false,"in_shape":[64,32,32,3],"out_shape":[64,16,128]}],
      "full_model": {"file":"model_full.hlo.txt","in_shape":[64,32,32,3],"out_shape":[64,10]},
      "quant": {"quantize":"quantize.hlo.txt","dequantize":"dequantize.hlo.txt",
                "rows":1024,"cols":128,"supported_bits":[2,4,6,8,16]},
      "eval": {"file":"eval.bin","count":1920},
      "calib": {"file":"calib.bin","boundaries":3},
      "golden": "golden.json"
    }"#;

    #[test]
    fn parse_minimal_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.microbatch, 64);
        assert_eq!(m.stages.len(), 1);
        assert!(m.stages[0].first);
        assert_eq!(m.quant.rows, 1024);
        assert_eq!(m.quant.supported_bits, vec![2, 4, 6, 8, 16]);
        assert!((m.model.fp32_top1 - 0.93).abs() < 1e-12);
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn version_gate() {
        let bad = SAMPLE.replacen("\"version\": 1", "\"version\": 9", 1);
        assert!(Manifest::parse(&bad).is_err());
    }
}
