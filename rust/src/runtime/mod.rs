//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO **text** is the interchange format
//! (serialized jax≥0.5 protos are rejected by xla_extension 0.5.1).
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so an [`Engine`] lives on one
//! thread; the pipeline gives each stage its own OS thread that constructs
//! its engine in place (see [`crate::pipeline`]).

pub mod artifacts;

pub use artifacts::Manifest;

use crate::quant::codec::QuantBackend;
use crate::quant::QuantParams;
use crate::tensor::Tensor;
use crate::Result;
use std::path::Path;

/// One-thread PJRT engine: client + compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    /// Backend platform name.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("loading HLO {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe })
    }
}

/// A compiled computation. All our AOT modules return a 1-tuple (lowered
/// with `return_tuple=True`), so `run*` unwraps `to_tuple1`.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 tensor inputs, returning the f32 tuple-0 output.
    pub fn run_f32(&self, inputs: &[&Tensor], out_shape: &[usize]) -> Result<Tensor> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| literal_f32(&t.data, &t.shape))
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let data = out.to_vec::<f32>()?;
        anyhow::ensure!(
            data.len() == out_shape.iter().product::<usize>(),
            "output size mismatch: got {} want {:?}",
            data.len(),
            out_shape
        );
        Ok(Tensor::new(data, out_shape.to_vec()))
    }

    /// Execute with raw literals (mixed dtypes).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// The AOT Pallas quantize/dequantize kernels as a [`QuantBackend`].
///
/// The kernels were lowered for a fixed (rows, cols) activation shape (all
/// ViT boundaries share it); scale/zp/lo/hi arrive as runtime `(1,)`
/// tensors so bitwidth changes never recompile.
pub struct HloQuantBackend {
    quantize: Executable,
    dequantize: Executable,
    rows: usize,
    cols: usize,
    /// Thread the backend was constructed on. The `unsafe impl Send`
    /// below is sound only under the construct-where-you-use discipline;
    /// debug builds assert it on every kernel call.
    home: std::thread::ThreadId,
}

impl HloQuantBackend {
    /// Load the AOT quantize/dequantize executables named in the manifest.
    pub fn load(engine: &Engine, dir: impl AsRef<Path>, manifest: &Manifest) -> Result<Self> {
        let dir = dir.as_ref();
        Ok(HloQuantBackend {
            quantize: engine.load_hlo(dir.join(&manifest.quant.quantize))?,
            dequantize: engine.load_hlo(dir.join(&manifest.quant.dequantize))?,
            rows: manifest.quant.rows,
            cols: manifest.quant.cols,
            home: std::thread::current().id(),
        })
    }

    /// Debug-build guard for the `Send` contract: every kernel call must
    /// happen on the thread that constructed the backend.
    #[inline]
    fn assert_home_thread(&self) {
        debug_assert_eq!(
            std::thread::current().id(),
            self.home,
            "HloQuantBackend used off its construction thread — the unsafe \
             `Send` impl relies on construct-where-you-use (see runtime/mod.rs)"
        );
    }
}

impl QuantBackend for HloQuantBackend {
    fn quantize(&mut self, x: &[f32], p: &QuantParams, out: &mut [i32]) -> Result<()> {
        self.assert_home_thread();
        anyhow::ensure!(
            x.len() == self.rows * self.cols,
            "HLO quant kernel compiled for {}x{}, got {} elems",
            self.rows,
            self.cols,
            x.len()
        );
        let scalar = |v: f32| literal_f32(&[v], &[1]);
        let lits = vec![
            literal_f32(x, &[self.rows, self.cols])?,
            scalar(p.scale)?,
            scalar(p.zero_point)?,
            scalar(p.lo)?,
            scalar(p.hi)?,
        ];
        let res = self.quantize.run_literals(&lits)?;
        let codes = res.to_vec::<i32>()?;
        out.copy_from_slice(&codes);
        Ok(())
    }

    fn dequantize(&mut self, codes: &[i32], p: &QuantParams, out: &mut [f32]) -> Result<()> {
        self.assert_home_thread();
        anyhow::ensure!(codes.len() == self.rows * self.cols, "shape mismatch");
        let scalar = |v: f32| literal_f32(&[v], &[1]);
        let lits = vec![
            literal_i32(codes, &[self.rows, self.cols])?,
            scalar(p.scale)?,
            scalar(p.zero_point)?,
        ];
        let res = self.dequantize.run_literals(&lits)?;
        let x = res.to_vec::<f32>()?;
        out.copy_from_slice(&x);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "hlo-pallas"
    }
}

// SAFETY: Engine/Executable contain Rc-backed PJRT handles and are
// therefore !Send; this impl asserts that HloQuantBackend may cross a
// thread boundary anyway. It is sound because every constructor runs
// inside the stage thread that will use the backend (the pipeline moves a
// `Send` *factory* closure, never a constructed Engine — see
// pipeline::StageFactory), so the Rc reference counts are only ever
// touched from one thread for the value's whole life. The bound exists
// only because `QuantBackend: Send` (Codec moves native backends between
// threads); the HLO backend never actually migrates. Debug builds enforce
// the discipline: `assert_home_thread` panics on any kernel call from a
// thread other than the constructing one.
unsafe impl Send for HloQuantBackend {}

#[cfg(test)]
mod tests {
    // Engine tests that require artifacts live in rust/tests/ (integration)
    // so unit tests stay artifact-free.

    #[test]
    fn manifest_default_dir_env_override() {
        std::env::set_var("QUANTPIPE_ARTIFACTS", "/tmp/somewhere");
        assert_eq!(
            super::Manifest::default_dir(),
            std::path::PathBuf::from("/tmp/somewhere")
        );
        std::env::remove_var("QUANTPIPE_ARTIFACTS");
        assert_eq!(super::Manifest::default_dir(), std::path::PathBuf::from("artifacts"));
    }
}
